"""Prepared-plan vs per-call-padding predictor benchmark, plus the
quantize-once scenarios the quantized-first API exists for.

Scenario 1 (``run``) measures the cost the compiled-plan API hoists out
of the hot loop: the legacy kwarg path (`core.predict.raw_predict`)
re-resolves the backend, re-runs the block tuner and re-pads the model
arrays on every call, while `Predictor.build` does all of that once and
then dispatches through a shape-cached jitted entry.

  kwarg       eager legacy path, per-call preparation
  kwarg-jit   legacy path under a caller-side jax.jit (the old
              "fast" pattern every call site had to hand-roll)
  prepared    Predictor built once, plan.raw per call

Scenario 2 (``run_quantized``) measures what quantizing once hoists on
top of a prepared plan:

  prepared-float   plan.raw(x) — binarize runs inside every call
  prequantized     pool = plan.quantize(x) once; plan.raw(pool) per
                   call — binarize never runs

Scenario 3 (``run_registry``) is the multi-model serving shape: K
models sharing one feature schema score the same batch.  The float
path binarizes K times per batch; `ModelRegistry.predict_multi`
quantizes once and scores K pools.

Scenario 4 (``run_layouts``) sweeps the physical model layouts
(`core.layout`: soa / depth_major / depth_grouped / bitpacked) over a
mixed-depth covertype-style ensemble — the shape `depth_grouped` exists
for: its shallow trees carry 2^d-entry leaf tables instead of 2^Dmax,
so both the leaf-index and leaf-gather passes do measurably less work.
`bitpacked` keeps the grouped tables but assembles leaf indexes on
integer registers (word-packed comparisons, uint8 split planes), so on
a uint8 pool it additionally skips the int32 promotion of the gathered
comparison panel; its row reports ``speedup_vs_depth_grouped`` plus the
u1 pool-plane shrink figures from ``describe()``.  Every layout is
parity-gated against the jnp reference.

Emits the same ``name,us_per_call,derived`` CSV rows as benchmarks.run,
and (unless ``--no-write``) one JSON per scenario into
``results/perf/`` — timestamp, scenario, layout and timing fields — so
future PRs can diff performance against this one.
With ``--check`` the process exits nonzero unless (a) the prepared path
is at least at parity with the *best* legacy row and (b) the
prequantized paths match the float paths exactly and (c) every lowered
layout matches the reference on the mixed-depth ensemble (the parity
gates for the plan, pool and layout APIs never regressing).

  PYTHONPATH=src python -m benchmarks.predictor_bench [--quick] [--check]
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import sys

import numpy as np

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[1] / "results" / "perf"


def eprint(*a):
    print(*a, file=sys.stderr, flush=True)


def _timed_paths(paths: dict, x, iters: int) -> dict[str, list[float]]:
    """Interleave the paths round-robin so machine drift (shared CI
    boxes) hits all of them equally; returns per-round times."""
    import time

    import jax

    times: dict[str, list[float]] = {name: [] for name in paths}
    for fn in paths.values():
        jax.block_until_ready(fn(x))            # warm compile caches
    for _ in range(iters):
        for name, fn in paths.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            times[name].append(time.perf_counter() - t0)
    return times


def run(n_trees: int, batch: int, iters: int) -> dict[str, float]:
    import functools

    import jax

    from benchmarks.serving_bench import _build_model
    from repro.core import predict
    from repro.core.predictor import PredictConfig, Predictor

    ens, ds = _build_model(n_trees)
    xs = np.asarray(ds.x_test, np.float32)
    while len(xs) < batch:
        xs = np.concatenate([xs, xs])
    import jax.numpy as jnp
    x = jnp.asarray(xs[:batch])

    kwarg = functools.partial(predict.raw_predict, ens,
                              strategy="staged", backend="ref")
    kwarg_jit = jax.jit(functools.partial(predict.raw_predict, ens,
                                          strategy="staged", backend="ref"))
    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"),
                           expected_batch=batch)
    paths = {"kwarg": kwarg, "kwarg-jit": kwarg_jit, "prepared": plan.raw}

    times = _timed_paths(paths, x, iters)
    out = {name: float(np.median(ts)) for name, ts in times.items()}
    # per-round ratio vs the jitted legacy path, for the parity gate:
    # pairing within a round cancels drift a sequential comparison keeps
    out["parity_ratio"] = float(np.median(
        [k / p for k, p in zip(times["kwarg-jit"], times["prepared"])]))
    # correctness guard: all three paths are the same math
    np.testing.assert_allclose(np.asarray(kwarg(x)),
                               np.asarray(plan.raw(x)),
                               rtol=1e-5, atol=1e-5)
    return out


def run_quantized(n_trees: int, batch: int, iters: int) -> dict[str, float]:
    """Prepared-float vs prequantized-pool scoring on one plan."""
    import jax.numpy as jnp

    from benchmarks.serving_bench import _build_model
    from repro.core.predictor import PredictConfig, Predictor

    ens, ds = _build_model(n_trees)
    xs = np.asarray(ds.x_test, np.float32)
    while len(xs) < batch:
        xs = np.concatenate([xs, xs])
    x = jnp.asarray(xs[:batch])

    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"),
                           expected_batch=batch)
    pool = plan.quantize(x)                     # quantize ONCE
    paths = {"prepared-float": plan.raw,
             "prequantized": lambda _x: plan.raw(pool)}
    times = _timed_paths(paths, x, iters)
    out = {name: float(np.median(ts)) for name, ts in times.items()}
    # parity gate: the pool path is the same math, binarize skipped
    err = float(np.max(np.abs(np.asarray(plan.raw(x))
                              - np.asarray(plan.raw(pool)))))
    out["max_abs_err"] = err
    return out


def run_registry(n_trees: int, batch: int, iters: int,
                 n_models: int) -> dict[str, float]:
    """Quantize-once / score-K-models over `ModelRegistry`.

    The K models are tree-slices of one ensemble, so they share the
    quantization schema by construction (the registry-serving pattern:
    model variants trained on one quantized dataset)."""
    from benchmarks.serving_bench import _build_model
    from repro.core.predictor import PredictConfig
    from repro.serving.engine import ModelRegistry

    ens, ds = _build_model(n_trees)
    xs = np.asarray(ds.x_test, np.float32)
    while len(xs) < batch:
        xs = np.concatenate([xs, xs])
    xs = xs[:batch]

    n_models = min(n_models, ens.n_trees)      # at most one tree per model
    per_model = ens.n_trees // n_models
    registry = ModelRegistry(max_batch=batch,
                             config=PredictConfig(strategy="staged",
                                                  backend="ref"))
    try:
        for i in range(n_models):
            lo = i * per_model
            registry.register(f"m{i}", ens.slice_trees(
                lo, min(lo + per_model, ens.n_trees)))
        names = registry.names()
        fkey, pkey = f"float-x{n_models}", f"pooled-x{n_models}"
        # jax.block_until_ready in _timed_paths works on the dict of
        # np arrays each path returns (np conversion already synced)
        paths = {fkey: lambda _: {n: registry.predict_batch(n, xs)
                                  for n in names},
                 pkey: lambda _: registry.predict_multi(xs, names)}
        times = _timed_paths(paths, None, iters)
        out = {k: float(np.median(v)) for k, v in times.items()}
        a, b = paths[fkey](None), paths[pkey](None)
        out["max_abs_err"] = max(
            float(np.max(np.abs(a[n] - b[n]))) for n in names)
        out["_keys"] = (fkey, pkey)
        return out
    finally:
        registry.close()


def _mixed_depth_variant(ens, cycle=(2, 3, 4, None)):
    """A covertype-style mixed-depth ensemble: tree t is truncated to
    depth cycle[t % len] (None = keep full depth) through the canonical
    `trees.truncate_tree_depths` (trailing always-left pads)."""
    from repro.core.trees import truncate_tree_depths

    depths = [ens.depth if cycle[t % len(cycle)] is None
              else min(cycle[t % len(cycle)], ens.depth)
              for t in range(ens.n_trees)]
    return truncate_tree_depths(ens, depths)


def run_layouts(n_trees: int, batch: int, iters: int) -> dict[str, dict]:
    """Physical-layout sweep on a mixed-depth ensemble.

    Scores a pre-quantized pool (the paper's evaluators never re-touch
    float features), so the timings isolate exactly the passes the
    layouts reorganize — leaf index + leaf gather — instead of being
    diluted by the layout-independent binarize.  Returns per-layout
    ``{us_per_call, max_abs_err, leaf_table_bytes, lower_time_s}`` —
    the parity + depth_grouped-wins evidence the lowering layer is
    gated on."""
    import jax.numpy as jnp

    from benchmarks.serving_bench import _build_model
    from repro.core.layout import LAYOUT_NAMES
    from repro.core.predictor import PredictConfig, Predictor
    from repro.kernels import ref

    ens, ds = _build_model(n_trees)
    ens = _mixed_depth_variant(ens)
    xs = np.asarray(ds.x_test, np.float32)
    while len(xs) < batch:
        xs = np.concatenate([xs, xs])
    x = jnp.asarray(xs[:batch])
    want = np.asarray(ens.base_score)[None, :] + np.asarray(
        ref.fused_predict(x, ens.borders, ens.split_features,
                          ens.split_bins, ens.leaf_values))

    plans = {name: Predictor.build(
        ens, PredictConfig(strategy="staged", backend="ref", layout=name),
        expected_batch=batch) for name in LAYOUT_NAMES}
    # one pool for all plans: identical borders -> identical fingerprint
    pool = next(iter(plans.values())).quantize(x)
    times = _timed_paths({n: (lambda _x, p=p: p.raw(pool))
                          for n, p in plans.items()}, x, iters)
    out: dict[str, dict] = {}
    for name, plan in plans.items():
        err = float(np.max(np.abs(np.asarray(plan.raw(pool)) - want)))
        out[name] = {
            "us_per_call": float(np.median(times[name])) * 1e6,
            "max_abs_err": err,
            "leaf_table_bytes": plan.lowered.leaf_table_bytes(),
            "lower_time_s": plan.stats["lower_time_s"],
        }
        if name == "bitpacked":
            desc = plan.lowered.describe()
            out[name].update(
                plane_bytes=desc["plane_bytes"],
                binary_split=desc["binary_split"],
                pool_row_bytes_u8=desc["pool_row_bytes_u8"],
                pool_row_bytes_u1=desc["pool_row_bytes_u1"],
                pool_shrink_x=desc["pool_shrink_x"])
    for name in out:
        out[name]["speedup_vs_depth_grouped"] = (
            out["depth_grouped"]["us_per_call"] / out[name]["us_per_call"])
    return out


def _write_scenario_json(out_dir: pathlib.Path, name: str, scenario: str,
                         layout: str, fields: dict) -> None:
    """One JSON per scenario under results/perf/ — the perf trajectory
    future PRs diff against (timestamp + scenario + layout + timings)."""
    out_dir.mkdir(parents=True, exist_ok=True)
    payload = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "scenario": scenario,
        "layout": layout,
        **fields,
    }
    (out_dir / f"{name}.json").write_text(json.dumps(payload, indent=1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the prepared path is below parity "
                         "with the best legacy path, or if a quantized "
                         "path diverges from its float path, or if a "
                         "lowered layout diverges from the reference")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--models", type=int, default=4,
                    help="K models sharing a schema in the registry "
                         "scenario")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR),
                    help="where the per-scenario result JSONs go")
    ap.add_argument("--no-write", action="store_true",
                    help="skip writing result JSONs")
    args = ap.parse_args()

    n_trees = 30 if args.quick else 100
    iters = 10 if args.quick else 30
    batch = min(args.batch, 64) if args.quick else args.batch
    n_models = max(2, min(args.models, 4) if args.quick else args.models)

    res = run(n_trees, batch, iters)
    qres = run_quantized(n_trees, batch, iters)
    rres = run_registry(n_trees, batch, iters, n_models)
    lres = run_layouts(n_trees, batch, iters)
    # parity gate on the median per-round prepared-vs-jitted-legacy
    # ratio; >= 0.66 (prepared within 1.5x) tolerates dispatch jitter on
    # loaded CI boxes while still catching a reintroduced per-call model
    # pad (that costs whole multiples, not fractions)
    parity = res["parity_ratio"] >= 0.66
    # the quantized paths are the same math: exact-ish parity, gated
    q_parity = (qres["max_abs_err"] < 1e-4
                and rres["max_abs_err"] < 1e-4)
    # every lowered layout is the same math as the logical model: soa
    # and depth_major must be BIT-identical to the reference on the ref
    # backend (integer-exact one-hot matmuls); depth_grouped and
    # bitpacked reassociate the tree sum (same addends, per-depth-group
    # order), hence float tolerance for those two — the bitpacked leaf
    # *indexes* stay integer-exact, pinned by tests/test_differential.py
    l_parity = (lres["soa"]["max_abs_err"] == 0.0
                and lres["depth_major"]["max_abs_err"] == 0.0
                and lres["depth_grouped"]["max_abs_err"] < 1e-4
                and lres["bitpacked"]["max_abs_err"] < 1e-4)

    eprint(f"# predictor bench: batch={batch}, {n_trees} trees, "
           f"{iters} interleaved rounds, ref backend")
    for name in ("kwarg", "kwarg-jit", "prepared"):
        eprint(f"{name:16s} {res[name] * 1e6:10.1f} us/call "
               f"({res['kwarg'] / res[name]:5.2f}x vs kwarg)")
    eprint(f"prepared vs jitted legacy (median per-round ratio): "
           f"{res['parity_ratio']:.2f}x "
           f"({'parity OK' if parity else 'BELOW PARITY'})")
    eprint(f"# quantize-once (single plan): binarize in-loop vs hoisted")
    for name in ("prepared-float", "prequantized"):
        eprint(f"{name:16s} {qres[name] * 1e6:10.1f} us/call "
               f"({qres['prepared-float'] / qres[name]:5.2f}x vs float)")
    fkey, pkey = rres.pop("_keys")
    eprint(f"# quantize-once / score-{n_models}-models (ModelRegistry)")
    for name in (fkey, pkey):
        eprint(f"{name:16s} {rres[name] * 1e6:10.1f} us/batch "
               f"({rres[fkey] / rres[name]:5.2f}x vs float)")
    eprint(f"quantized-path parity: max |err| = "
           f"{max(qres['max_abs_err'], rres['max_abs_err']):.2e} "
           f"({'OK' if q_parity else 'MISMATCH'})")
    eprint(f"# layout sweep (mixed-depth ensemble, staged/ref)")
    soa_us = lres["soa"]["us_per_call"]
    for name, v in lres.items():
        eprint(f"{name:16s} {v['us_per_call']:10.1f} us/call "
               f"({soa_us / v['us_per_call']:5.2f}x vs soa, "
               f"leaf table {v['leaf_table_bytes'] / 1024:.0f} KiB, "
               f"err {v['max_abs_err']:.1e})")
    eprint(f"layout parity: {'OK' if l_parity else 'MISMATCH'}; "
           f"depth_grouped vs soa: "
           f"{soa_us / lres['depth_grouped']['us_per_call']:.2f}x; "
           f"bitpacked vs depth_grouped: "
           f"{lres['bitpacked']['speedup_vs_depth_grouped']:.2f}x "
           f"(plane bytes {lres['bitpacked']['plane_bytes']}, "
           f"pool shrink {lres['bitpacked']['pool_shrink_x']:.1f}x)")

    print("name,us_per_call,derived")
    for name in ("kwarg", "kwarg-jit", "prepared"):
        print(f"predictor/{name},{res[name] * 1e6:.1f},"
              f"speedup_vs_kwarg={res['kwarg'] / res[name]:.2f}")
    for name in ("prepared-float", "prequantized"):
        print(f"predictor/{name},{qres[name] * 1e6:.1f},"
              f"speedup_vs_float={qres['prepared-float'] / qres[name]:.2f}")
    for name in (fkey, pkey):
        print(f"predictor/{name},{rres[name] * 1e6:.1f},"
              f"speedup_vs_float={rres[fkey] / rres[name]:.2f}")
    for name, v in lres.items():
        print(f"layout/{name},{v['us_per_call']:.1f},"
              f"speedup_vs_soa={soa_us / v['us_per_call']:.2f}")

    if not args.no_write:
        out_dir = pathlib.Path(args.out_dir)
        common = {"batch": batch, "n_trees": n_trees, "iters": iters,
                  "backend": "ref", "quick": bool(args.quick)}
        _write_scenario_json(
            out_dir, "predictor-bench__prepared", "prepared-plan", "auto",
            {**common, "us_per_call": res["prepared"] * 1e6,
             "speedup_vs_kwarg": res["kwarg"] / res["prepared"],
             "parity_ratio_vs_jitted_legacy": res["parity_ratio"]})
        _write_scenario_json(
            out_dir, "predictor-bench__prequantized", "prequantized",
            "auto",
            {**common, "us_per_call": qres["prequantized"] * 1e6,
             "speedup_vs_float": (qres["prepared-float"]
                                  / qres["prequantized"]),
             "max_abs_err": qres["max_abs_err"]})
        _write_scenario_json(
            out_dir, "predictor-bench__registry-multi", "registry-multi",
            "auto",
            {**common, "n_models": n_models,
             "us_per_batch": rres[pkey] * 1e6,
             "speedup_vs_float": rres[fkey] / rres[pkey],
             "max_abs_err": rres["max_abs_err"]})
        for name, v in lres.items():
            _write_scenario_json(
                out_dir, f"layout-sweep__{name}", "layout-sweep", name,
                {**common, **v,
                 "speedup_vs_soa": soa_us / v["us_per_call"]})
        eprint(f"# wrote result JSONs to {out_dir}")

    if args.check and not parity:
        eprint("FAIL: prepared plan slower than the kwarg path it replaces")
        return 1
    if args.check and not q_parity:
        eprint("FAIL: quantized path diverges from the float path")
        return 1
    if args.check and not l_parity:
        eprint("FAIL: a lowered layout diverges from the reference")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
