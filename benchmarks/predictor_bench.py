"""Prepared-plan vs per-call-padding predictor benchmark.

Measures the cost the compiled-plan API hoists out of the hot loop: the
legacy kwarg path (`core.predict.raw_predict`) re-resolves the backend,
re-runs the block tuner and re-pads the model arrays on every call,
while `Predictor.build` does all of that once and then dispatches
through a shape-cached jitted entry.

Three rows (ref backend, so kernel math is identical and the delta is
pure per-call preparation + dispatch):

  kwarg       eager legacy path, per-call preparation
  kwarg-jit   legacy path under a caller-side jax.jit (the old
              "fast" pattern every call site had to hand-roll)
  prepared    Predictor built once, plan.raw per call

Emits the same ``name,us_per_call,derived`` CSV rows as benchmarks.run.
With ``--check`` the process exits nonzero unless the prepared path is
at least at parity with the *best* legacy row — the CI gate for the
plan API never regressing below the kwarg path it replaced.

  PYTHONPATH=src python -m benchmarks.predictor_bench [--quick] [--check]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def eprint(*a):
    print(*a, file=sys.stderr, flush=True)


def run(n_trees: int, batch: int, iters: int) -> dict[str, float]:
    import functools
    import time

    import jax
    import jax.numpy as jnp

    from benchmarks.serving_bench import _build_model
    from repro.core import predict
    from repro.core.predictor import PredictConfig, Predictor

    ens, ds = _build_model(n_trees)
    xs = np.asarray(ds.x_test, np.float32)
    while len(xs) < batch:
        xs = np.concatenate([xs, xs])
    x = jnp.asarray(xs[:batch])

    kwarg = functools.partial(predict.raw_predict, ens,
                              strategy="staged", backend="ref")
    kwarg_jit = jax.jit(functools.partial(predict.raw_predict, ens,
                                          strategy="staged", backend="ref"))
    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"),
                           expected_batch=batch)
    paths = {"kwarg": kwarg, "kwarg-jit": kwarg_jit, "prepared": plan.raw}

    # Interleave the paths round-robin so machine drift (shared CI
    # boxes) hits all of them equally; per-path medians over rounds.
    times: dict[str, list[float]] = {name: [] for name in paths}
    for fn in paths.values():
        jax.block_until_ready(fn(x))            # warm compile caches
    for _ in range(iters):
        for name, fn in paths.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            times[name].append(time.perf_counter() - t0)
    out = {name: float(np.median(ts)) for name, ts in times.items()}
    # per-round ratio vs the jitted legacy path, for the parity gate:
    # pairing within a round cancels drift a sequential comparison keeps
    out["parity_ratio"] = float(np.median(
        [k / p for k, p in zip(times["kwarg-jit"], times["prepared"])]))
    # correctness guard: all three paths are the same math
    np.testing.assert_allclose(np.asarray(kwarg(x)),
                               np.asarray(plan.raw(x)),
                               rtol=1e-5, atol=1e-5)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if prepared path is below parity with "
                         "the best legacy path")
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    n_trees = 30 if args.quick else 100
    iters = 10 if args.quick else 30
    batch = min(args.batch, 64) if args.quick else args.batch

    res = run(n_trees, batch, iters)
    # parity gate on the median per-round prepared-vs-jitted-legacy
    # ratio; >= 0.66 (prepared within 1.5x) tolerates dispatch jitter on
    # loaded CI boxes while still catching a reintroduced per-call model
    # pad (that costs whole multiples, not fractions)
    parity = res["parity_ratio"] >= 0.66

    eprint(f"# predictor bench: batch={batch}, {n_trees} trees, "
           f"{iters} interleaved rounds, ref backend")
    for name in ("kwarg", "kwarg-jit", "prepared"):
        eprint(f"{name:10s} {res[name] * 1e6:10.1f} us/call "
               f"({res['kwarg'] / res[name]:5.2f}x vs kwarg)")
    eprint(f"prepared vs jitted legacy (median per-round ratio): "
           f"{res['parity_ratio']:.2f}x "
           f"({'parity OK' if parity else 'BELOW PARITY'})")

    print("name,us_per_call,derived")
    for name in ("kwarg", "kwarg-jit", "prepared"):
        print(f"predictor/{name},{res[name] * 1e6:.1f},"
              f"speedup_vs_kwarg={res['kwarg'] / res[name]:.2f}")

    if args.check and not parity:
        eprint("FAIL: prepared plan slower than the kwarg path it replaces")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
