"""Benchmark harness entrypoint — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable tables
to stderr) and appends the GBDT kernel roofline estimates for the TPU
target.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys


def eprint(*a):
    print(*a, file=sys.stderr, flush=True)


def gbdt_kernel_roofline() -> list[str]:
    """Derived TPU-v5e roofline for the Pallas GBDT kernels (table D):
    arithmetic intensity and the bound each kernel hits.

    Workload: N=100k samples, F=200 features, B=255 borders, T=1000
    trees, depth 6, C=1 — Santander-scale batch prediction.
    """
    PEAK, HBM = 197e12, 819e9
    N, F, B, T, D, C = 100_000, 200, 255, 1000, 6, 1
    L = 2 ** D
    rows = []

    def row(name, flops, bytes_, note):
        ai = flops / bytes_
        t_c, t_m = flops / PEAK, bytes_ / HBM
        bound = "compute" if t_c > t_m else "memory"
        t = max(t_c, t_m)
        rows.append(f"roofline/{name},{t*1e6:.2f},"
                    f"AI={ai:.2f};bound={bound};{note}")
        return t

    # binarize: N*F*B compares; reads x (N*F*4) + borders, writes bins
    row("binarize", N * F * B, (N * F * 4) * 2 + B * F * 4,
        "VPU compare-accumulate")
    # leaf_index: one-hot gather matmul (T*D x F) @ (F x N) + mask ops
    row("leaf_index", 2 * T * D * F * N, N * F * 4 + N * T * 4 + T * D * 8,
        "MXU one-hot gather")
    # leaf_gather: onehot (N x T*L) @ (T*L x C)
    row("leaf_gather", 2 * N * T * L * C, N * T * 4 + T * L * C * 4 + N * C * 4,
        "MXU onehot-matmul (paper left scalar)")
    # fused predict: same flops, bins/idx never hit HBM
    row("fused_predict", N * F * B + 2 * T * D * F * N + 2 * N * T * L * C,
        N * F * 4 + B * F * 4 + T * (D * 8 + L * C * 4) + N * C * 4,
        "fused: no bins/idx HBM roundtrip")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI)")
    ap.add_argument("--tables", default="2,3,4,5,6")
    args, _ = ap.parse_known_args()

    from benchmarks import tables as T

    wanted = set(args.tables.split(","))
    csv_rows: list[str] = []
    jobs = []
    if "2" in wanted:
        jobs.append(lambda: T.table2_yearpred(
            n_samples=300 if args.quick else 1000,
            n_trees=100 if args.quick else 500))
    if "3" in wanted:
        jobs.append(lambda: T.table3_covertype(
            n_samples=300 if args.quick else 1000,
            n_trees=60 if args.quick else 300))
    if "4" in wanted:
        jobs.append(lambda: T.table4_embeddings(
            n_queries=100 if args.quick else 200,
            n_trees=50 if args.quick else 200))
    if "5" in wanted:
        jobs.append(lambda: T.table5_full(scale=0.005 if args.quick
                                          else 0.02))
    if "6" in wanted:
        jobs.append(lambda: T.table6_batch_scaling(
            n_trees=60 if args.quick else 300))

    for job in jobs:
        tbl = job()
        for line in tbl.emit():
            eprint(line)
        eprint("")
        csv_rows.extend(tbl.csv_rows())

    csv_rows.extend(gbdt_kernel_roofline())
    print("name,us_per_call,derived")
    for r in csv_rows:
        print(r)


if __name__ == "__main__":
    main()
