"""Scalar-loop implementations of the paper's hotspots — the "Baseline"
column of Tables 2-4.

The paper's baseline is CatBoost's scalar C++ compiled for RISC-V without
vectorization; the optimized version is the RVV-intrinsic rewrite.  The
CPU analog here: nested `lax.fori_loop`s with per-element dynamic updates
(XLA cannot vectorize across the loop-carried scatter), versus the
vectorized jnp/Pallas formulations in repro.kernels.  Both run through
XLA on the same machine, so the ratio isolates vectorization — the same
quantity the paper reports.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.jit
def binarize_scalar(x: jax.Array, borders: jax.Array) -> jax.Array:
    """Per-sample, per-feature, per-border scalar compare-accumulate."""
    N, F = x.shape
    B = borders.shape[0]

    def sample(i, out):
        def feat(j, out):
            def bor(b, acc):
                return acc + jnp.where(x[i, j] > borders[b, j], 1, 0)
            v = jax.lax.fori_loop(0, B, bor, jnp.int32(0))
            return out.at[i, j].set(v)
        return jax.lax.fori_loop(0, F, feat, out)

    return jax.lax.fori_loop(0, N, sample, jnp.zeros((N, F), jnp.int32))


@jax.jit
def leaf_index_scalar(bins: jax.Array, sf: jax.Array, sb: jax.Array
                      ) -> jax.Array:
    """CalcIndexesBasic baseline: scalar bit accumulation per (n, t)."""
    N = bins.shape[0]
    T, D = sf.shape

    def sample(n, out):
        def tree(t, out):
            def depth(d, idx):
                go = jnp.where(bins[n, sf[t, d]] >= sb[t, d], 1, 0)
                return idx | (go << d)
            idx = jax.lax.fori_loop(0, D, depth, jnp.int32(0))
            return out.at[n, t].set(idx)
        return jax.lax.fori_loop(0, T, tree, out)

    return jax.lax.fori_loop(0, N, sample, jnp.zeros((N, T), jnp.int32))


@jax.jit
def leaf_gather_scalar(idx: jax.Array, lv: jax.Array) -> jax.Array:
    """CalculateLeafValues baseline: scalar gather-accumulate."""
    N, T = idx.shape
    C = lv.shape[2]

    def sample(n, out):
        def tree(t, acc):
            return acc + lv[t, idx[n, t], :]
        acc = jax.lax.fori_loop(0, T, tree, jnp.zeros((C,), jnp.float32))
        return out.at[n].set(acc)

    return jax.lax.fori_loop(0, N, sample, jnp.zeros((N, C), jnp.float32))


@jax.jit
def l2sq_scalar(q: jax.Array, refs: jax.Array) -> jax.Array:
    """L2SqrDistance baseline: scalar FMA loop per reference row."""
    M, K = refs.shape

    def row(m, out):
        def dim(k, acc):
            d = refs[m, k] - q[k]
            return acc + d * d
        return out.at[m].set(jax.lax.fori_loop(0, K, dim, jnp.float32(0)))

    return jax.lax.fori_loop(0, M, row, jnp.zeros((M,), jnp.float32))


def predict_scalar(x, borders, sf, sb, lv):
    """End-to-end scalar prediction (baseline CalcTreesBlockedImpl path)."""
    bins = binarize_scalar(x, borders)
    idx = leaf_index_scalar(bins, sf, sb)
    return leaf_gather_scalar(idx, lv)
