"""Benchmark utilities: timing, table formatting, CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> float:
    """Median wall seconds of a jax function (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Table:
    """Paper-style profiling table: function, time, % total, speedup."""

    def __init__(self, title: str):
        self.title = title
        self.rows: list[tuple] = []

    def add(self, func: str, calls: int, base_s: float, opt_s: float):
        self.rows.append((func, calls, base_s, opt_s))

    def emit(self) -> list[str]:
        base_total = sum(r[2] for r in self.rows)
        opt_total = sum(r[3] for r in self.rows)
        lines = [f"# {self.title}",
                 f"{'function':28s} {'calls':>6s} {'base_s':>10s} "
                 f"{'%base':>7s} {'opt_s':>10s} {'%opt':>7s} {'speedup':>8s}"]
        for func, calls, b, o in self.rows:
            lines.append(
                f"{func:28s} {calls:6d} {b:10.4f} "
                f"{100*b/max(base_total,1e-12):6.1f}% {o:10.4f} "
                f"{100*o/max(opt_total,1e-12):6.1f}% {b/max(o,1e-12):8.2f}")
        lines.append(f"{'TOTAL':28s} {'':6s} {base_total:10.4f} "
                     f"{'':7s} {opt_total:10.4f} {'':7s} "
                     f"{base_total/max(opt_total,1e-12):8.2f}")
        return lines

    def csv_rows(self) -> list[str]:
        out = []
        for func, calls, b, o in self.rows:
            us = o * 1e6
            out.append(f"{self.title}/{func},{us:.1f},"
                       f"speedup={b/max(o,1e-12):.2f}")
        return out
