"""Bulk dataset-apply benchmark: naive predict_batch loop vs BulkScorer.

The paper's headline numbers are whole-dataset model application
(`ApplyModelMulti` over millions of rows).  Before this subsystem, the
only way to score a dataset bigger than one batch was to loop
`GBDTServer.predict_batch` — which chunks at the online path's largest
*bucket* (hundreds of rows), paying a dispatch + in-jit binarize +
unpad round-trip per tiny chunk.  The scenarios:

  naive          GBDTServer.predict_batch over the full matrix — the
                 predict_batch Python loop (bucket-sized chunks,
                 binarize inside every jitted call, host sync per
                 chunk)
  bulk           BulkScorer, float chunks (binarize still in-jit, but
                 planner-sized chunks, prefetch and lag-1 sync)
  bulk-prequant  BulkScorer, prequantized pipeline: the prefetch
                 worker binarizes chunk k+1 into a uint8 pool while
                 chunk k scores — binarize leaves the critical path
                 and the score entries run the u8 kernels

All three run the same plan configuration (staged/ref — the measured
backend on CPU containers), so outputs must match bit-for-bit, and the
chunk-shape contract (<= 2 padded shapes per bulk run) is asserted.
Rows come from a >= 100k-row synthetic covertype sweep
(`SyntheticSource(repeat=...)` — out-of-core row counts at
base-dataset memory).  Scenarios are timed in interleaved rounds (the
`predictor_bench` trick) so shared-box drift hits all of them equally;
the reported rows/s is the per-scenario median across rounds.

Emits ``name,us_per_call,derived`` CSV rows like the sibling benches,
and (unless ``--no-write``) one JSON per scenario into
``results/perf/`` — the established perf-trajectory schema.  With
``--check`` the process exits nonzero unless outputs match exactly,
each bulk run stayed <= 2 shapes, and the best BulkScorer beats the
naive loop (>= 2x full runs / >= 1.2x --quick, where the model is tiny
and CI boxes noisy).

  PYTHONPATH=src python -m benchmarks.scoring_bench [--quick] [--check]
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import sys
import time

import numpy as np

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[1] / "results" / "perf"


def eprint(*a):
    print(*a, file=sys.stderr, flush=True)


class _Window:
    """First-n-rows view of a source (warmup runs)."""

    def __init__(self, source, n):
        self._s, self.n_rows = source, n
        self.n_features = source.n_features

    def read(self, start, stop):
        return self._s.read(start, stop)


class NaiveRunner:
    """The pre-subsystem path: predict_batch in a Python loop."""

    label = "naive"

    def __init__(self, ens, source, max_batch: int):
        from repro.core.predictor import PredictConfig
        from repro.serving.engine import GBDTServer

        self.source = source
        self.step = max_batch * 16       # a realistic caller's read size
        self.server = GBDTServer(
            ens, config=PredictConfig(strategy="staged", backend="ref"),
            max_batch=max_batch, name="naive-bulk")
        # warm the compile caches over the full-bucket shape AND the
        # run's remainder shapes (steady state is the claim everywhere)
        warm = min(source.n_rows, max_batch + source.n_rows % max_batch)
        self.server.predict_batch(source.read(0, warm))

    def run(self) -> tuple[float, np.ndarray]:
        src = self.source
        c = self.server.predictor.ensemble.n_outputs
        out = np.zeros((src.n_rows, 2 if c == 1 else c), np.float32)
        t0 = time.perf_counter()
        for s in range(0, src.n_rows, self.step):
            stop = min(s + self.step, src.n_rows)
            out[s:stop] = self.server.predict_batch(src.read(s, stop))
        return time.perf_counter() - t0, out

    def stats(self) -> dict:
        return {"recompiles": self.server.metrics.snapshot()["recompiles"]}

    def close(self):
        self.server.close()


class BulkRunner:
    label = "bulk"

    def __init__(self, ens, source, chunk_rows: int, *,
                 prequantize: bool, label: str):
        from repro.core.predictor import PredictConfig, Predictor
        from repro.scoring import ArraySink, BulkScorer, ScoreConfig

        self.label = label
        self.source = source
        self._sink_cls = ArraySink
        self.scorer = BulkScorer(
            Predictor.build(ens, PredictConfig(strategy="staged",
                                               backend="ref")),
            ScoreConfig(chunk_rows=chunk_rows, output="proba",
                        prequantize=prequantize))
        # warmup covering the full-chunk shape and the real run's tail
        # bucket, so the timed rounds see zero compiles
        chunk = self.scorer.resolve_chunk_rows(source.n_rows)
        warm = min(source.n_rows, chunk + source.n_rows % chunk)
        self.scorer.score(_Window(source, warm), ArraySink())
        self.last = None

    def run(self) -> tuple[float, np.ndarray]:
        res = self.scorer.score(self.source, self._sink_cls())
        self.last = res
        return res.metrics["wall_s"], res.output

    def stats(self) -> dict:
        m = self.last.metrics
        return {"chunk_rows": self.last.chunk_rows,
                "chunk_shapes": list(self.last.chunk_shapes),
                "chunks": m["chunks"], "compiles": m["compiles"],
                "quantize_frac": m["quantize_frac"],
                "pad_overhead": m["pad_overhead"]}

    def close(self):
        pass


def _write_scenario_json(out_dir: pathlib.Path, name: str, scenario: str,
                         fields: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    payload = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "scenario": scenario,
        "layout": "auto",
        **fields,
    }
    (out_dir / f"{name}.json").write_text(json.dumps(payload, indent=1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless outputs match exactly, bulk "
                         "runs stayed <= 2 shapes, and BulkScorer "
                         "beats the naive loop")
    ap.add_argument("--chunk", type=int, default=0,
                    help="fixed chunk rows (0 = the tuning planner's "
                         "working-set-budgeted choice)")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="the naive server's online max_batch (its "
                         "bulk path chunks at the top bucket)")
    ap.add_argument("--rounds", type=int, default=0,
                    help="interleaved timing rounds per scenario "
                         "(0 = 2 quick / 3 full)")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args()

    n_trees = 30 if args.quick else 100
    # full run: scale 0.05 "all" = ~23k base rows x5 = ~116k-row sweep
    scale = 0.02 if args.quick else 0.05
    repeat = 2 if args.quick else 5
    rounds = args.rounds or (2 if args.quick else 3)

    from benchmarks.serving_bench import _build_model
    from repro.scoring import SyntheticSource

    ens, _ = _build_model(n_trees)
    source = SyntheticSource("covertype", scale=scale, split="all",
                             repeat=repeat)
    rows = source.n_rows
    eprint(f"# scoring bench: {rows} rows x {source.n_features} features "
           f"(base {source.base_rows} x repeat {repeat}), {n_trees} "
           f"trees, chunk={'auto' if not args.chunk else args.chunk}, "
           f"naive max_batch={args.max_batch}, {rounds} interleaved "
           f"rounds, ref backend")

    runners = [
        NaiveRunner(ens, source, args.max_batch),
        BulkRunner(ens, source, args.chunk, prequantize=False,
                   label="bulk"),
        BulkRunner(ens, source, args.chunk, prequantize=True,
                   label="bulk-prequant"),
    ]
    try:
        walls: dict[str, list[float]] = {r.label: [] for r in runners}
        scores: dict[str, np.ndarray] = {}
        for _ in range(rounds):
            for r in runners:
                wall, out = r.run()
                walls[r.label].append(wall)
                scores[r.label] = out
        med = {label: float(np.median(w)) for label, w in walls.items()}
        stats = {r.label: r.stats() for r in runners}
    finally:
        for r in runners:
            r.close()

    err = {label: float(np.max(np.abs(scores["naive"] - scores[label])))
           for label in ("bulk", "bulk-prequant")}
    rps = {label: rows / w for label, w in med.items()}

    eprint(f"{'scenario':16s} {'rows/s':>10s} {'wall_s':>8s} "
           f"{'vs naive':>9s} {'shapes':>7s} {'err':>9s}")
    eprint(f"{'naive':16s} {rps['naive']:10.0f} {med['naive']:8.2f} "
           f"{'1.00x':>9s} {'-':>7s} {'-':>9s}")
    for label in ("bulk", "bulk-prequant"):
        eprint(f"{label:16s} {rps[label]:10.0f} {med[label]:8.2f} "
               f"{rps[label] / rps['naive']:8.2f}x "
               f"{len(stats[label]['chunk_shapes']):7d} "
               f"{err[label]:9.1e}")
    eprint(f"chunk={stats['bulk']['chunk_rows']} rows; bulk-prequant "
           f"quantize share of busy time: "
           f"{stats['bulk-prequant']['quantize_frac']:.0%} (overlapped "
           f"on the prefetch worker)")

    print("name,us_per_call,derived")
    print(f"scoring/naive,{med['naive'] / rows * 1e6:.2f},"
          f"rows_per_s={rps['naive']:.0f}")
    for label in ("bulk", "bulk-prequant"):
        print(f"scoring/{label},{med[label] / rows * 1e6:.2f},"
              f"rows_per_s={rps[label]:.0f};speedup_vs_naive="
              f"{rps[label] / rps['naive']:.2f};"
              f"max_abs_err={err[label]:.1e}")

    if not args.no_write:
        out_dir = pathlib.Path(args.out_dir)
        common = {"rows": rows, "n_trees": n_trees,
                  "chunk": stats["bulk"]["chunk_rows"],
                  "rounds": rounds, "backend": "ref",
                  "quick": bool(args.quick)}
        _write_scenario_json(
            out_dir, "scoring-bench__naive", "scoring-naive",
            {**common, "rows_per_s": rps["naive"],
             "wall_s": med["naive"], "max_batch": args.max_batch})
        for label in ("bulk", "bulk-prequant"):
            _write_scenario_json(
                out_dir, f"scoring-bench__{label}", f"scoring-{label}",
                {**common, "rows_per_s": rps[label],
                 "wall_s": med[label],
                 "speedup_vs_naive": rps[label] / rps["naive"],
                 "max_abs_err": err[label], **stats[label]})
        eprint(f"# wrote result JSONs to {out_dir}")

    if args.check:
        if err["bulk"] != 0.0 or err["bulk-prequant"] != 0.0:
            eprint("FAIL: bulk output diverges from the naive "
                   "predict_batch loop (same plan, same math)")
            return 1
        for label in ("bulk", "bulk-prequant"):
            shapes = stats[label]["chunk_shapes"]
            if len(shapes) > 2:
                eprint(f"FAIL: {label} compiled {len(shapes)} chunk "
                       f"shapes ({shapes}); the planner contract is "
                       "<= 2")
                return 1
        best = max(rps["bulk"], rps["bulk-prequant"])
        floor = 1.2 if args.quick else 2.0
        if best < floor * rps["naive"]:
            eprint(f"FAIL: best BulkScorer {best:.0f} rows/s is below "
                   f"{floor}x the naive loop ({rps['naive']:.0f} "
                   f"rows/s)")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
