"""Paper table reproductions (Tables 2-5).

Structure mirrors the paper's experiment design:
  * Tables 2-4: serial-mode per-hotspot profile on 1000 samples for
    YearPredictionMSD (regression), Covertype (multiclass) and
    image-embeddings (KNN features + multiclass), baseline scalar vs
    vectorized, with per-function time / % total / speedup.
  * Table 5: end-to-end batched prediction on the full (synthetic)
    datasets with accuracy parity between baseline and optimized paths.

The "Baseline" column is the scalar-loop analog (benchmarks/
scalar_baseline.py); "Optimized" is the vectorized pipeline from
repro.kernels (ref backend on CPU — the same math the Pallas TPU kernels
execute, which interpret-mode tests pin to the oracle).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import scalar_baseline as sb
from benchmarks.common import Table, time_fn
from repro.core import boosting, knn, losses, quantize
from repro.core.boosting import BoostingParams
from repro.core.predictor import PredictConfig, Predictor
from repro.data import synthetic
from repro.kernels import ops, ref


def _train_model(ds, n_trees, max_bins=64):
    loss = losses.make_loss(ds.loss, n_classes=max(ds.n_classes, 2),
                            group_index=ds.group_index_train)
    params = BoostingParams(
        n_trees=n_trees, depth=ds.params.depth,
        learning_rate=ds.params.learning_rate, max_bins=max_bins)
    ens, hist = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                             params=params)
    return ens, loss, hist


def _profile_hotspots(title, ens, x_eval) -> Table:
    """Per-hotspot serial profile, paper Tables 2-4 layout."""
    x = jnp.asarray(x_eval)
    borders = ens.borders
    sf, sbins, lv = ens.split_features, ens.split_bins, ens.leaf_values

    t = Table(title)
    bins = ops.binarize(x, borders, backend="ref")
    idx = ops.leaf_index(bins, sf, sbins, backend="ref")

    # optimized paths are jitted whole (the paper's optimized build is
    # compiled too); baselines are the jitted scalar loops.
    jbin = jax.jit(lambda a, b: ref.binarize(a, b))
    base_bin = time_fn(sb.binarize_scalar, x, borders)
    opt_bin = time_fn(jbin, x, borders)
    t.add("BinarizeFloatsNonSse", 1, base_bin, opt_bin)

    jidx = jax.jit(lambda b, f, c: ref.leaf_index(b, f, c))
    base_idx = time_fn(sb.leaf_index_scalar, bins, sf, sbins)
    opt_idx = time_fn(jidx, bins, sf, sbins)
    t.add("CalcIndexesBasic", 1, base_idx, opt_idx)

    name = ("CalculateLeafValuesMulti" if lv.shape[2] > 1
            else "CalculateLeafValues")
    jlv = jax.jit(lambda i, l: ref.leaf_gather(i, l))
    base_lv = time_fn(sb.leaf_gather_scalar, idx, lv)
    opt_lv = time_fn(jlv, idx, lv)
    t.add(name, 1, base_lv, opt_lv)
    return t


def table2_yearpred(n_samples=1000, n_trees=500) -> Table:
    ds = synthetic.load("year_prediction_msd", scale=0.02)
    ens, _, _ = _train_model(ds, n_trees)
    return _profile_hotspots("table2_YearPredictionMSD", ens,
                             ds.x_test[:n_samples])


def table3_covertype(n_samples=1000, n_trees=300) -> Table:
    ds = synthetic.load("covertype", scale=0.01)
    ens, _, _ = _train_model(ds, n_trees)
    return _profile_hotspots("table3_Covertype", ens,
                             ds.x_test[:n_samples])


def table4_embeddings(n_queries=200, n_trees=200) -> Table:
    """image-embeddings: L2SqrDistance dominates (91.6% baseline time)."""
    ds = synthetic.load("image_embeddings", scale=0.5)
    feat = knn.KNNFeaturizer(jnp.asarray(ds.emb_train),
                             jnp.asarray(ds.y_train), ds.n_classes, k=16)
    t = Table("table4_image_embeddings")

    q = jnp.asarray(ds.emb_test[:n_queries])
    refs = jnp.asarray(ds.emb_train)
    jl2 = jax.jit(lambda a, b: ref.l2sq_matrix(a, b))
    base_l2 = time_fn(
        lambda: [sb.l2sq_scalar(q[i], refs) for i in range(16)])
    opt_l2 = time_fn(jl2, q[:16], refs)
    t.add("L2SqrDistance(x16 queries)", 16, base_l2, opt_l2)

    x_tr = knn.augment_with_knn(ds.x_train, ds.emb_train, feat)
    ds2 = synthetic.Dataset("aug", x_tr, ds.y_train, x_tr, ds.y_train,
                            loss="multiclass", n_classes=20,
                            params=ds.params)
    ens, _, _ = _train_model(ds2, n_trees)
    prof = _profile_hotspots("", ens, x_tr[:n_queries])
    for row in prof.rows:
        t.rows.append(row)
    return t


def table5_full(scale=0.02) -> Table:
    """End-to-end batched prediction + accuracy parity (paper Table 5)."""
    t = Table("table5_full_datasets")
    for name, n_trees in [("santander", 200), ("covertype", 200),
                          ("year_prediction_msd", 300), ("mq2008", 200),
                          ("image_embeddings", 100)]:
        ds = synthetic.load(name, scale=scale if name not in
                            ("mq2008", "image_embeddings") else 0.5)
        x_te = ds.x_test
        if name == "image_embeddings":
            feat = knn.KNNFeaturizer(jnp.asarray(ds.emb_train),
                                     jnp.asarray(ds.y_train),
                                     ds.n_classes, k=16)
            x_tr = knn.augment_with_knn(ds.x_train, ds.emb_train, feat)
            x_te = knn.augment_with_knn(ds.x_test, ds.emb_test, feat)
            ds = synthetic.Dataset("aug", x_tr, ds.y_train, x_te,
                                   ds.y_test, loss="multiclass",
                                   n_classes=20, params=ds.params)
        ens, loss, _ = _train_model(ds, n_trees)
        xj = jnp.asarray(ds.x_test if name != "image_embeddings" else x_te)

        plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                                  backend="ref"))
        base_s = time_fn(
            lambda: sb.predict_scalar(xj[:512], ens.borders,
                                      ens.split_features, ens.split_bins,
                                      ens.leaf_values), iters=1)
        opt_s = time_fn(plan.raw, xj[:512])
        # accuracy parity: baseline scalar vs optimized must agree exactly
        raw_b = np.asarray(sb.predict_scalar(
            xj[:512], ens.borders, ens.split_features, ens.split_bins,
            ens.leaf_values))
        raw_o = np.asarray(plan.raw(xj[:512])
                           - ens.base_score[None, :])
        parity = np.max(np.abs(raw_b - raw_o))
        assert parity < 1e-4, f"{name}: baseline/optimized diverge {parity}"
        t.add(f"{name}(512rows,{n_trees}t)", 1, base_s, opt_s)
    return t


def table6_batch_scaling(n_trees=300) -> Table:
    """Beyond-paper: vectorization gain vs batch size.

    The paper's limitation section notes the speedup exists only for
    batched prediction; this quantifies it — scalar cost is O(batch)
    while the vectorized path amortizes, so the ratio grows with batch.
    """
    ds = synthetic.load("year_prediction_msd", scale=0.01)
    ens, _, _ = _train_model(ds, n_trees)
    t = Table("table6_batch_scaling")
    xj = jnp.asarray(ds.x_test)
    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"))
    for bs in (1, 8, 64, 512):
        base = time_fn(lambda: sb.predict_scalar(
            xj[:bs], ens.borders, ens.split_features, ens.split_bins,
            ens.leaf_values), iters=2)
        opt = time_fn(plan.raw, xj[:bs], iters=3)
        t.add(f"batch_{bs}", 1, base, opt)
    return t


ALL_TABLES = [table2_yearpred, table3_covertype, table4_embeddings,
              table5_full, table6_batch_scaling]
