"""Serving throughput benchmark: requests/s and p50/p99 latency vs.
batch-bucket configuration.

Drives a `GBDTServer` with a realistic ragged request-size stream (sizes
drawn log-uniform in [1, max_batch]) through the synchronous bucketed
path, for several bucket ladders:

  exact      one bucket per distinct size — the seed behaviour: every new
             size is a fresh XLA trace (unbounded recompilation)
  pow2       power-of-two ladder (the production default)
  coarse     two buckets (min, max) — maximum padding, minimum compiles
  single     one max-size bucket

Emits the same ``name,us_per_call,derived`` CSV rows as benchmarks.run.

  PYTHONPATH=src python -m benchmarks.serving_bench [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def eprint(*a):
    print(*a, file=sys.stderr, flush=True)


def _build_model(n_trees: int):
    from repro.core import boosting, losses
    from repro.core.boosting import BoostingParams
    from repro.data import synthetic

    ds = synthetic.load("covertype", scale=0.003)
    loss = losses.make_loss("multiclass", n_classes=7)
    ens, _ = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                          params=BoostingParams(n_trees=n_trees, depth=5,
                                                learning_rate=0.3))
    return ens, ds


def _request_sizes(n_batches: int, max_batch: int,
                   seed: int = 0) -> list[int]:
    rng = np.random.default_rng(seed)
    # log-uniform: mostly small interactive batches, occasional bulk ones
    return [int(np.clip(np.exp(rng.uniform(0, np.log(max_batch))), 1,
                        max_batch)) for _ in range(n_batches)]


def bench_config(label: str, ens, xs: np.ndarray, sizes: list[int],
                 buckets, max_batch: int) -> dict:
    from repro.core.predictor import PredictConfig
    from repro.serving.engine import GBDTServer

    server = GBDTServer(ens,
                        config=PredictConfig(strategy="staged",
                                             backend="ref"),
                        max_batch=max_batch, buckets=buckets,
                        name=label)
    lat = []
    try:
        # warm the compile caches so steady-state latency is measured,
        # then time each batch individually
        server.predict_batch(xs[:max_batch])
        t_start = time.perf_counter()
        for n in sizes:
            t0 = time.perf_counter()
            server.predict_batch(xs[:n])
            lat.append(time.perf_counter() - t0)
        wall = time.perf_counter() - t_start
        snap = server.metrics.snapshot()
    finally:
        server.close()
    lat_ms = np.asarray(lat) * 1e3
    n_req = int(np.sum(sizes))
    return {
        "label": label,
        "buckets": len(server.buckets),
        "recompiles": snap["recompiles"],
        "req_s": n_req / wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "pad_overhead": snap["pad_overhead"],
        "us_per_req": wall / n_req * 1e6,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--max-batch", type=int, default=256)
    args = ap.parse_args()

    n_trees = 30 if args.quick else 100
    n_batches = 40 if args.quick else 150
    max_batch = min(args.max_batch, 64) if args.quick else args.max_batch

    ens, ds = _build_model(n_trees)
    xs = np.asarray(ds.x_test, np.float32)
    while len(xs) < max_batch:
        xs = np.concatenate([xs, xs])
    sizes = _request_sizes(n_batches, max_batch)

    from repro.serving.batching import pow2_buckets
    configs = [
        ("exact", tuple(sorted(set(sizes) | {max_batch}))),
        ("pow2", pow2_buckets(max_batch)),
        ("coarse", (pow2_buckets(max_batch)[0], pow2_buckets(max_batch)[-1])),
        ("single", (pow2_buckets(max_batch)[-1],)),
    ]

    eprint(f"# serving bench: {n_batches} ragged batches, "
           f"{int(np.sum(sizes))} requests, max_batch={max_batch}, "
           f"{n_trees} trees")
    eprint(f"{'config':10s} {'buckets':>7s} {'recomp':>7s} {'req/s':>9s} "
           f"{'p50ms':>7s} {'p99ms':>7s} {'pad%':>6s}")
    rows = []
    for label, buckets in configs:
        r = bench_config(label, ens, xs, sizes, buckets, max_batch)
        eprint(f"{r['label']:10s} {r['buckets']:7d} {r['recompiles']:7d} "
               f"{r['req_s']:9.0f} {r['p50_ms']:7.2f} {r['p99_ms']:7.2f} "
               f"{100 * r['pad_overhead']:6.1f}")
        rows.append(f"serving/{r['label']},{r['us_per_req']:.1f},"
                    f"req_s={r['req_s']:.0f};p50_ms={r['p50_ms']:.2f};"
                    f"p99_ms={r['p99_ms']:.2f};recompiles={r['recompiles']};"
                    f"pad={r['pad_overhead']:.2f}")

    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
