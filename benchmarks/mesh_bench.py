"""Mesh weak-scaling benchmark: row-sharded pool scoring vs devices.

The paper parallelizes prediction across cores by blocking the document
axis per OpenMP thread; the JAX analog is `Predictor.sharded` over a
device mesh, each shard scoring its own (N/K, F) row panel through the
full registry pipeline.  This bench measures that weak scaling on
*virtual* host devices (``--xla_force_host_platform_device_count``):
one subprocess per device count K — XLA pins the device count at first
init, so every K needs a fresh process — timing the same prequantized
bulk scenario the scoring bench gates (quantize once, score many; the
score calls are pure u8 kernel work, no binarize).

Even on a single physical core the K=4 mesh wins: each shard's bins
panel and per-shard intermediates fit the last-level cache, where the
unsharded call streams the full panel through memory per pipeline
stage — the same cache-blocking effect the paper engineers per core.
The committed JSONs (results/perf/mesh-bench__k*.json) pin that curve,
and ``--check`` gates exact parity (sharded == single-device, bit for
bit) plus >= 1.5x at K=4 vs K=1.

  PYTHONPATH=src python -m benchmarks.mesh_bench [--quick] [--check]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[1] / "results" / "perf"
REPO = pathlib.Path(__file__).resolve().parents[1]

# The bulk-prequant scenario: multiclass model at covertype-like dims
# (54 features, 7 classes, 254 borders — the paper's evaluation
# dataset family), 100 trees of depth 6, 16384 rows.  16384 keeps each
# K=4 shard's working set (4096 rows x 54 u8 bins + staged
# intermediates) inside the last-level cache — the blocking win this
# bench exists to measure; much larger N pushes even the per-shard
# panel past the cache and the curve flattens.
SCENARIO = dict(n_trees=100, depth=6, n_features=54, n_borders=254,
                n_outputs=7, n_rows=16384)

WORKER = """
import os
os.environ["XLA_FLAGS"] = \
    "--xla_force_host_platform_device_count={k}"
import json
import time
import numpy as np
import jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.predictor import PredictConfig, Predictor
from repro.core.trees import ObliviousEnsemble
from repro.kernels import registry

T, D, F, B, C, N = {t}, {d}, {f}, {b}, {c}, {n}
rng = np.random.default_rng(42)
sf = rng.integers(0, F, size=(T, D)).astype(np.int32)
sb = rng.integers(1, B + 1, size=(T, D)).astype(np.int32)
lv = rng.normal(size=(T, 1 << D, C)).astype(np.float32)
borders = np.sort(rng.normal(size=(B, F)).astype(np.float32), axis=0)
ens = ObliviousEnsemble(jnp.asarray(sf), jnp.asarray(sb),
                        jnp.asarray(lv), jnp.asarray(borders),
                        jnp.asarray(np.full((F,), B, np.int32)))
x = rng.normal(size=(N, F)).astype(np.float32)

plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                          backend="ref", layout="soa"))
pool = plan.quantize(x)                      # once, outside the loop
ref = np.asarray(plan.raw(pool))             # single-device reference
mesh = make_mesh(({k},), ("data",))
fn = plan.sharded(mesh)

registry.reset_call_stats()
for _ in range({warmup}):
    fn(pool).block_until_ready()
walls = []
for _ in range({reps}):
    t0 = time.perf_counter()
    fn(pool).block_until_ready()
    walls.append(time.perf_counter() - t0)
n_binarize = sum(v for key, v in registry.call_stats().items()
                 if key[0].startswith("binarize"))
wall = float(np.median(walls))
print(json.dumps({{
    "k": {k}, "wall_s": wall, "rows_per_s": N / wall,
    "exact": bool((np.asarray(fn(pool)) == ref).all()),
    "binarize_calls": n_binarize,
    "layout": plan.config.layout,
}}))
"""


def eprint(*a):
    print(*a, file=sys.stderr, flush=True)


def run_worker(k: int, warmup: int, reps: int) -> dict:
    body = WORKER.format(k=k, warmup=warmup, reps=reps,
                         t=SCENARIO["n_trees"], d=SCENARIO["depth"],
                         f=SCENARIO["n_features"],
                         b=SCENARIO["n_borders"],
                         c=SCENARIO["n_outputs"], n=SCENARIO["n_rows"])
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    if out.returncode != 0:
        raise RuntimeError(f"K={k} worker failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def _write_scenario_json(out_dir: pathlib.Path, name: str, scenario: str,
                         fields: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    payload = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "scenario": scenario,
        "layout": "soa",
        **fields,
    }
    (out_dir / f"{name}.json").write_text(json.dumps(payload, indent=1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="K in {1,4} and fewer timed reps")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every K matched the "
                         "single-device reference exactly, dispatched "
                         "zero binarize calls, and K=4 cleared 1.5x")
    ap.add_argument("--devices", default=None,
                    help="comma-separated device counts (overrides "
                         "quick/full defaults)")
    ap.add_argument("--reps", type=int, default=0,
                    help="timed reps per K (0 = 9 quick / 15 full)")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args()

    if args.devices:
        ks = [int(s) for s in args.devices.split(",")]
    else:
        ks = [1, 4] if args.quick else [1, 2, 4, 8]
    reps = args.reps or (9 if args.quick else 15)
    warmup = 3

    s = SCENARIO
    eprint(f"# mesh bench: bulk-prequant, {s['n_rows']} rows x "
           f"{s['n_features']} features, {s['n_trees']} trees depth "
           f"{s['depth']}, {s['n_outputs']} classes, soa/staged/ref; "
           f"host devices K={ks}, {warmup} warmup + {reps} timed "
           f"(median), one subprocess per K")

    results = {}
    for k in ks:
        results[k] = run_worker(k, warmup, reps)
        r = results[k]
        eprint(f"K={k}: {r['rows_per_s']:10.0f} rows/s  "
               f"wall={r['wall_s'] * 1e3:7.2f} ms  "
               f"exact={r['exact']}  binarize_calls="
               f"{r['binarize_calls']}")

    base = results[min(ks)]["rows_per_s"]
    print("name,us_per_call,derived")
    for k in ks:
        r = results[k]
        speedup = r["rows_per_s"] / base
        r["speedup_vs_k1"] = speedup
        print(f"mesh/k{k},{r['wall_s'] * 1e6:.1f},"
              f"rows_per_s={r['rows_per_s']:.0f};"
              f"speedup_vs_k1={speedup:.2f};exact={int(r['exact'])}")

    if not args.no_write:
        out_dir = pathlib.Path(args.out_dir)
        common = {**SCENARIO, "warmup": warmup, "reps": reps,
                  "backend": "ref", "quick": bool(args.quick)}
        for k in ks:
            _write_scenario_json(
                out_dir, f"mesh-bench__k{k}", "mesh-bulk-prequant",
                {**common, "devices": k, **results[k]})
        eprint(f"# wrote result JSONs to {out_dir}")

    if args.check:
        for k in ks:
            if not results[k]["exact"]:
                eprint(f"FAIL: K={k} sharded output diverges from the "
                       "single-device reference (row sharding must be "
                       "bit-exact)")
                return 1
            if results[k]["binarize_calls"]:
                eprint(f"FAIL: K={k} pool scoring dispatched "
                       f"{results[k]['binarize_calls']} binarize calls "
                       "(the prequantized path must dispatch zero)")
                return 1
        if 4 in results and 1 in results:
            ratio = results[4]["rows_per_s"] / results[1]["rows_per_s"]
            if ratio < 1.5:
                eprint(f"FAIL: K=4 weak scaling {ratio:.2f}x is below "
                       "the 1.5x gate vs K=1")
                return 1
            eprint(f"# weak-scaling gate: K4/K1 = {ratio:.2f}x >= 1.5x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
