"""Training benchmark: seed float-path scan vs quantized-first trainer.

Three scenarios over the same dataset and BoostingParams:

  seed-float   `core.boosting.fit_scan` — the pre-PR-7 trainer: one
               lax.scan over trees, binarizes its own float matrix
               every fit, segment-sum histograms outside the registry
  pool         `GBDTTrainer.fit_pool` on a prebuilt uint8
               `QuantizedPool` — registered histogram kernels, zero
               binarize dispatches inside boosting
  streamed     `GBDTTrainer.fit_source` on a `SyntheticSource` —
               includes the out-of-core quantize passes (borders +
               chunked binarize), i.e. ingest amortization included

Timing: one warmup fit (compiles), then --rounds measured fits,
median wall.  `rows_per_s` counts trained sample-rows (N x trees) —
the same unit TrainingMetrics reports.

``--check`` gates (exit 1 on failure):
  * pool == float parity to the leaf-value level (identical splits,
    leaf values within 1e-6) and streamed == pool bit-identical splits
  * the <= compiled-shapes contract: a warmed pool refit performs ZERO
    new histogram dispatches
  * full mode only: pool-path training >= 1.5x the seed float path

Result JSONs land in ``results/perf/training-bench__<scenario>.json``
(the established perf-trajectory schema); ``--no-write`` keeps CI from
clobbering the committed trajectory.
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.core import boosting, quantize
from repro.core.losses import make_loss
from repro.data import synthetic
from repro.kernels import registry
from repro.scoring import sources as sources_lib
from repro.training.gbdt import GBDTTrainer

RESULTS_DIR = pathlib.Path(__file__).resolve().parent.parent / "results" / "perf"


def eprint(*args) -> None:
    print(*args, file=sys.stderr)


def _write_scenario_json(out_dir: pathlib.Path, name: str, scenario: str,
                         fields: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    payload = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "scenario": scenario,
        "layout": "auto",
        **fields,
    }
    (out_dir / f"{name}.json").write_text(json.dumps(payload, indent=1))


def _splits_equal(a, b) -> bool:
    return (np.array_equal(np.asarray(a.split_features),
                           np.asarray(b.split_features))
            and np.array_equal(np.asarray(a.split_bins),
                               np.asarray(b.split_bins)))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless pool==float leaf-value parity "
                         "holds, warmed refits compile nothing new, "
                         "and (full mode) pool training >= 1.5x seed")
    ap.add_argument("--dataset", default="covertype")
    ap.add_argument("--scale", type=float, default=0.0)
    ap.add_argument("--trees", type=int, default=0)
    ap.add_argument("--depth", type=int, default=0)
    ap.add_argument("--max-bins", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=1024,
                    help="streamed-scenario chunk rows")
    ap.add_argument("--rounds", type=int, default=0,
                    help="measured fits per scenario (0 = mode default)")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args()

    scale = args.scale or (0.005 if args.quick else 0.02)
    trees = args.trees or (8 if args.quick else 20)
    depth = args.depth or (4 if args.quick else 6)
    rounds = args.rounds or (1 if args.quick else 2)

    ds = synthetic.load(args.dataset, scale=scale)
    loss = make_loss(ds.loss, n_classes=ds.n_classes)
    params = boosting.BoostingParams(n_trees=trees, depth=depth,
                                     max_bins=args.max_bins, seed=0)
    x, y = ds.x_train, ds.y_train
    rows = int(x.shape[0])
    eprint(f"# training bench: {args.dataset} scale={scale} "
           f"rows={rows} trees={trees} depth={depth}")

    borders, n_borders = quantize.compute_borders(
        np.asarray(x, np.float32), args.max_bins)
    pool = quantize.quantize_pool(jnp.asarray(x, jnp.float32), borders)
    source = sources_lib.SyntheticSource(args.dataset, scale=scale,
                                         split="train", repeat=1)

    def run_seed():
        return boosting.fit_scan(x, y, loss=loss, params=params)

    def run_pool():
        tr = GBDTTrainer(loss, params)
        return tr.fit_pool(pool, y, borders=borders, n_borders=n_borders)

    def run_streamed():
        tr = GBDTTrainer(loss, params)
        return tr.fit_source(source, y, chunk_rows=args.chunk)

    runners = [("seed-float", run_seed), ("pool", run_pool),
               ("streamed", run_streamed)]
    med: dict[str, float] = {}
    result: dict[str, tuple] = {}
    refit_hist_dispatches = 0
    for name, fn in runners:
        result[name] = fn()                       # warmup: compiles
        if name == "pool":
            before = registry.call_stats().get("histogram", 0)
        walls = []
        for _ in range(rounds):
            t0 = time.perf_counter()
            result[name] = fn()
            walls.append(time.perf_counter() - t0)
        if name == "pool":
            refit_hist_dispatches = (registry.call_stats()
                                     .get("histogram", 0) - before)
        med[name] = float(np.median(walls))

    rps = {name: rows * trees / med[name] for name, _ in runners}
    speedup = {name: med["seed-float"] / med[name] for name, _ in runners}
    ens_f = result["seed-float"][0]
    ens_p = result["pool"][0]
    ens_s = result["streamed"][0]
    splits_ok = _splits_equal(ens_p, ens_f)
    leaf_err = float(np.max(np.abs(np.asarray(ens_p.leaf_values)
                                   - np.asarray(ens_f.leaf_values))))
    streamed_ok = _splits_equal(ens_s, ens_p)
    dispatch = result["pool"][1]["dispatch_delta"]

    print("scenario,wall_s,rows_per_s,speedup_vs_seed")
    for name, _ in runners:
        print(f"training/{name},{med[name]:.3f},{rps[name]:.0f},"
              f"{speedup[name]:.2f}")
    eprint(f"# pool==float: splits_equal={splits_ok} "
           f"leaf_max_abs_err={leaf_err:.2e}; streamed==pool splits: "
           f"{streamed_ok}; warmed-refit histogram dispatches: "
           f"{refit_hist_dispatches}")

    if not args.no_write:
        out_dir = pathlib.Path(args.out_dir)
        common = {"dataset": args.dataset, "rows": rows, "trees": trees,
                  "depth": depth, "max_bins": args.max_bins,
                  "rounds": rounds, "backend": "ref",
                  "quick": bool(args.quick)}
        for name, _ in runners:
            extra = {}
            if name == "pool":
                extra = {"splits_equal_vs_seed": splits_ok,
                         "leaf_max_abs_err_vs_seed": leaf_err,
                         "boost_binarize_dispatches":
                             dispatch.get("binarize", 0),
                         "refit_histogram_dispatches":
                             refit_hist_dispatches}
            if name == "streamed":
                extra = {"splits_equal_vs_pool": streamed_ok,
                         "chunk_rows": args.chunk}
            _write_scenario_json(
                out_dir, f"training-bench__{name}", f"training-{name}",
                {**common, "wall_s": med[name], "rows_per_s": rps[name],
                 "speedup_vs_seed": speedup[name], **extra})
        eprint(f"# wrote result JSONs to {out_dir}")

    if args.check:
        if not splits_ok or leaf_err > 1e-6:
            eprint(f"FAIL: pool-path training diverges from the seed "
                   f"float path (splits_equal={splits_ok}, "
                   f"leaf_max_abs_err={leaf_err:.2e})")
            return 1
        if not streamed_ok:
            eprint("FAIL: streamed-source training diverges from "
                   "pool-path training (same rows, same borders)")
            return 1
        if dispatch.get("binarize", 0) != 0:
            eprint(f"FAIL: pool-path boosting dispatched binarize "
                   f"{dispatch['binarize']}x (expected 0)")
            return 1
        if refit_hist_dispatches != 0:
            eprint(f"FAIL: warmed pool refit performed "
                   f"{refit_hist_dispatches} new histogram dispatches; "
                   "the compiled-shape contract is <= depth once")
            return 1
        if not args.quick and speedup["pool"] < 1.5:
            eprint(f"FAIL: pool-path training speedup "
                   f"{speedup['pool']:.2f}x is below the 1.5x floor")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
