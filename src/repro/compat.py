"""Version compatibility shims.

`shard_map` moved from `jax.experimental.shard_map` to the top-level
`jax` namespace around jax 0.4.35/0.5; the pinned container image ships
0.4.37 where only the experimental path exists.  Import it from here
everywhere so the repo runs on either side of the move:

    from repro.compat import shard_map
"""
from __future__ import annotations

try:                                      # jax >= 0.4.35 (top-level export)
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:                       # jax 0.4.x experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f=None, **kw):
    """`shard_map` accepting either replication-check spelling.

    The flag that disables the output-replication check is `check_vma`
    on new jax and `check_rep` on 0.4.x; translate whichever the caller
    used to the one this jax understands.
    """
    if "check_vma" in kw or "check_rep" in kw:
        flag = kw.pop("check_vma", kw.pop("check_rep", None))
        for name in ("check_vma", "check_rep"):
            try:
                return (_shard_map(f, **kw, **{name: flag}) if f is not None
                        else _shard_map(**kw, **{name: flag}))
            except TypeError as e:
                if name not in str(e):
                    raise
        raise TypeError("shard_map accepts neither check_vma nor check_rep")
    return _shard_map(f, **kw) if f is not None else _shard_map(**kw)


def pvary(x, axis_names):
    """`jax.lax.pvary` where it exists, identity elsewhere.

    pvary only annotates device-variance for the new-jax vma checker;
    on 0.4.x there is no checker (we run shard_map with check_rep=False)
    and the annotation has no runtime effect, so identity is exact.
    """
    import jax

    fn = getattr(jax.lax, "pvary", None)
    if fn is None:
        return x
    return fn(x, tuple(axis_names))


def make_mesh(shape, axis_names, *, devices=None):
    """`jax.make_mesh` with explicit Auto axis types where supported.

    jax >= 0.5 accepts ``axis_types=(jax.sharding.AxisType.Auto, ...)``;
    0.4.x has neither the parameter nor the enum (every axis is Auto
    implicitly), so fall back to the plain call.
    """
    import jax

    try:
        return jax.make_mesh(
            tuple(shape), tuple(axis_names), devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(shape)))
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axis_names),
                             devices=devices)


def abstract_mesh(shape, axis_names):
    """Device-free mesh metadata across the AbstractMesh API change.

    jax >= 0.5 takes ``AbstractMesh(shape_tuple, axis_names)``; 0.4.x
    takes a single tuple of ``(name, size)`` pairs.
    """
    import jax

    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(tuple(axis_names), tuple(shape))))


__all__ = ["shard_map", "pvary", "make_mesh", "abstract_mesh"]
