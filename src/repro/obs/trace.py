"""Span tracer with Chrome-trace-event export.

The paper attributes its speedups loop-by-loop (Tables 2-6 time each
vectorized hotspot separately on the Lichee Pi 4a); this module is the
same attribution for our stack: "where did this 180ms batch go?" is
answered by loading `trace.export_chrome(path)` output into Perfetto
(https://ui.perfetto.dev) or chrome://tracing and reading the timeline.

Design constraints, in priority order:

1. **Near-zero overhead when disabled.**  Tracing defaults OFF, and
   every hot site guards with `if TRACER.enabled:` — one attribute
   load + bool test (a few ns) — before building any span arguments.
   `span()` itself returns a shared no-op context manager when
   disabled, so even unguarded call sites stay cheap (no allocation).
   The disabled-cost bound is asserted in tests/test_obs.py.
2. **Thread-safe, bounded memory.**  Events land in a
   `collections.deque(maxlen=capacity)` ring buffer — appends are
   atomic under the GIL, eviction is FIFO (oldest events drop first),
   and a runaway trace can never grow past `capacity` events.
3. **Monotonic clocks.**  Timestamps come from `time.perf_counter_ns`
   relative to the tracer's epoch; wall-clock adjustments can never
   produce negative durations.

Event kinds (Chrome trace `ph` values the exporter emits):

  span     `ph="X"` complete event: name, category, ts, dur, args —
           produced by the `span()` context manager
  instant  `ph="i"` instant event — e.g. Predictor compile events
  counter  `ph="C"` counter event — e.g. dispatch totals over time
  (plus `ph="M"` thread-name metadata rows, emitted at export time)

Span taxonomy (see docs/observability.md for the full contract):

  dispatch/<op>      kernel registry dispatch (op, impl, layout, dtype)
  compile/<entry>    Predictor XLA trace (entry, layout, batch rows)
  sharded/<kind>     mesh-sharded predict (shard axis, device count)
  bulk/quantize      BulkScorer prefetch-worker binarize (per chunk)
  bulk/score         BulkScorer chunk dispatch (main thread)
  bulk/sink          BulkScorer device sync + sink write
  train/level        GBDTTrainer per-level histogram+split pass
  train/iteration    GBDTTrainer whole boosting iteration
  serve/batch        GBDTServer scored batch
"""
from __future__ import annotations

import collections
import json
import pathlib
import threading
import time
from typing import Any, Optional

DEFAULT_CAPACITY = 65536


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled.

    A singleton: entering/exiting allocates nothing, so an unguarded
    `with span(...)` costs one call + two no-op methods when tracing
    is off (hot sites additionally guard on `TRACER.enabled` to skip
    building the attribute kwargs at all)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> None:
        """Attribute updates on a disabled span are dropped."""


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records ts on __enter__, appends on __exit__."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = time.perf_counter_ns()
        self._tracer._append(("X", self.name, self.cat, self._t0,
                              t1 - self._t0,
                              threading.get_ident(), self.args))
        return False

    def set(self, **attrs: Any) -> None:
        """Attach attributes discovered mid-span (e.g. result shape)."""
        self.args.update(attrs)


class Tracer:
    """Thread-safe span/instant/counter recorder with a bounded ring.

    One process-wide instance (`get_tracer()`) serves every
    instrumentation site; tests may construct private tracers.  All
    recording methods are safe to call from any thread — the scorer's
    prefetch worker and the serving batcher thread record into the
    same ring as the main thread, which is exactly what makes prefetch
    overlap visible on the exported timeline.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = False
        # (ph, name, cat, t_ns, dur_ns, thread_ident, args) tuples.
        # deque.append is atomic under the GIL and maxlen gives FIFO
        # eviction — no lock on the record path.
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._epoch_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._dropped = 0
        # thread ident -> name, captured at record time: a worker (the
        # scorer's Prefetcher) may be gone by export time, when
        # threading.enumerate() can no longer name it
        self._thread_names: dict[int, str] = {}

    # -- control -----------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self._ring.clear()
        self._epoch_ns = time.perf_counter_ns()
        self._dropped = 0

    # -- recording ---------------------------------------------------------
    def _append(self, event: tuple) -> None:
        if len(self._ring) == self.capacity:
            # racy read, but the count is advisory (exported as
            # metadata); the ring itself evicts correctly regardless
            self._dropped += 1
        if event[5] not in self._thread_names:
            self._thread_names[event[5]] = threading.current_thread().name
        self._ring.append(event)

    def span(self, name: str, cat: str = "", **attrs: Any):
        """Context manager timing a region.  Returns the shared no-op
        singleton while disabled, so `with span(...)` is always legal
        and never allocates when tracing is off."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, attrs)

    def complete(self, name: str, cat: str = "", *, start_ns: int,
                 duration_ns: int, **attrs: Any) -> None:
        """Record an already-timed region as a complete span.

        For call sites that measure their own stage timings anyway
        (the trainer's per-level clocks): `start_ns` is a
        `time.perf_counter_ns()` reading — the same clock spans use —
        so these land on the timeline exactly like `span()` output."""
        if not self.enabled:
            return
        self._append(("X", name, cat, start_ns, duration_ns,
                      threading.get_ident(), attrs))

    def instant(self, name: str, cat: str = "", **attrs: Any) -> None:
        """A point-in-time event (Chrome `ph="i"`)."""
        if not self.enabled:
            return
        self._append(("i", name, cat, time.perf_counter_ns(), 0,
                      threading.get_ident(), attrs))

    def counter(self, name: str, cat: str = "",
                **values: float) -> None:
        """A process-level counter sample (Chrome `ph="C"` — renders
        as a stacked area track).  Values must be numeric."""
        if not self.enabled:
            return
        self._append(("C", name, cat, time.perf_counter_ns(), 0,
                      threading.get_ident(), values))

    # -- reading -----------------------------------------------------------
    def events(self) -> list[dict[str, Any]]:
        """Snapshot of the ring as dicts (oldest first).  Timestamps
        are microseconds relative to the tracer epoch."""
        epoch = self._epoch_ns
        out = []
        for ph, name, cat, t_ns, dur_ns, tid, args in list(self._ring):
            out.append({"ph": ph, "name": name, "cat": cat,
                        "ts_us": (t_ns - epoch) / 1e3,
                        "dur_us": dur_ns / 1e3, "tid": tid,
                        "args": dict(args)})
        return out

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def dropped(self) -> int:
        """Events evicted by the ring bound (advisory count)."""
        return self._dropped

    # -- export ------------------------------------------------------------
    def export_chrome(self, path: str | pathlib.Path) -> dict[str, Any]:
        """Write the ring as Chrome trace-event JSON and return the
        object.  The file loads directly in Perfetto or
        chrome://tracing: spans are `ph="X"` complete events with
        microsecond `ts`/`dur`, counters are `ph="C"`, and thread-name
        metadata rows label the prefetch/batcher worker threads so
        overlap is readable."""
        with self._lock:
            events = list(self._ring)
            epoch = self._epoch_ns
            dropped = self._dropped
            names = dict(self._thread_names)
        pid = 1
        tid_map: dict[int, int] = {}
        rows: list[dict[str, Any]] = []
        main_ident = threading.main_thread().ident
        for ph, name, cat, t_ns, dur_ns, tid, args in events:
            if tid not in tid_map:
                tid_map[tid] = len(tid_map)
                label = ("main" if tid == main_ident
                         else names.get(tid, f"thread-{len(tid_map)}"))
                rows.append({"ph": "M", "name": "thread_name", "pid": pid,
                             "tid": tid_map[tid],
                             "args": {"name": label}})
            row: dict[str, Any] = {
                "ph": ph, "name": name, "cat": cat or "repro",
                "ts": (t_ns - epoch) / 1e3, "pid": pid,
                "tid": tid_map[tid], "args": dict(args),
            }
            if ph == "X":
                row["dur"] = dur_ns / 1e3
            elif ph == "i":
                row["s"] = "t"           # instant scope: thread
            rows.append(row)
        obj = {"traceEvents": rows, "displayTimeUnit": "ms",
               "otherData": {"dropped_events": dropped,
                             "capacity": self.capacity}}
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(obj))
        return obj


# --------------------------------------------------------------------------
# Process-wide tracer + module-level conveniences
# --------------------------------------------------------------------------
_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer every instrumentation site records to."""
    return _GLOBAL


def enabled() -> bool:
    return _GLOBAL.enabled


def enable() -> None:
    _GLOBAL.enable()


def disable() -> None:
    _GLOBAL.disable()


def span(name: str, cat: str = "", **attrs: Any):
    return _GLOBAL.span(name, cat, **attrs)


def instant(name: str, cat: str = "", **attrs: Any) -> None:
    _GLOBAL.instant(name, cat, **attrs)


def counter(name: str, cat: str = "", **values: float) -> None:
    _GLOBAL.counter(name, cat, **values)


def export_chrome(path: str | pathlib.Path) -> dict[str, Any]:
    return _GLOBAL.export_chrome(path)


class tracing:
    """`with tracing():` — enable the global tracer for a region and
    restore the previous state on exit (exception-safe; what the CLI
    `--trace-out` flags use)."""

    def __init__(self, tracer: Optional[Tracer] = None,
                 clear: bool = False):
        # explicit None test: an *empty* Tracer is falsy (__len__ == 0)
        self._tracer = tracer if tracer is not None else _GLOBAL
        self._clear = clear
        self._was = False

    def __enter__(self) -> Tracer:
        if self._clear:
            self._tracer.clear()
        self._was = self._tracer.enabled
        self._tracer.enable()
        return self._tracer

    def __exit__(self, *exc: Any) -> bool:
        self._tracer.enabled = self._was
        return False
