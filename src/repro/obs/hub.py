"""MetricsHub: one namespace over every metrics class in the stack.

The stack grew three snapshot-shaped metrics classes — `ServerMetrics`
(online serving), `ScoringMetrics` (offline bulk), `TrainingMetrics`
(boosting) — each exporting a flat dict in shared units (`rows_per_s`
appears in all three).  The hub registers any number of them behind
namespaces and renders the combined view in two formats:

* **JSON** — `{namespace: snapshot}` with a capture timestamp; what
  the CLI `--metrics-out` flags write and dashboards poll.
* **Prometheus textfile** — one gauge per numeric snapshot field,
  `repro_<namespace>_<field>`, string fields attached as labels
  (textfile-collector format: drop the file into
  `node_exporter --collector.textfile.directory`).

Sources are registered as anything with a `.snapshot()` method or any
zero-arg callable returning a dict, so `ReplicaGroup.metrics_snapshot`
and ad-hoc lambdas register the same way as the metrics classes.

SLO accounting itself lives where the measurements are taken
(`ServerMetrics`: deadline attainment / shed rate / p99-under-deadline
— see docs/observability.md for the definitions); the hub is transport.
"""
from __future__ import annotations

import datetime
import json
import pathlib
import re
import threading
from typing import Any, Callable, Mapping

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(*parts: str) -> str:
    """Join parts into a Prometheus-legal metric name."""
    name = "_".join(_NAME_RE.sub("_", p) for p in parts if p)
    if not name or name[0].isdigit():
        name = "repro_" + name
    return name


def _label_value(v: Any) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"') \
        .replace("\n", "\\n")


class MetricsHub:
    """Named registry of snapshot sources with JSON/Prometheus export.

    >>> hub = MetricsHub()
    >>> hub.register("serving/gbdt", server.metrics)
    >>> hub.register("scoring/nightly", lambda: result.metrics)
    >>> hub.snapshot()["serving/gbdt"]["rows_per_s"]
    >>> hub.export_prometheus("metrics.prom")
    """

    def __init__(self, prefix: str = "repro"):
        self.prefix = prefix
        self._lock = threading.Lock()
        self._sources: dict[str, Callable[[], Mapping[str, Any]]] = {}

    def register(self, namespace: str, source: Any,
                 replace: bool = False) -> None:
        """Register a snapshot source under `namespace`.

        `source` is an object with `.snapshot()` (ServerMetrics,
        ScoringMetrics, TrainingMetrics), a zero-arg callable
        returning a dict, or a plain dict (captured by reference).
        Re-registering a live namespace without `replace=True` raises —
        silently shadowing a dashboard feed is the failure mode this
        guards."""
        if hasattr(source, "snapshot"):
            fn = source.snapshot
        elif callable(source):
            fn = source
        elif isinstance(source, Mapping):
            fn = lambda: source                       # noqa: E731
        else:
            raise TypeError(
                f"source for {namespace!r} must have .snapshot(), be "
                f"callable, or be a Mapping; got {type(source).__name__}")
        with self._lock:
            if namespace in self._sources and not replace:
                raise KeyError(f"namespace {namespace!r} already "
                               "registered (pass replace=True)")
            self._sources[namespace] = fn

    def unregister(self, namespace: str) -> None:
        with self._lock:
            if namespace not in self._sources:
                raise KeyError(f"unknown namespace {namespace!r}; "
                               f"registered: {self.namespaces()}")
            del self._sources[namespace]

    def namespaces(self) -> list[str]:
        with self._lock:
            return sorted(self._sources)

    # -- collection --------------------------------------------------------
    def snapshot(self) -> dict[str, dict[str, Any]]:
        """Collect every source.  A source that raises is reported as
        `{"error": repr}` instead of poisoning the whole export (a
        dead model's metrics must not take down the fleet view)."""
        with self._lock:
            sources = dict(self._sources)
        out: dict[str, dict[str, Any]] = {}
        for ns, fn in sources.items():
            try:
                out[ns] = dict(fn())
            except Exception as e:          # noqa: BLE001 — see docstring
                out[ns] = {"error": repr(e)}
        return out

    # -- export ------------------------------------------------------------
    def export_json(self, path: str | pathlib.Path) -> dict[str, Any]:
        obj = {
            "collected_at": datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds"),
            "metrics": self.snapshot(),
        }
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(obj, indent=1, default=float))
        return obj

    def format_prometheus(self) -> str:
        """Render every numeric snapshot field as a gauge in the
        Prometheus text exposition format.

        Numbers (and bools, as 0/1) become
        `<prefix>_<namespace>_<field> value`; string fields become
        labels shared by every gauge of that namespace; nested dicts
        flatten one level (`traces.raw` -> `traces_raw`); lists are
        skipped (no natural gauge shape)."""
        lines: list[str] = []
        for ns, snap in sorted(self.snapshot().items()):
            flat: dict[str, float] = {}
            labels: dict[str, str] = {}
            for key, val in snap.items():
                if isinstance(val, bool):
                    flat[key] = float(val)
                elif isinstance(val, (int, float)):
                    flat[key] = float(val)
                elif isinstance(val, str):
                    labels[key] = val
                elif isinstance(val, Mapping):
                    for k2, v2 in val.items():
                        if isinstance(v2, (int, float)) \
                                and not isinstance(v2, bool):
                            flat[f"{key}_{k2}"] = float(v2)
                        elif isinstance(v2, bool):
                            flat[f"{key}_{k2}"] = float(v2)
                # lists / None: no gauge shape, skipped
            label_str = ""
            if labels:
                label_str = "{" + ",".join(
                    f'{_metric_name(k)}="{_label_value(v)}"'
                    for k, v in sorted(labels.items())) + "}"
            for key, val in sorted(flat.items()):
                name = _metric_name(self.prefix, ns, key)
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name}{label_str} {val:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def export_prometheus(self, path: str | pathlib.Path) -> str:
        text = self.format_prometheus()
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(text)
        return text
