"""Unified telemetry: dispatch-level tracing + metrics aggregation.

The paper's whole argument is a measurement story — it attributes the
RISC-V speedups loop-by-loop by benchmarking each vectorized kernel on
real hardware.  This package is that attribution layer for our stack:

* `repro.obs.trace` — a thread-safe, near-zero-overhead-when-disabled
  span tracer with a Chrome-trace-event exporter (loadable in Perfetto
  / chrome://tracing).  Every hot path is instrumented: kernel-registry
  dispatches, Predictor compile events, BulkScorer quantize/score/sink
  stages (prefetch overlap visible on the timeline), per-level training
  histogram passes, sharded mesh entries.
* `repro.obs.hub` — a `MetricsHub` that registers the existing
  `ServerMetrics` / `ScoringMetrics` / `TrainingMetrics` snapshots
  behind one namespace and exports Prometheus-textfile and JSON
  formats; serving snapshots carry deadline-SLO accounting.

See docs/observability.md for the span taxonomy and exporter formats.
"""
from repro.obs.trace import (Tracer, get_tracer, span, instant, counter,
                             enable, disable, enabled,
                             export_chrome)   # noqa: F401
from repro.obs.hub import MetricsHub          # noqa: F401
