"""The paper's own workload configs (Table 1) as selectable configs.

These drive examples/, benchmarks/ and the serving launcher; row counts
are scaled by the harness (synthetic data generators keep the feature
dimensionality, class counts and loss of the originals).
"""
from __future__ import annotations

import dataclasses

from repro.core.boosting import BoostingParams


@dataclasses.dataclass(frozen=True)
class GBDTWorkload:
    name: str
    dataset: str                 # repro.data.synthetic registry key
    loss: str
    n_classes: int
    rows_cols: tuple
    params: BoostingParams
    # paper Table 1 sets 10000 max iterations; benchmark presets scale the
    # tree count down for the single-core container (documented)
    paper_iterations: int = 10000


WORKLOADS = {
    "mq2008": GBDTWorkload(
        "mq2008", "mq2008", "yetirank", 0, (9630, 46),
        BoostingParams(depth=6, learning_rate=0.02)),
    "santander": GBDTWorkload(
        "santander", "santander", "logloss", 2, (400_000, 202),
        BoostingParams(depth=1, learning_rate=0.01)),
    "covertype": GBDTWorkload(
        "covertype", "covertype", "multiclass", 7, (464_800, 54),
        BoostingParams(depth=8, learning_rate=0.50)),
    "year_prediction_msd": GBDTWorkload(
        "year_prediction_msd", "year_prediction_msd", "mae", 0,
        (515_345, 90), BoostingParams(depth=6, learning_rate=0.30)),
    "image_embeddings": GBDTWorkload(
        "image_embeddings", "image_embeddings", "multiclass", 20,
        (5_649, 512), BoostingParams(depth=4, learning_rate=0.05)),
}


def get(name: str) -> GBDTWorkload:
    return WORKLOADS[name]
