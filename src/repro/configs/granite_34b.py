"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense", n_layers=88, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152,
    rope_theta=1e4, fsdp=True, mlp="gelu")

SMOKE = dataclasses.replace(
    CONFIG, name="granite-34b-smoke", n_layers=3, d_model=128, n_heads=8,
    n_kv_heads=1, d_ff=384, vocab_size=512, fsdp=False, remat=False, compute_dtype="float32")
