"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H (kv=12)
d_ff=3072 vocab=51865 — enc-dec, conv frontend stub [arXiv:2212.04356].

The conv1d mel frontend is a stub providing precomputed frame embeddings
(1500 frames), per the assignment. LayerNorm + GELU, learned positions."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=51865,
    encoder_layers=12, frontend="audio", frontend_seq=1500,
    norm="layernorm", mlp="gelu", learned_positions=True)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-small-smoke", n_layers=2, encoder_layers=2,
    d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
    frontend_seq=32, remat=False, compute_dtype="float32")
