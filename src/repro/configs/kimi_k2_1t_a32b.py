"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE [arXiv:2501.kimi2].

1T-param note: Adam needs ~12 TB of optimizer state for 1T params — more
than a 512-chip x 16 GB pod; config uses Adafactor + FSDP (DESIGN.md SS5).
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab_size=163840,
    n_experts=384, experts_per_token=8, moe_shard="expert",
    rope_theta=5e7, fsdp=True, optimizer="adafactor",
    param_dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, name="kimi-k2-1t-a32b-smoke", n_layers=2, d_model=128,
    n_heads=8, n_kv_heads=4, d_ff=64, vocab_size=512, n_experts=8,
    experts_per_token=2, moe_group_size=64, moe_capacity_factor=8.0, fsdp=False, remat=False, compute_dtype="float32",
    param_dtype="float32", optimizer="adamw")
