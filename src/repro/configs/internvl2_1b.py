"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2 [arXiv:2404.16821; hf].

Backbone only; the ViT frontend is a stub providing precomputed patch
embeddings (input_specs), per the assignment."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab_size=151655,
    frontend="vision", frontend_seq=256, rope_theta=1e6)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-1b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, frontend_seq=16, remat=False, compute_dtype="float32")
