"""Architecture registry: the 10 assigned archs + paper GBDT workloads."""
from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES,
                                SUBQUADRATIC, applicable_shapes)
from repro.configs import (glm4_9b, granite_34b, internlm2_20b, internvl2_1b,
                           kimi_k2_1t_a32b, mamba2_1p3b, mixtral_8x22b,
                           stablelm_12b, whisper_small, zamba2_1p2b)

_MODULES = [internlm2_20b, glm4_9b, stablelm_12b, granite_34b, zamba2_1p2b,
            mamba2_1p3b, kimi_k2_1t_a32b, mixtral_8x22b, internvl2_1b,
            whisper_small]

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES = {m.CONFIG.name: m.SMOKE for m in _MODULES}


def get(name: str, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]
