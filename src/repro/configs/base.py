"""Config schema: architectures (assigned pool) and workload shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 1024
    moe_capacity_factor: float = 1.25
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    attn_every: int = 0          # hybrid: shared attn block every k layers
    # --- attention ---
    rope_theta: float = 1e6
    sliding_window: int = 0      # SWA (mixtral)
    attn_chunk: int = 1024       # row-blocked attention q-chunk for long seq
    attn_chunk_threshold: int = 4096
    # --- enc-dec / frontends ---
    encoder_layers: int = 0      # whisper encoder depth
    frontend: str = ""           # "" | "vision" | "audio" (stub embeddings)
    frontend_seq: int = 0        # patches / frames provided by the stub
    norm: str = "rmsnorm"        # rmsnorm | layernorm (whisper)
    mlp: str = "swiglu"          # swiglu | gelu
    learned_positions: bool = False
    tie_embeddings: bool = False
    # --- lowering ---
    scan_unroll: bool = False    # dry-run: unroll scans so cost_analysis
    #                              counts loop bodies x trip_count (XLA
    #                              counts a `while` body once)
    # --- numerics / training ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs only)
    optimizer: str = "adamw"     # adamw | adafactor (1T-param models)
    # --- distribution ---
    fsdp: bool = False           # shard weights over the data axis too
    moe_shard: str = "expert"    # expert (EP) | ffn (TP inside experts)
    #                            | expert2d (EP over model x d_ff over data:
    #                              reshards activations instead of
    #                              all-gathering expert weights)
    flash_decode: bool = False   # shard_map LSE-combined decode attention
    #                              over the seq-sharded KV cache (no
    #                              per-layer KV all-gather)
    attention_impl: str = "auto"  # auto | ring (sequence-sharded ring
    #                              attention via shard_map ppermute; the fix
    #                              for head counts that cannot shard the
    #                              model axis)
    sequence_parallel: bool = False  # constrain hidden states to shard the
    #                              sequence dim over "model": removes the
    #                              16x replicated compute when head counts
    #                              cannot shard the model axis (small archs)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def param_count(self) -> int:
        """Approximate total parameters (embeddings + blocks)."""
        D, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * D
            if self.n_experts:
                ffn = self.n_experts * 3 * D * self.d_ff + D * self.n_experts
            else:
                mult = 3 if self.mlp == "swiglu" else 2
                ffn = mult * D * self.d_ff
            per_layer = attn + ffn
        elif self.family in ("ssm", "hybrid"):
            d_inner = self.ssm_expand * D
            H = d_inner // self.ssm_headdim
            proj = D * (2 * d_inner + 2 * self.ssm_state + H)
            per_layer = proj + d_inner * D
        total = emb + L * per_layer
        if self.family == "hybrid" and self.attn_every:
            attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * D
            total += attn + 3 * D * self.d_ff          # one shared block
        if self.family == "audio":
            total += self.encoder_layers * per_layer    # encoder stack
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * D
        ffn = self.experts_per_token * 3 * D * self.d_ff
        return emb + L * (attn + ffn + D * self.n_experts)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs that may run long_500k (sub-quadratic decode): SSM state decode or
# sliding-window attention.  Pure full-attention archs skip it (DESIGN.md
# SSArch-applicability).
SUBQUADRATIC = {"mamba2-1.3b", "zamba2-1.2b", "mixtral-8x22b"}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.name in SUBQUADRATIC:
        names.append("long_500k")
    return names
