"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — RoPE, GQA [hf:THUDM/glm-4-9b; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=151552,
    rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, name="glm4-9b-smoke", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=2, d_ff=192, vocab_size=512, remat=False, compute_dtype="float32")
