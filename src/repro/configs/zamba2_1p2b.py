"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, attn_every=6, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-1.2b-smoke", n_layers=5, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=512, ssm_state=16, ssm_headdim=32,
    attn_every=2, ssm_chunk=16, remat=False, compute_dtype="float32")
