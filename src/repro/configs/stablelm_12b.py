"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-1_6b family; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=13824, vocab_size=100352,
    rope_theta=1e4, fsdp=True)   # 12B: fp32 Adam states need ZeRO on 16GB

SMOKE = dataclasses.replace(
    CONFIG, name="stablelm-12b-smoke", n_layers=2, d_model=160, n_heads=8,
    n_kv_heads=4, d_ff=256, vocab_size=512, remat=False, compute_dtype="float32")
