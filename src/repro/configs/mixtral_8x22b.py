"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) expert d_ff=16384
vocab=32768, MoE 8e top-2, SWA [arXiv:2401.04088; hf].

8 experts < 16-way model axis -> moe_shard="ffn" (TP inside experts).
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=32768,
    n_experts=8, experts_per_token=2, moe_shard="ffn",
    sliding_window=4096, rope_theta=1e6, fsdp=True)

SMOKE = dataclasses.replace(
    CONFIG, name="mixtral-8x22b-smoke", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=4, d_ff=128, vocab_size=512, n_experts=4,
    experts_per_token=2, moe_group_size=64, moe_capacity_factor=8.0, sliding_window=32,
    fsdp=False, remat=False, compute_dtype="float32")
