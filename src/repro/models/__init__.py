"""Model substrate: unified LM stack for the 10 assigned architectures."""
from repro.models import layers, moe, ssm, steps, transformer  # noqa: F401
