"""Step functions: train_step / prefill_step / decode_step factories.

These are the functions the launcher jits with explicit in/out shardings
and the dry-run lowers against ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf

AUX_WEIGHT = 0.01     # MoE load-balance loss weight


def token_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array
               ) -> jax.Array:
    """Mean next-token cross entropy; logits (B, S, V) fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def loss_fn(cfg: ModelConfig, params, batch, mesh=None
            ) -> tuple[jax.Array, dict]:
    logits, aux = tf.forward(cfg, params, batch, mesh=mesh)
    ce = token_loss(cfg, logits, batch["labels"])
    total = ce + AUX_WEIGHT * aux
    return total, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, optimizer, mesh=None) -> Callable:
    """Returns fn(params, opt_state, batch) -> (params, opt_state, metrics).

    `optimizer` follows the repro.training.optimizer interface
    (init/update); gradient all-reduce across data axes is implicit in the
    pjit sharding (GSPMD inserts the collectives).
    """

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, mesh=mesh),
            has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                        params, updates)
        gnorm = optimizer.global_norm(grads)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        loss, parts = loss_fn(cfg, params, batch)
        return {"loss": loss, **parts}
    return eval_step


def make_prefill_step(cfg: ModelConfig, max_seq: int,
                      mesh=None) -> Callable:
    def prefill_step(params, batch):
        return tf.prefill(cfg, params, batch, max_seq, mesh=mesh)
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None) -> Callable:
    def decode_step(params, cache, tokens):
        return tf.decode_step(cfg, params, cache, tokens, mesh=mesh)
    return decode_step
