"""Mamba2 (SSD — state-space duality) block, chunked scan formulation.

Implements the SSD algorithm from arXiv:2405.21060: the sequence is split
into chunks; within a chunk the recurrence is computed as a (masked,
decay-weighted) quadratic attention-like product; across chunks a small
associative scan carries the (H, P, N) state.  On TPU both the intra-chunk
einsums and the chunk-state contraction are MXU work, and the inter-chunk
scan touches only O(S/Q) state tensors.

Decode is the pure recurrence: h <- exp(dt*A) h + dt * (x outer B);
y = h . C — O(1) per token, which is why mamba2/zamba2 are the archs that
run the long_500k shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


class SSMParams(NamedTuple):
    in_proj: jax.Array      # (D, 2*d_inner + 2*N + H)
    conv_w: jax.Array       # (W, conv_dim)  depthwise, conv_dim=d_inner+2N
    conv_b: jax.Array       # (conv_dim,)
    A_log: jax.Array        # (H,)
    D_skip: jax.Array       # (H,)
    dt_bias: jax.Array      # (H,)
    norm_scale: jax.Array   # (d_inner,)
    out_proj: jax.Array     # (d_inner, D)


def ssm_dims(d_model: int, *, expand: int = 2, headdim: int = 64,
             d_state: int = 64, conv_width: int = 4):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_dim = d_inner + 2 * d_state
    return dict(d_inner=d_inner, n_heads=n_heads, headdim=headdim,
                d_state=d_state, conv_dim=conv_dim, conv_width=conv_width)


def init_ssm_params(key, d_model: int, dims: dict, dtype=jnp.float32
                    ) -> SSMParams:
    d_inner, H = dims["d_inner"], dims["n_heads"]
    N, W, conv_dim = dims["d_state"], dims["conv_width"], dims["conv_dim"]
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * N + H
    return SSMParams(
        in_proj=(jax.random.normal(ks[0], (d_model, proj_out)) * 0.02
                 ).astype(dtype),
        conv_w=(jax.random.normal(ks[1], (W, conv_dim)) * 0.2).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        D_skip=jnp.ones((H,), dtype),
        dt_bias=jnp.full((H,), -1.0, dtype),
        norm_scale=jnp.ones((d_inner,), dtype),
        out_proj=(jax.random.normal(ks[2], (d_inner, d_model)) * 0.02
                  ).astype(dtype),
    )


def _depthwise_causal_conv(x: jax.Array, w: jax.Array, b: jax.Array
                           ) -> jax.Array:
    """x: (B, S, C), w: (W, C). Causal depthwise conv, silu activation."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return jax.nn.silu(out + b[None, None, :])


def _split_proj(zxbcdt, dims):
    d_inner, N, H = dims["d_inner"], dims["d_state"], dims["n_heads"]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xBC, dt


def ssd_forward(params: SSMParams, u: jax.Array, dims: dict, *,
                chunk: int = 64, return_cache: bool = False,
                unroll: bool = False):
    """u: (B, S, D) -> (B, S, D) [, final SSMCache for decode handoff].

    S is padded up to a multiple of `chunk` internally (causal, so the
    tail padding never influences real positions)."""
    B, S0, D = u.shape
    if S0 % chunk:
        pad = chunk - S0 % chunk
        out = ssd_forward(params, jnp.pad(u, ((0, 0), (0, pad), (0, 0))),
                          dims, chunk=chunk, return_cache=False,
                          unroll=unroll)
        # NOTE: return_cache with padding would hand back a state advanced
        # past S0; callers needing the cache must pass chunk-aligned S.
        assert not return_cache, "return_cache requires S % chunk == 0"
        return out[:, :S0, :]
    S = S0
    d_inner, H, P = dims["d_inner"], dims["n_heads"], dims["headdim"]
    N = dims["d_state"]
    W = dims["conv_width"]

    zxbcdt = u @ params.in_proj
    z, xBC, dt = _split_proj(zxbcdt, dims)
    xBC_raw = xBC                                          # pre-conv tail
    xBC = _depthwise_causal_conv(xBC, params.conv_w, params.conv_b)
    x = xBC[..., :d_inner].reshape(B, S, H, P)
    Bm = xBC[..., d_inner:d_inner + N]                     # (B, S, N)
    Cm = xBC[..., d_inner + N:]                            # (B, S, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params.dt_bias.astype(jnp.float32))  # (B, S, H)
    A = -jnp.exp(params.A_log.astype(jnp.float32))         # (H,)
    dA = dt * A[None, None, :]                             # (B, S, H) <= 0

    nc = S // chunk
    Q = chunk
    xc = x.reshape(B, nc, Q, H, P)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)
    dtc = dt.reshape(B, nc, Q, H)
    dAc = dA.reshape(B, nc, Q, H)
    cs = jnp.cumsum(dAc, axis=2)                           # (B, nc, Q, H)

    # ---- intra-chunk (quadratic, attention-like) ----
    CB = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc,
                    preferred_element_type=jnp.float32)    # (B, nc, Q, Q)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    delta = cs[:, :, :, None, :] - cs[:, :, None, :, :]
    # mask BEFORE exp: the upper triangle has delta > 0 (cs decreasing),
    # exp would overflow and poison the gradient through jnp.where
    decay = jnp.exp(jnp.where(tri, delta, -jnp.inf))
    att = CB[:, :, :, :, None] * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", att.astype(x.dtype), xc,
                         preferred_element_type=jnp.float32)

    # ---- chunk states ----
    last = cs[:, :, -1:, :]                                 # (B, nc, 1, H)
    w_state = jnp.exp(last - cs) * dtc                      # (B, nc, Q, H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                        Bc.astype(jnp.float32), w_state,
                        xc.astype(jnp.float32))             # (B,nc,H,N,P)

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(last[:, :, 0, :])                 # (B, nc, H)

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    sc_states = jnp.moveaxis(states, 1, 0)                  # (nc, B, H, N, P)
    sc_decay = jnp.moveaxis(chunk_decay, 1, 0)              # (nc, B, H)
    h_final, h_prev = jax.lax.scan(scan_fn, h0, (sc_states, sc_decay),
                                   unroll=unroll)
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B, nc, H, N, P)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc.astype(jnp.float32), jnp.exp(cs), h_prev)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + params.D_skip[None, None, :, None].astype(jnp.float32) \
        * x.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params.norm_scale)
    out = y @ params.out_proj
    if return_cache:
        conv_tail = xBC_raw[:, S - (W - 1):, :]
        return out, SSMCache(h=h_final, conv=conv_tail)
    return out


class SSMCache(NamedTuple):
    h: jax.Array          # (B, H, N, P) float32
    conv: jax.Array       # (B, W-1, conv_dim)


def init_ssm_cache(batch: int, dims: dict, dtype=jnp.float32) -> SSMCache:
    H, N, P = dims["n_heads"], dims["d_state"], dims["headdim"]
    W, conv_dim = dims["conv_width"], dims["conv_dim"]
    return SSMCache(h=jnp.zeros((batch, H, N, P), jnp.float32),
                    conv=jnp.zeros((batch, W - 1, conv_dim), dtype))


def ssd_decode_step(params: SSMParams, u: jax.Array, cache: SSMCache,
                    dims: dict) -> tuple[jax.Array, SSMCache]:
    """u: (B, 1, D) one token -> (B, 1, D), updated cache."""
    B = u.shape[0]
    d_inner, H, P = dims["d_inner"], dims["n_heads"], dims["headdim"]
    N, W = dims["d_state"], dims["conv_width"]

    zxbcdt = u[:, 0, :] @ params.in_proj                    # (B, total)
    z, xBC, dt = _split_proj(zxbcdt, dims)
    conv_in = jnp.concatenate([cache.conv, xBC[:, None, :]], axis=1)
    conv_out = jnp.sum(conv_in * params.conv_w[None, :, :], axis=1) \
        + params.conv_b[None, :]
    xBC = jax.nn.silu(conv_out)                             # (B, conv_dim)
    x = xBC[:, :d_inner].reshape(B, H, P)
    Bm = xBC[:, d_inner:d_inner + N]
    Cm = xBC[:, d_inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params.dt_bias.astype(jnp.float32))   # (B, H)
    A = -jnp.exp(params.A_log.astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                           # (B, H)

    hx = cache.h * dA[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt, x.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), hx)
    y = y + params.D_skip[None, :, None].astype(jnp.float32) \
        * x.astype(jnp.float32)
    y = y.reshape(B, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params.norm_scale)
    out = (y @ params.out_proj)[:, None, :]
    return out, SSMCache(h=hx, conv=conv_in[:, 1:, :])
