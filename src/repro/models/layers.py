"""Shared LM building blocks: norms, RoPE, attention (GQA/SWA/chunked),
MLPs.  Pure functions over explicit parameter pytrees; dtype policy is
(param_dtype storage, compute in bf16 by default, fp32 softmax/norm).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (B, S, H, Dh), positions: (B, S) or (S,)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)            # (half,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]                       # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:2 * half].astype(
        jnp.float32)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    if x.shape[-1] > 2 * half:                              # odd tail passes
        rot = jnp.concatenate([rot, x[..., 2 * half:].astype(jnp.float32)],
                              axis=-1)
    return rot.astype(dt)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def _attend_block(q, k, v, mask):
    """q: (B, Sq, KVH, G, Dh); k/v: (B, Sk, KVH, Dh); mask: (Sq, Sk) or None.

    fp32 softmax, bf16 matmuls.
    """
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhgd,bshd->bhgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[None, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _mask(sq: int, sk: int, q_off, *, causal: bool, window: int):
    """(sq, sk) boolean mask. q position = q_off + row."""
    qpos = q_off + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              q_chunk: int = 0, unroll: bool = False) -> jax.Array:
    """GQA attention.  q: (B, S, H, Dh), k/v: (B, S, KVH, Dh).

    `q_chunk > 0` enables row-blocked (flash-style) execution: exact
    softmax per query block, O(S * q_chunk) score memory instead of
    O(S^2) — required for the 32k prefill shapes.
    """
    B, S, H, Dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, S, KVH, G, Dh)

    if q_chunk and S > q_chunk and S % q_chunk == 0:
        nc = S // q_chunk

        def body(carry, qc):
            qblk, off = qc
            m = (_mask(q_chunk, k.shape[1], off, causal=causal,
                       window=window) if (causal or window) else None)
            return carry, _attend_block(qblk, k, v, m)

        q_chunks = qg.reshape(B, nc, q_chunk, KVH, G, Dh).transpose(
            1, 0, 2, 3, 4, 5)
        offs = jnp.arange(nc) * q_chunk
        _, outs = jax.lax.scan(body, None, (q_chunks, offs), unroll=unroll)
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KVH, G, Dh)
    else:
        m = _mask(S, S, 0, causal=causal, window=window) if (
            causal or window) else None
        out = _attend_block(qg, k, v, m)
    return out.reshape(B, S, H, Dh)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_pos: jax.Array, *, window: int = 0) -> jax.Array:
    """One-token attention over a (possibly longer-than-valid) KV cache.

    q: (B, 1, H, Dh); caches: (B, S, KVH, Dh); cur_pos: scalar int32 —
    number of valid cache positions (the new token's k/v already written).
    """
    B, _, H, Dh = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qg = q.reshape(B, 1, KVH, G, Dh)
    scale = Dh ** -0.5
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(S)[None, None, None, None, :]
    valid = kpos < cur_pos
    if window > 0:
        valid = valid & (kpos > cur_pos - 1 - window)
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def swiglu(x: jax.Array, w_gate: jax.Array, w_in: jax.Array,
           w_out: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_in)
    return h @ w_out


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w_in + b_in, approximate=True) @ w_out + b_out
