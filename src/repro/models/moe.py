"""Mixture-of-Experts FFN with gather/scatter dispatch (no one-hot matmul
dispatch: slot indices are built with a scatter and tokens move via gather,
so dispatch costs memory bandwidth, not MXU FLOPs — the same lesson as the
paper's CalculateLeafValues: keep the matrix engine for useful math).

Token-choice top-k routing with per-group capacity (drops overflow, like
Switch/GShard).  Expert weights carry a leading E axis that shards over
the "model" mesh axis when n_experts divides it (EP, e.g. kimi 384/16);
otherwise d_ff shards instead (TP-in-expert, e.g. mixtral E=8 < 16) — the
choice is a config flag consumed by distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array          # load-balance loss (Switch-style)
    drop_frac: jax.Array         # fraction of selections dropped


def moe_ffn(x: jax.Array, router_w: jax.Array, w_gate: jax.Array,
            w_in: jax.Array, w_out: jax.Array, *, top_k: int,
            group_size: int = 1024, capacity_factor: float = 1.25
            ) -> tuple[jax.Array, MoEMetrics]:
    """x: (T, D) tokens -> (T, D).  Experts: w_* have leading E axis.

    Pipeline: route -> sort-free slotting (scatter slot table) ->
    gather-dispatch -> grouped expert matmuls -> gather-combine.
    """
    T, D = x.shape
    E, _, F = w_gate.shape
    k = top_k
    G = max(1, T // group_size)
    S = T // G                                           # tokens per group
    C = max(k, int(S * k / E * capacity_factor))         # capacity per group

    xg = x.reshape(G, S, D)
    logits = (xg.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)              # (G, S, E)
    top_p, top_e = jax.lax.top_k(probs, k)               # (G, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Position of each selection within its expert queue (per group):
    # rank via cumsum over the flattened (S*k) selection order.
    sel_onehot = jax.nn.one_hot(top_e.reshape(G, S * k), E,
                                dtype=jnp.int32)         # (G, S*k, E)
    pos = jnp.cumsum(sel_onehot, axis=1) - sel_onehot    # selections before
    pos = jnp.take_along_axis(
        pos, top_e.reshape(G, S * k, 1), axis=2)[..., 0]  # (G, S*k)
    pos = pos.reshape(G, S, k)
    keep = pos < C                                       # (G, S, k) bool

    # Slot table: slot = e*C + pos; dropped selections target a trash slot.
    slot = jnp.where(keep, top_e * C + pos, E * C)       # (G, S, k)
    src_token = jnp.broadcast_to(jnp.arange(S)[None, :, None],
                                 (G, S, k)).astype(jnp.int32)
    # Scatter token ids into the slot table (one extra trash slot).
    table = jnp.zeros((G, E * C + 1), jnp.int32)
    table = jax.vmap(lambda t, s, v: t.at[s.reshape(-1)].set(
        v.reshape(-1)))(table, slot, src_token)          # (G, E*C+1)
    src = table[:, :E * C]                               # (G, E*C)

    # Dispatch: gather token rows -> (G, E, C, D).
    xe = jnp.take_along_axis(xg, src[:, :, None], axis=1)
    xe = xe.reshape(G, E, C, D)

    # Expert FFN: grouped matmuls (contraction per expert) on the MXU.
    h = jnp.einsum("gecd,edf->gecf", xe, w_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("gecd,edf->gecf", xe, w_in,
                   preferred_element_type=jnp.float32)
    act = (jax.nn.silu(h) * u).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", act, w_out,
                    preferred_element_type=jnp.float32).astype(x.dtype)

    # Combine: gather each selection's slot output, weight, sum over k.
    ye_flat = ye.reshape(G, E * C, D)
    ye_flat = jnp.concatenate(
        [ye_flat, jnp.zeros((G, 1, D), ye.dtype)], axis=1)  # trash slot
    sel = jnp.take_along_axis(ye_flat, slot.reshape(G, S * k)[:, :, None],
                              axis=1).reshape(G, S, k, D)
    w = (top_p * keep).astype(x.dtype)                   # (G, S, k)
    y = jnp.einsum("gskd,gsk->gsd", sel, w)

    # Switch load-balance aux loss: E * sum_e f_e * p_e.
    frac_sel = jnp.mean(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1, 2))
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_sel * mean_p)
    drop = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y.reshape(T, D), MoEMetrics(aux, drop)
