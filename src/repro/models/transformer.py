"""Unified model assembly for all 10 assigned architectures.

One parameter-pytree + pure-function design:
  init_params(cfg, key)          real arrays (smoke tests / examples)
  abstract_params(cfg)           ShapeDtypeStructs (dry-run, no allocation)
  forward(cfg, params, batch)    logits for training/prefill
  init_cache / prefill / decode  serving path with KV / SSM caches

Families: dense (internlm2/glm4/stablelm/granite), moe (kimi/mixtral),
ssm (mamba2), hybrid (zamba2: mamba + shared attention block every k
layers), vlm (internvl2: stub patch embeddings + decoder LM), audio
(whisper: stub frame embeddings + enc-dec).

Layer stacks are `lax.scan` over stacked parameters (bounded HLO size for
88-layer / 1T-param lowering) with optional remat.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as ll
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

Params = dict
Cache = dict


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def _cast_params(cfg: ModelConfig, params: Params) -> Params:
    """Mixed precision: compute in cfg.compute_dtype (grads flow through
    the cast back to the fp32 master params)."""
    cdt = _dtype(cfg.compute_dtype)
    return jax.tree_util.tree_map(
        lambda a: a.astype(cdt)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)


# ==========================================================================
# Parameter construction
# ==========================================================================
def _attn_shapes(cfg: ModelConfig, stacked: int | None):
    hd = cfg.resolved_head_dim
    lead = (stacked,) if stacked else ()
    return {
        "attn_norm": lead + (cfg.d_model,),
        "wq": lead + (cfg.d_model, cfg.n_heads * hd),
        "wk": lead + (cfg.d_model, cfg.n_kv_heads * hd),
        "wv": lead + (cfg.d_model, cfg.n_kv_heads * hd),
        "wo": lead + (cfg.n_heads * hd, cfg.d_model),
    }


def _mlp_shapes(cfg: ModelConfig, stacked: int | None):
    lead = (stacked,) if stacked else ()
    D, F = cfg.d_model, cfg.d_ff
    if cfg.n_experts:
        E = cfg.n_experts
        return {
            "mlp_norm": lead + (D,),
            "router": lead + (D, E),
            "w_gate": lead + (E, D, F),
            "w_in": lead + (E, D, F),
            "w_out": lead + (E, F, D),
        }
    if cfg.mlp == "swiglu":
        return {"mlp_norm": lead + (D,), "w_gate": lead + (D, F),
                "w_in": lead + (D, F), "w_out": lead + (F, D)}
    return {"mlp_norm": lead + (D,), "w_in": lead + (D, F),
            "b_in": lead + (F,), "w_out": lead + (F, D),
            "b_out": lead + (D,)}


def _ssm_shapes(cfg: ModelConfig, stacked: int):
    dims = ssm_dims(cfg)
    L = stacked
    return {
        "norm": (L, cfg.d_model),
        "in_proj": (L, cfg.d_model, 2 * dims["d_inner"]
                    + 2 * dims["d_state"] + dims["n_heads"]),
        "conv_w": (L, dims["conv_width"], dims["conv_dim"]),
        "conv_b": (L, dims["conv_dim"]),
        "A_log": (L, dims["n_heads"]),
        "D_skip": (L, dims["n_heads"]),
        "dt_bias": (L, dims["n_heads"]),
        "norm_scale": (L, dims["d_inner"]),
        "out_proj": (L, dims["d_inner"], cfg.d_model),
    }


def ssm_dims(cfg: ModelConfig) -> dict:
    return ssm_lib.ssm_dims(cfg.d_model, expand=cfg.ssm_expand,
                            headdim=cfg.ssm_headdim, d_state=cfg.ssm_state)


def _eff_chunk(cfg: ModelConfig, S: int) -> int:
    """SSD chunk size: grows with S so the inter-chunk scan stays <= 128
    steps (bounds both scan latency and unrolled-probe HLO size)."""
    c = cfg.ssm_chunk
    while S > 128 * c and S % (2 * c) == 0:
        c *= 2
    return c


def param_shapes(cfg: ModelConfig, *, max_positions: int = 0) -> dict:
    """Nested dict of shapes for the whole model."""
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    tree: dict = {"embed": (V, D), "final_norm": (D,)}
    if cfg.norm == "layernorm":
        tree["final_norm_bias"] = (D,)
    if not cfg.tie_embeddings:
        tree["lm_head"] = (D, V)
    if cfg.learned_positions:
        tree["pos_embed"] = (max(max_positions, 2048), D)

    if cfg.family in ("dense", "vlm"):
        tree["blocks"] = {**_attn_shapes(cfg, L), **_mlp_shapes(cfg, L)}
    elif cfg.family == "moe":
        tree["blocks"] = {**_attn_shapes(cfg, L), **_mlp_shapes(cfg, L)}
    elif cfg.family == "ssm":
        tree["blocks"] = _ssm_shapes(cfg, L)
    elif cfg.family == "hybrid":
        tree["blocks"] = _ssm_shapes(cfg, L)
        shared = {**_attn_shapes(cfg, None),
                  "mlp_norm": (D,), "w_gate": (D, cfg.d_ff),
                  "w_in": (D, cfg.d_ff), "w_out": (cfg.d_ff, D)}
        tree["shared_attn"] = shared
    elif cfg.family == "audio":
        enc: dict = {**_attn_shapes(cfg, cfg.encoder_layers),
                     **_mlp_shapes(cfg, cfg.encoder_layers)}
        dec: dict = {**_attn_shapes(cfg, L), **_mlp_shapes(cfg, L)}
        for k, v in _attn_shapes(cfg, L).items():
            dec["x_" + k] = v
        tree["enc_blocks"] = enc
        tree["dec_blocks"] = dec
        tree["enc_final_norm"] = (D,)
        if cfg.norm == "layernorm":
            tree["enc_final_norm_bias"] = (D,)
    else:
        raise ValueError(cfg.family)
    return tree


def _init_leaf(key, path: str, shape, dtype):
    if not shape or path.endswith(("norm", "norm_scale", "D_skip", "scale")):
        return jnp.ones(shape, dtype)
    if path.endswith(("_bias", "b_in", "b_out", "conv_b")):
        return jnp.zeros(shape, dtype)
    if path.endswith("A_log"):
        H = shape[-1]
        base = jnp.log(jnp.linspace(1.0, 16.0, H))
        return jnp.broadcast_to(base, shape).astype(dtype)
    if path.endswith("dt_bias"):
        return jnp.full(shape, -1.0, dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = min(0.02, fan_in ** -0.5)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key, *, max_positions: int = 0) -> Params:
    shapes = param_shapes(cfg, max_positions=max_positions)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes,
                                                           is_leaf=lambda x:
                                                           isinstance(x, tuple))
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, (path, shape) in zip(keys, leaves):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append(_init_leaf(k, name, shape, dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(cfg: ModelConfig, *, max_positions: int = 0) -> Params:
    shapes = param_shapes(cfg, max_positions=max_positions)
    dtype = _dtype(cfg.param_dtype)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s, dtype), shapes,
        is_leaf=lambda x: isinstance(x, tuple))


# ==========================================================================
# Blocks
# ==========================================================================
def _norm(cfg, x, scale, bias=None):
    if cfg.norm == "layernorm":
        return ll.layer_norm(x, scale, bias if bias is not None
                             else jnp.zeros_like(scale))
    return ll.rms_norm(x, scale)


def _attn_block(cfg: ModelConfig, x, p, positions, *, causal=True,
                kv_override=None, mesh=None):
    """Pre-norm attention. kv_override=(k, v) for cross-attention."""
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    h = _norm(cfg, x, p["attn_norm"])
    q = (h @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if kv_override is None:
        k = (h @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
        v = (h @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
        if not cfg.learned_positions:
            q = ll.apply_rope(q, positions, cfg.rope_theta)
            k = ll.apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    use_ring = (cfg.attention_impl == "ring" and mesh is not None
                and "model" in mesh.axis_names
                and kv_override is None and causal
                and not cfg.sliding_window
                and S % mesh.shape["model"] == 0)
    if use_ring:
        from repro.distributed import collectives, sharding as shd
        dp = shd.dp_axes(mesh)
        bspec = dp if (B % max(shd.mesh_size(mesh, dp), 1) == 0 and dp)             else None
        out = collectives.ring_attention(
            mesh, dp=bspec, unroll=cfg.scan_unroll)(q, k, v)
    else:
        q_chunk = cfg.attn_chunk if S > cfg.attn_chunk_threshold else 0
        out = ll.attention(q, k, v, causal=causal and kv_override is None,
                           window=cfg.sliding_window, q_chunk=q_chunk,
                           unroll=cfg.scan_unroll)
    return x + out.reshape(B, S, -1) @ p["wo"]


def _mlp_block(cfg: ModelConfig, x, p):
    h = _norm(cfg, x, p["mlp_norm"])
    if cfg.n_experts:
        B, S, D = h.shape
        y, metrics = moe_lib.moe_ffn(
            h.reshape(B * S, D), p["router"], p["w_gate"], p["w_in"],
            p["w_out"], top_k=cfg.experts_per_token,
            group_size=cfg.moe_group_size,
            capacity_factor=cfg.moe_capacity_factor)
        return x + y.reshape(B, S, D), metrics.aux_loss
    if cfg.mlp == "swiglu":
        return x + ll.swiglu(h, p["w_gate"], p["w_in"], p["w_out"]), 0.0
    return x + ll.gelu_mlp(h, p["w_in"], p["b_in"], p["w_out"],
                           p["b_out"]), 0.0


def _ssm_params(p, idx=None) -> ssm_lib.SSMParams:
    fields = ["in_proj", "conv_w", "conv_b", "A_log", "D_skip", "dt_bias",
              "norm_scale", "out_proj"]
    vals = [p[f] if idx is None else p[f][idx] for f in fields]
    return ssm_lib.SSMParams(*vals)


# ==========================================================================
# Forward (training / prefill body)
# ==========================================================================
def _sp(cfg, mesh, x):
    """Sequence-parallel constraint: shard S over 'model' between blocks."""
    if not (cfg.sequence_parallel and mesh is not None
            and "model" in mesh.axis_names):
        return x
    if x.shape[1] % mesh.shape["model"]:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(None, "model", None)))


def _scan_blocks(cfg, x, blocks, body, mesh=None):
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    def step(carry, p):
        y, aux = body(carry[0], p)
        return (_sp(cfg, mesh, y), carry[1] + aux), None

    (x, aux), _ = jax.lax.scan(step, (_sp(cfg, mesh, x), 0.0), blocks,
                               unroll=cfg.scan_unroll)
    return x, aux


def _decoder_stack(cfg: ModelConfig, x, params, positions, mesh=None):
    """dense / moe / vlm decoder-only stack."""
    def body(h, p):
        h = _attn_block(cfg, h, p, positions, mesh=mesh)
        h, aux = _mlp_block(cfg, h, p)
        return h, aux
    return _scan_blocks(cfg, x, params["blocks"], body, mesh=mesh)


def _ssm_stack(cfg: ModelConfig, x, blocks):
    dims = ssm_dims(cfg)

    def body(h, p):
        hn = ll.rms_norm(h, p["norm"])
        return h + ssm_lib.ssd_forward(_ssm_params(p), hn, dims,
                                       chunk=_eff_chunk(cfg, hn.shape[1]),
                                       unroll=cfg.scan_unroll), 0.0
    return _scan_blocks(cfg, x, blocks, body)


def _hybrid_stack(cfg: ModelConfig, x, params, positions):
    """zamba2: mamba stack with a SHARED attention block every k layers."""
    k = cfg.attn_every
    L = cfg.n_layers
    shared = params["shared_attn"]
    blocks = params["blocks"]
    aux = 0.0
    start = 0
    while start < L:
        stop = min(start + k, L)
        seg = jax.tree_util.tree_map(lambda a: a[start:stop], blocks)
        x, a = _ssm_stack(cfg, x, seg)
        aux += a
        if stop < L or stop % k == 0:
            x = _attn_block(cfg, x, shared, positions)
            x, _ = _mlp_block(cfg, x, shared)
        start = stop
    return x, aux


def _whisper_encode(cfg: ModelConfig, params, frames):
    """frames: (B, S_f, D) stub conv-frontend output."""
    x = frames.astype(_dtype(cfg.compute_dtype))
    x = x + ll.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(h, p):
        h = _attn_block(cfg, h, p, None, causal=False)
        h, aux = _mlp_block(cfg, h, p)
        return h, aux

    x, _ = _scan_blocks(cfg, x, params["enc_blocks"], body)
    return _norm(cfg, x, params["enc_final_norm"],
                 params.get("enc_final_norm_bias"))


def _whisper_decode_stack(cfg: ModelConfig, x, params, enc_out, positions):
    hd = cfg.resolved_head_dim
    B, Se, _ = enc_out.shape

    def body(h, p):
        h = _attn_block(cfg, h, p, positions)
        # cross-attention: kv from encoder output
        xp = {k[2:]: v for k, v in p.items() if k.startswith("x_")}
        enc_h = enc_out
        xk = (enc_h @ xp["wk"]).reshape(B, Se, cfg.n_kv_heads, hd)
        xv = (enc_h @ xp["wv"]).reshape(B, Se, cfg.n_kv_heads, hd)
        h = _attn_block(cfg, h, xp, None, kv_override=(xk, xv))
        h, aux = _mlp_block(cfg, h, p)
        return h, aux

    return _scan_blocks(cfg, x, params["dec_blocks"], body)


def _embed_tokens(cfg, params, tokens, positions):
    x = params["embed"][tokens].astype(_dtype(cfg.compute_dtype))
    if cfg.learned_positions:
        pos = positions if positions is not None else jnp.arange(
            tokens.shape[1])
        x = x + params["pos_embed"][pos].astype(x.dtype)
    return x


def _logits(cfg, params, x):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def forward(cfg: ModelConfig, params: Params, batch: dict,
            mesh=None) -> tuple:
    """Training/prefill forward -> (logits, aux_loss).

    batch: tokens (B, S) [+ frontend_embeds (B, S_f, D) for vlm/audio].
    """
    params = _cast_params(cfg, params)
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    if cfg.family == "audio":
        enc_out = _whisper_encode(cfg, params, batch["frontend_embeds"])
        x = _embed_tokens(cfg, params, tokens, positions[0])
        x, aux = _whisper_decode_stack(cfg, x, params, enc_out, positions)
    elif cfg.family == "vlm":
        x_txt = _embed_tokens(cfg, params, tokens, None)
        x_img = batch["frontend_embeds"].astype(x_txt.dtype)
        x = jnp.concatenate([x_img, x_txt], axis=1)
        positions = jnp.arange(x.shape[1])[None, :].astype(jnp.int32)
        x, aux = _decoder_stack(cfg, x, params, positions, mesh=mesh)
        x = x[:, x_img.shape[1]:, :]                    # text positions only
    elif cfg.family == "ssm":
        x = _embed_tokens(cfg, params, tokens, None)
        x, aux = _ssm_stack(cfg, x, params["blocks"])
    elif cfg.family == "hybrid":
        x = _embed_tokens(cfg, params, tokens, None)
        x, aux = _hybrid_stack(cfg, x, params, positions)
    else:
        x = _embed_tokens(cfg, params, tokens, None)
        x, aux = _decoder_stack(cfg, x, params, positions, mesh=mesh)

    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_bias"))
    return _logits(cfg, params, x), aux


# ==========================================================================
# Serving: caches, prefill, decode
# ==========================================================================
def hybrid_n_apps(cfg: ModelConfig) -> int:
    """Number of shared-attention applications in the hybrid schedule."""
    n, start = 0, 0
    while start < cfg.n_layers:
        stop = min(start + cfg.attn_every, cfg.n_layers)
        if stop < cfg.n_layers or stop % cfg.attn_every == 0:
            n += 1
        start = stop
    return n


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               abstract: bool = False) -> Cache:
    hd = cfg.resolved_head_dim
    cdt = _dtype(cfg.compute_dtype)
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda s, d: jnp.zeros(s, d)))
    cache: Cache = {"pos": mk((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        kv = min(max_seq, cfg.sliding_window) if cfg.sliding_window \
            else max_seq
        cache["k"] = mk((cfg.n_layers, batch, kv, cfg.n_kv_heads, hd), cdt)
        cache["v"] = mk((cfg.n_layers, batch, kv, cfg.n_kv_heads, hd), cdt)
    elif cfg.family == "audio":
        cache["k"] = mk((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd),
                        cdt)
        cache["v"] = mk((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd),
                        cdt)
        cache["xk"] = mk((cfg.n_layers, batch, cfg.frontend_seq,
                          cfg.n_kv_heads, hd), cdt)
        cache["xv"] = mk((cfg.n_layers, batch, cfg.frontend_seq,
                          cfg.n_kv_heads, hd), cdt)
    if cfg.family in ("ssm", "hybrid"):
        dims = ssm_dims(cfg)
        cache["h"] = mk((cfg.n_layers, batch, dims["n_heads"],
                         dims["d_state"], dims["headdim"]), jnp.float32)
        cache["conv"] = mk((cfg.n_layers, batch, dims["conv_width"] - 1,
                            dims["conv_dim"]), cdt)
    if cfg.family == "hybrid":
        n_apps = hybrid_n_apps(cfg)
        cache["ak"] = mk((n_apps, batch, max_seq, cfg.n_kv_heads, hd), cdt)
        cache["av"] = mk((n_apps, batch, max_seq, cfg.n_kv_heads, hd), cdt)
    return cache


def _decode_attn_block(cfg, x, p, kc, vc, pos, mesh=None):
    """One-token attention with cache update. x: (B, 1, D)."""
    hd = cfg.resolved_head_dim
    B = x.shape[0]
    S = kc.shape[1]
    h = _norm(cfg, x, p["attn_norm"])
    q = (h @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k = (h @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v = (h @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    if not cfg.learned_positions:
        pvec = jnp.full((B, 1), pos, jnp.int32)
        q = ll.apply_rope(q, pvec, cfg.rope_theta)
        k = ll.apply_rope(k, pvec, cfg.rope_theta)
    # SWA: ring-buffer write; full: linear write.
    slot = (pos % S) if cfg.sliding_window else jnp.minimum(pos, S - 1)
    kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                      (0, slot, 0, 0))
    valid = jnp.minimum(pos + 1, S) if cfg.sliding_window else pos + 1
    if cfg.flash_decode and mesh is not None:
        from repro.distributed import collectives, sharding as shd
        dp = shd.dp_axes(mesh)
        bspec = dp if (B % max(shd.mesh_size(mesh, dp), 1) == 0 and dp) \
            else None
        fd = collectives.flash_decode(mesh, dp=bspec)
        out = fd(q[:, 0], kc, vc, valid)[:, None]
    else:
        out = ll.decode_attention(q, kc, vc, valid)
    return x + out.reshape(B, 1, -1) @ p["wo"], kc, vc


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                tokens: jax.Array, *, mesh=None) -> tuple[jax.Array, Cache]:
    """tokens: (B, 1) -> (logits (B, 1, V), updated cache)."""
    params = _cast_params(cfg, params)
    pos = cache["pos"]
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(_dtype(cfg.compute_dtype))
    if cfg.learned_positions:
        x = x + params["pos_embed"][pos][None, None, :].astype(x.dtype)
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, inp):
            p, kc, vc = inp
            h, kc, vc = _decode_attn_block(cfg, h, p, kc, vc, pos,
                                           mesh=mesh)
            h, aux = _mlp_block(cfg, h, p)
            return h, (kc, vc)

        def step(carry, inp):
            h, _ = carry
            h, kv = body(h, inp)
            return (h, 0.0), kv

        (x, _), (nk, nv) = jax.lax.scan(
            step, (x, 0.0), (params["blocks"], cache["k"], cache["v"]),
            unroll=cfg.scan_unroll)
        new_cache.update(k=nk, v=nv)

    elif cfg.family == "ssm":
        dims = ssm_dims(cfg)

        def step(h, inp):
            p, hc, cc = inp
            hn = ll.rms_norm(h, p["norm"])
            y, c2 = ssm_lib.ssd_decode_step(
                _ssm_params(p), hn, ssm_lib.SSMCache(hc, cc), dims)
            return h + y, (c2.h, c2.conv)

        x, (nh, nconv) = jax.lax.scan(
            step, x, (params["blocks"], cache["h"], cache["conv"]),
            unroll=cfg.scan_unroll)
        new_cache.update(h=nh, conv=nconv)

    elif cfg.family == "hybrid":
        dims = ssm_dims(cfg)
        k_every = cfg.attn_every
        L = cfg.n_layers
        shared = params["shared_attn"]
        nh, nconv = [], []
        nak, nav = [], []
        app = 0
        start = 0
        while start < L:
            stop = min(start + k_every, L)
            for i in range(start, stop):
                p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                hn = ll.rms_norm(x, p["norm"])
                y, c2 = ssm_lib.ssd_decode_step(
                    _ssm_params(p), hn,
                    ssm_lib.SSMCache(cache["h"][i], cache["conv"][i]), dims)
                x = x + y
                nh.append(c2.h)
                nconv.append(c2.conv)
            if stop < L or stop % k_every == 0:
                x, kc, vc = _decode_attn_block(
                    cfg, x, shared, cache["ak"][app], cache["av"][app],
                    pos, mesh=mesh)
                x, _ = _mlp_block(cfg, x, shared)
                nak.append(kc)
                nav.append(vc)
                app += 1
            start = stop
        new_cache.update(h=jnp.stack(nh), conv=jnp.stack(nconv),
                         ak=jnp.stack(nak), av=jnp.stack(nav))

    elif cfg.family == "audio":
        def step(carry, inp):
            h = carry
            p, kc, vc, xk, xv = inp
            h, kc, vc = _decode_attn_block(cfg, h, p, kc, vc, pos,
                                           mesh=mesh)
            xp = {k[2:]: v for k, v in p.items() if k.startswith("x_")}
            hq = _norm(cfg, h, xp["attn_norm"])
            hd_ = cfg.resolved_head_dim
            q = (hq @ xp["wq"]).reshape(B, 1, cfg.n_heads, hd_)
            out = ll.decode_attention(q, xk, xv, xk.shape[1])
            h = h + out.reshape(B, 1, -1) @ xp["wo"]
            h, _ = _mlp_block(cfg, h, p)
            return h, (kc, vc)

        x, (nk, nv) = jax.lax.scan(
            step, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]), unroll=cfg.scan_unroll)
        new_cache.update(k=nk, v=nv)

    new_cache["pos"] = pos + 1
    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_bias"))
    return _logits(cfg, params, x), new_cache


def _prefill_kv(cfg, hn, p, positions, B, S):
    hd = cfg.resolved_head_dim
    k = (hn @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (hn @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if not cfg.learned_positions:
        k = ll.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _store_kv(cache_k, ks, S):
    """Write stacked (L, B, S, KVH, hd) prefill k/v into the cache."""
    kv_len = cache_k.shape[2]
    if kv_len >= S:
        return jax.lax.dynamic_update_slice(
            cache_k, ks.astype(cache_k.dtype), (0, 0, 0, 0, 0))
    return ks[:, :, S - kv_len:, :, :].astype(cache_k.dtype)  # SWA tail


def prefill(cfg: ModelConfig, params: Params, batch: dict,
            max_seq: int, mesh=None) -> tuple[jax.Array, Cache]:
    """Full-sequence forward filling the serving cache.

    Returns (last-position logits, cache).  For vlm, batch carries
    frontend_embeds prepended to the token sequence (total length must be
    <= max_seq); for audio, frontend_embeds feed the encoder and the
    cross-attention KV is precomputed here.
    """
    params = _cast_params(cfg, params)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_seq)

    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.family == "vlm":
            x_txt = _embed_tokens(cfg, params, tokens, None)
            x_img = batch["frontend_embeds"].astype(x_txt.dtype)
            x = jnp.concatenate([x_img, x_txt], axis=1)
        else:
            x = _embed_tokens(cfg, params, tokens,
                              jnp.arange(S).astype(jnp.int32))
        St = x.shape[1]
        positions = jnp.arange(St)[None, :].astype(jnp.int32)

        def step(h, p):
            hn = _norm(cfg, h, p["attn_norm"])
            k, v = _prefill_kv(cfg, hn, p, positions, B, St)
            h = _attn_block(cfg, h, p, positions, mesh=mesh)
            h, _ = _mlp_block(cfg, h, p)
            return _sp(cfg, mesh, h), (k, v)

        x, (ks, vs) = jax.lax.scan(step, _sp(cfg, mesh, x),
                                   params["blocks"],
                                   unroll=cfg.scan_unroll)
        cache["k"] = _store_kv(cache["k"], ks, St)
        cache["v"] = _store_kv(cache["v"], vs, St)
        cache["pos"] = jnp.asarray(St, jnp.int32)

    elif cfg.family == "ssm":
        x = _embed_tokens(cfg, params, tokens, None)
        dims = ssm_dims(cfg)

        def step(h, p):
            hn = ll.rms_norm(h, p["norm"])
            y, c = ssm_lib.ssd_forward(_ssm_params(p), hn, dims,
                                       chunk=_eff_chunk(cfg, hn.shape[1]),
                                       return_cache=True,
                                       unroll=cfg.scan_unroll)
            return h + y, (c.h, c.conv)

        x, (hs, convs) = jax.lax.scan(step, x, params["blocks"],
                                      unroll=cfg.scan_unroll)
        cache["h"], cache["conv"] = hs, convs
        cache["pos"] = jnp.asarray(S, jnp.int32)

    elif cfg.family == "hybrid":
        x = _embed_tokens(cfg, params, tokens, None)
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
        dims = ssm_dims(cfg)
        shared = params["shared_attn"]
        hs, convs, aks, avs = [], [], [], []
        start = 0
        while start < cfg.n_layers:
            stop = min(start + cfg.attn_every, cfg.n_layers)
            for i in range(start, stop):
                p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                hn = ll.rms_norm(x, p["norm"])
                y, c = ssm_lib.ssd_forward(_ssm_params(p), hn, dims,
                                           chunk=_eff_chunk(cfg, hn.shape[1]),
                                           return_cache=True,
                                           unroll=cfg.scan_unroll)
                x = x + y
                hs.append(c.h)
                convs.append(c.conv)
            if stop < cfg.n_layers or stop % cfg.attn_every == 0:
                hn = _norm(cfg, x, shared["attn_norm"])
                k, v = _prefill_kv(cfg, hn, shared, positions, B, S)
                aks.append(k)
                avs.append(v)
                x = _attn_block(cfg, x, shared, positions)
                x, _ = _mlp_block(cfg, x, shared)
            start = stop
        cache["h"], cache["conv"] = jnp.stack(hs), jnp.stack(convs)
        cache["ak"] = _store_kv(cache["ak"], jnp.stack(aks), S)
        cache["av"] = _store_kv(cache["av"], jnp.stack(avs), S)
        cache["pos"] = jnp.asarray(S, jnp.int32)

    elif cfg.family == "audio":
        enc_out = _whisper_encode(cfg, params, batch["frontend_embeds"])
        hd = cfg.resolved_head_dim
        Se = enc_out.shape[1]
        x = _embed_tokens(cfg, params, tokens,
                          jnp.arange(S).astype(jnp.int32))
        positions = jnp.arange(S)[None, :].astype(jnp.int32)

        def step(h, p):
            hn = _norm(cfg, h, p["attn_norm"])
            k, v = _prefill_kv(cfg, hn, p, positions, B, S)
            h = _attn_block(cfg, h, p, positions)
            xp = {kk[2:]: vv for kk, vv in p.items() if kk.startswith("x_")}
            xk = (enc_out @ xp["wk"]).reshape(B, Se, cfg.n_kv_heads, hd)
            xv = (enc_out @ xp["wv"]).reshape(B, Se, cfg.n_kv_heads, hd)
            h = _attn_block(cfg, h, xp, None, kv_override=(xk, xv))
            h, _ = _mlp_block(cfg, h, p)
            return h, (k, v, xk, xv)

        x, (ks, vs, xks, xvs) = jax.lax.scan(step, x, params["dec_blocks"],
                                             unroll=cfg.scan_unroll)
        cache["k"] = _store_kv(cache["k"], ks, S)
        cache["v"] = _store_kv(cache["v"], vs, S)
        cache["xk"], cache["xv"] = (xks.astype(cache["xk"].dtype),
                                    xvs.astype(cache["xv"].dtype))
        cache["pos"] = jnp.asarray(S, jnp.int32)
    else:
        raise ValueError(cfg.family)

    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_bias"))
    logits = _logits(cfg, params, x[:, -1:, :])
    return logits, cache
