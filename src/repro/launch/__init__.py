"""Launch layer: meshes, dry-run, roofline report, train/serve CLIs.

NOTE: do NOT import repro.launch.dryrun from here — it sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 at import time and
must only be imported by the dry-run entrypoint itself.
"""
from repro.launch import mesh  # noqa: F401
