"""Perf-regression gate over the committed `results/perf` trajectory.

The benches (`benchmarks/{scoring,predictor,training,mesh}_bench.py`)
each write one JSON per scenario into `results/perf/` — committed
full-run numbers that document the perf story PR by PR.  This gate
gives that story teeth: it re-runs the benches in `--quick` mode into
a scratch dir and compares each fresh scenario against its committed
baseline, exiting non-zero when a gated metric regressed.

What is compared (and what deliberately is not):

* **ratio metrics** (`speedup_vs_*`, `parity_ratio_*`) — scale-free,
  so a quick run on different hardware is still comparable to the
  committed full run.  Gate: fresh >= baseline * (1 - tolerance).
  The default tolerance is WIDE (0.6): quick mode uses smaller models
  whose speedups are legitimately lower, and CI boxes are noisy — the
  band catches collapse-class regressions (a 2.7x speedup falling to
  ~1x), not percent-level drift.  Tighten with --tolerance for local
  investigation.
* **error metrics** (`max_abs_err`, `*_max_abs_err*`) — fresh must
  stay within max(baseline * (1 + tol), 1e-5): parity must not rot.
* **flag metrics** (`exact`, `splits_equal_vs_*`) — a True baseline
  must stay True.
* **zero metrics** (`compiles`, `binarize_calls`, `*_dispatches`) — a
  0 baseline must stay 0 (the compiled-shape / quantized-first
  contracts).
* **absolute wall/throughput numbers** (`us_per_call`, `rows_per_s`,
  `wall_s`) are NOT gated: a quick run cannot be held to full-run
  absolutes, and machine-relative numbers do not transfer.

Scenarios with a committed baseline but no fresh quick run (e.g.
`mesh-bench__k8` — quick mode only runs K in {1,4}) are reported as
skipped, not failed.

  PYTHONPATH=src python -m repro.launch.perf_gate --quick --check
  # positive control / offline compare: gate pre-existing JSONs
  PYTHONPATH=src python -m repro.launch.perf_gate --check \
      --fresh-dir /tmp/fresh
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import tempfile
from typing import Any, Optional

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
BASELINE_DIR = REPO_ROOT / "results" / "perf"

# Gated metric families (see module docstring for the policy).
RATIO_PREFIXES = ("speedup_vs_", "parity_ratio_")
ERR_METRICS = ("max_abs_err", "leaf_max_abs_err_vs_seed")
FLAG_PREFIXES = ("exact", "splits_equal_vs_")
ZERO_METRICS = ("compiles", "binarize_calls", "boost_binarize_dispatches",
                "refit_histogram_dispatches")
ERR_FLOOR = 1e-5

RATIO_TOL = 0.6
ERR_TOL = 0.5

# bench key -> (module, baseline-file prefixes it produces)
BENCHES = {
    "scoring": ("benchmarks.scoring_bench", ("scoring-bench__",)),
    "predictor": ("benchmarks.predictor_bench",
                  ("predictor-bench__", "layout-sweep__")),
    "training": ("benchmarks.training_bench", ("training-bench__",)),
    "mesh": ("benchmarks.mesh_bench", ("mesh-bench__",)),
}


def classify(metric: str) -> Optional[str]:
    """Which gate family a scenario-JSON field belongs to (None = not
    gated)."""
    if metric.startswith(RATIO_PREFIXES):
        return "ratio"
    if metric in ERR_METRICS:
        return "err"
    if any(metric == p or metric.startswith(p) for p in FLAG_PREFIXES):
        return "flag"
    if metric in ZERO_METRICS:
        return "zero"
    return None


def load_dir(path: pathlib.Path) -> dict[str, dict[str, Any]]:
    """{scenario-file-stem: parsed JSON} for every *.json in `path`."""
    out = {}
    for p in sorted(pathlib.Path(path).glob("*.json")):
        try:
            out[p.stem] = json.loads(p.read_text())
        except ValueError as e:
            raise ValueError(f"unparseable scenario JSON {p}: {e}") from e
    return out


def compare(baselines: dict[str, dict], fresh: dict[str, dict], *,
            ratio_tol: float = RATIO_TOL, err_tol: float = ERR_TOL
            ) -> list[dict[str, Any]]:
    """Gate every baseline scenario against its fresh counterpart.

    Returns one row per (scenario, gated metric):
    {scenario, metric, kind, base, fresh, status, detail} with status
    in {"ok", "REGRESSION", "skipped"}.  Pure function of its inputs —
    the positive-control test injects fabricated fresh dicts here.
    """
    rows: list[dict[str, Any]] = []
    for name, base in sorted(baselines.items()):
        if name not in fresh:
            rows.append({"scenario": name, "metric": "-", "kind": "-",
                         "base": None, "fresh": None, "status": "skipped",
                         "detail": "no fresh quick run for this scenario"})
            continue
        got = fresh[name]
        for metric, bval in sorted(base.items()):
            kind = classify(metric)
            if kind is None:
                continue
            row = {"scenario": name, "metric": metric, "kind": kind,
                   "base": bval, "fresh": got.get(metric),
                   "status": "ok", "detail": ""}
            if metric not in got:
                row["status"] = "REGRESSION"
                row["detail"] = "metric missing from fresh run " \
                                "(schema break)"
                rows.append(row)
                continue
            fval = got[metric]
            if kind == "ratio":
                floor = float(bval) * (1.0 - ratio_tol)
                if float(fval) < floor:
                    row["status"] = "REGRESSION"
                    row["detail"] = (f"{fval:.3f} < {floor:.3f} "
                                     f"(= baseline {float(bval):.3f} "
                                     f"* {1 - ratio_tol:.2f})")
            elif kind == "err":
                cap = max(float(bval) * (1.0 + err_tol), ERR_FLOOR)
                if float(fval) > cap:
                    row["status"] = "REGRESSION"
                    row["detail"] = f"{fval:.3e} > cap {cap:.3e}"
            elif kind == "flag":
                if bool(bval) and not bool(fval):
                    row["status"] = "REGRESSION"
                    row["detail"] = f"baseline {metric}={bval} " \
                                    f"degraded to {fval}"
            elif kind == "zero":
                if int(bval) == 0 and int(fval) != 0:
                    row["status"] = "REGRESSION"
                    row["detail"] = f"baseline 0 grew to {fval}"
            rows.append(row)
    return rows


def run_benches(bench_keys: list[str], out_dir: pathlib.Path, *,
                quick: bool = True) -> None:
    """Run each bench as a subprocess writing scenario JSONs into
    `out_dir` (fresh interpreter per bench: jit caches and dispatch
    counters cannot leak between scenarios or from the gate itself)."""
    env_path = f"{REPO_ROOT / 'src'}:{REPO_ROOT}"
    for key in bench_keys:
        mod, _ = BENCHES[key]
        cmd = [sys.executable, "-m", mod, "--out-dir", str(out_dir)]
        if quick:
            cmd.append("--quick")
        print(f"[perf-gate] running {' '.join(cmd[1:])}", file=sys.stderr)
        proc = subprocess.run(
            cmd, cwd=REPO_ROOT, capture_output=True, text=True,
            env={**__import__('os').environ, "PYTHONPATH": env_path})
        if proc.returncode != 0:
            raise RuntimeError(
                f"bench {mod} exited {proc.returncode}:\n"
                f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


def format_report(rows: list[dict[str, Any]]) -> str:
    lines = []
    for r in rows:
        if r["status"] == "ok":
            continue
        lines.append(f"  {r['status']:<10} {r['scenario']}:{r['metric']} "
                     f"{r['detail']}")
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_bad = sum(r["status"] == "REGRESSION" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    lines.append(f"  {n_ok} gated metrics ok, {n_bad} regressions, "
                 f"{n_skip} skipped")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.perf_gate",
        description="gate fresh --quick bench runs against the "
                    "committed results/perf baselines")
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--fresh-dir", default="",
                    help="compare scenario JSONs already in this dir "
                         "instead of running the benches (positive-"
                         "control tests, offline debugging)")
    ap.add_argument("--benches", default=",".join(BENCHES),
                    help=f"comma list from {sorted(BENCHES)}")
    ap.add_argument("--quick", action="store_true",
                    help="run benches in --quick mode (the CI setting)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any regression")
    ap.add_argument("--tolerance", type=float, default=RATIO_TOL,
                    help="ratio-metric tolerance band (default "
                         f"{RATIO_TOL}; fresh >= base*(1-t))")
    ap.add_argument("--json-out", default="",
                    help="also write the full gate report here as JSON")
    args = ap.parse_args(argv)

    keys = [k.strip() for k in args.benches.split(",") if k.strip()]
    unknown = sorted(set(keys) - set(BENCHES))
    if unknown:
        ap.error(f"unknown benches {unknown}; known: {sorted(BENCHES)}")

    baselines = load_dir(pathlib.Path(args.baseline_dir))
    if not baselines:
        print(f"[perf-gate] no baselines in {args.baseline_dir}; "
              "nothing to gate", file=sys.stderr)
        return 0
    # only gate baselines the selected benches can reproduce
    prefixes = tuple(p for k in keys for p in BENCHES[k][1])
    gated = {n: b for n, b in baselines.items()
             if n.startswith(prefixes)}
    ungated = sorted(set(baselines) - set(gated))
    if ungated:
        print(f"[perf-gate] not gated (no selected bench writes them): "
              f"{ungated}", file=sys.stderr)

    if args.fresh_dir:
        fresh = load_dir(pathlib.Path(args.fresh_dir))
    else:
        with tempfile.TemporaryDirectory(prefix="perf-gate-") as td:
            run_benches(keys, pathlib.Path(td), quick=args.quick)
            fresh = load_dir(pathlib.Path(td))

    rows = compare(gated, fresh, ratio_tol=args.tolerance)
    print(format_report(rows), file=sys.stderr)
    if args.json_out:
        out = pathlib.Path(args.json_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rows, indent=1, default=str))
    regressed = any(r["status"] == "REGRESSION" for r in rows)
    if regressed:
        print("[perf-gate] REGRESSION: fresh quick run fell outside "
              "the tolerance band of the committed baselines",
              file=sys.stderr)
        return 1 if args.check else 0
    print("[perf-gate] ok: fresh quick run within tolerance of "
          "committed baselines", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
