"""Assemble EXPERIMENTS.md from results/ JSONs + benchmark CSV.

  PYTHONPATH=src python -m repro.launch.report > EXPERIMENTS.md
"""
from __future__ import annotations

import json
import pathlib

from repro import configs
from repro.configs.base import SHAPES, SUBQUADRATIC, applicable_shapes
from repro.launch import roofline as rl
from repro.launch.hlo_analysis import HBM_BW, LINK_BW, PEAK_FLOPS

ROOT = pathlib.Path(__file__).resolve().parents[3]
RESULTS = ROOT / "results"


def _load(path):
    return json.loads(path.read_text()) if path.exists() else None


def _fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_section() -> list[str]:
    out = ["## §Dry-run", ""]
    out.append(
        "Every applicable (architecture × shape) cell was lowered AND "
        "compiled with `jax.jit(step, in_shardings=…, out_shardings=…)"
        ".lower(**input_specs).compile()` on both production meshes — "
        "16×16 = 256 chips (`data`,`model`) and 2×16×16 = 512 chips "
        "(`pod`,`data`,`model`). `long_500k` runs for the sub-quadratic "
        f"archs {sorted(SUBQUADRATIC)} and is skipped for pure "
        "full-attention archs (DESIGN.md §Arch-applicability). "
        "`train_*` lowers `train_step` (fwd+bwd+optimizer), `prefill_*` "
        "lowers the cache-filling prefill, `decode_*`/`long_*` lower "
        "`serve_step` (one token against the KV/SSM cache).")
    out.append("")
    for mp, label in ((False, "single-pod 16×16 (256 chips)"),
                      (True, "multi-pod 2×16×16 (512 chips)")):
        out.append(f"### Mesh {label}")
        out.append("")
        out.append("| arch | shape | status | compile | HLO bytes/dev "
                   "(rolled) | args bytes/dev | temp bytes/dev | "
                   "collectives seen |")
        out.append("|---|---|---|---|---|---|---|---|")
        n_ok = n_total = 0
        for arch, cfg in configs.ARCHS.items():
            for shp in applicable_shapes(cfg):
                n_total += 1
                pod = "multipod" if mp else "singlepod"
                c = _load(RESULTS / "dryrun" / f"{arch}__{shp}__{pod}.json")
                if c is None:
                    out.append(f"| {arch} | {shp} | MISSING | | | | | |")
                    continue
                if c.get("status") != "ok":
                    out.append(f"| {arch} | {shp} | ERROR | "
                               f"{str(c.get('error',''))[:70]} | | | | |")
                    continue
                n_ok += 1
                mem = c.get("memory_analysis", {})
                kinds = [k for k in c["collective_bytes"]
                         if k != "total" and c["collective_bytes"][k] > 0]
                out.append(
                    f"| {arch} | {shp} | ok | {c['compile_seconds']}s | "
                    f"{_fmt_bytes(c['hlo_text_bytes'])} | "
                    f"{_fmt_bytes(mem.get('argument_bytes', 0))} | "
                    f"{_fmt_bytes(mem.get('temp_bytes', 0))} | "
                    f"{', '.join(sorted(kinds)) or '-'} |")
        out.append("")
        out.append(f"**{n_ok}/{n_total} cells compile.**")
        out.append("")
    out += [
        "**Fit notes.** `args bytes/dev` is the real per-device resident "
        "state (params + optimizer + inputs) and is the HBM-fit "
        "criterion: every cell is under the 16 GB v5e budget except "
        "mamba2 (13.9 GB) and zamba2 (11 GB) train cells, which sit high "
        "because Mamba TP is intentionally disabled (DESIGN §5: d_inner "
        "sharding would split B/C state projections) and their configs "
        "keep fsdp=False; setting `fsdp=True` shards Adam state over "
        "`data` exactly as measured for stablelm-12b (14 GB → 1.2 GB). "
        "`temp bytes/dev` comes from the CPU backend's unfused buffer "
        "accounting and massively over-states TPU temp usage (XLA:TPU "
        "fuses and reuses buffers across the layer loop); it is reported "
        "for completeness, not as a fit criterion. zamba2 compile times "
        "(50-240 s) reflect the hybrid python-segment structure "
        "(6 scans + shared attention blocks) — still a one-time cost.",
        "",
    ]
    out.append("### The paper's own model at production scale")
    out.append("")
    out.append(
        "1M×54 rows against a 10k-tree depth-8 7-class ensemble "
        "(Covertype at the paper's 10000-iteration setting): samples "
        "shard over (pod, data), trees over `model` with a psum combine; "
        "plus one full boosting iteration (histograms + oblivious split "
        "+ leaf values) on sharded rows.")
    out.append("")
    out.append("| cell | mesh | status | compute | memory | collective |"
               " useful ratio |")
    out.append("|---|---|---|---|---|---|---|")
    for cell in ("predict-1m", "train-iter"):
        for pod, label in (("singlepod", "16×16"), ("multipod", "2×16×16")):
            c = _load(RESULTS / "dryrun" / f"gbdt-{cell}__paper__{pod}.json")
            if not c:
                continue
            if c.get("status") != "ok":
                out.append(f"| gbdt-{cell} | {label} | ERROR | | | | |")
                continue
            out.append(
                f"| gbdt-{cell} | {label} | ok | {c['compute_s']*1e3:.3f}ms"
                f" | {c['memory_s']*1e3:.2f}ms | "
                f"{c['collective_s']*1e6:.2f}µs | "
                f"{c['useful_flops_ratio']:.3f} |")
    out.append("")
    out.append(
        "The predict path is memory-bound at ~13 ms/pod per 1M-row batch "
        "under the (pessimistic) CPU byte accounting — ≥78M rows/s/pod; "
        "tree-parallel psum traffic is negligible (one (rows × classes) "
        "partial sum).")
    out.append("")
    return out


def roofline_section() -> list[str]:
    out = ["## §Roofline", ""]
    out.append(
        f"Hardware model (TPU v5e per chip): {PEAK_FLOPS/1e12:.0f} TFLOP/s "
        f"bf16, {HBM_BW/1e9:.0f} GB/s HBM, {LINK_BW/1e9:.0f} GB/s/link "
        "ICI. Terms per the assignment: `compute = HLO_FLOPs/(chips·peak)`,"
        " `memory = HLO_bytes/(chips·HBM_bw)`, `collective = "
        "collective_bytes/(chips·link_bw)`; FLOPs/bytes from "
        "`compiled.cost_analysis()`, collective bytes parsed from the "
        "optimized HLO (all-gather/all-reduce/reduce-scatter/all-to-all/"
        "collective-permute result shapes).")
    out.append("")
    out.append(
        "**Methodology note (cost accounting).** XLA counts a `while` "
        "body once, so rolled scans under-report by the trip count; the "
        "numbers below come from shallow-depth UNROLLED probe compiles "
        "extrapolated linearly to full depth (layers are shape-identical;"
        " probe pairs L=2/L=4 isolate the exact per-layer cost — "
        "validated within 2.5% of a fully unrolled compile on glm4-9b). "
        "**Bias:** XLA-CPU `bytes accessed` sums operand bytes of every "
        "HLO op with no fusion modeling, so the memory term is a "
        "pessimistic upper bound (~10-100× real TPU HBM traffic for "
        "well-fused code). It is used as the optimization signal "
        "(fewer materializations ⇒ fewer bytes), not as wall-clock "
        "truth; the compute and collective terms do not suffer this "
        "bias. MODEL_FLOPS = 6·N_active·D (train), 2·N_active·D "
        "(prefill/decode).")
    out.append("")
    out.append("### Single-pod baselines (the full 33-cell table)")
    out.append("")
    cells = rl.load_cells(False)
    out.append(rl.render(cells))
    out.append("")
    ok = [c for c in cells if c.get("status") == "ok"]
    if ok:
        worst = min((c for c in ok if c["shape"] != "long_500k"),
                    key=lambda c: c["useful_flops_ratio"])
        coll = max(ok, key=lambda c: c["collective_s"])
        out.append(f"- Worst useful-FLOPs ratio: **{worst['arch']}/"
                   f"{worst['shape']}** ({worst['useful_flops_ratio']:.2f})")
        out.append(f"- Most collective-bound: **{coll['arch']}/"
                   f"{coll['shape']}** ({coll['collective_s']:.2f}s)")
        out.append(
            "- Dominant bottleneck is the memory term in every cell "
            "under this accounting; per-cell one-line diagnoses and "
            "what would move the term are in §Roofline-notes below.")
    out.append("")
    out.append("### Multi-pod (512-chip) deltas")
    out.append("")
    out.append("| arch | shape | collective Δ vs single-pod | compute/dev Δ |")
    out.append("|---|---|---|---|")
    for arch, cfg in configs.ARCHS.items():
        for shp in applicable_shapes(cfg):
            a = _load(RESULTS / "dryrun" / f"{arch}__{shp}__singlepod.json")
            b = _load(RESULTS / "dryrun" / f"{arch}__{shp}__multipod.json")
            if not (a and b and a.get("status") == b.get("status") == "ok"):
                continue
            d_coll = (b["collective_s"] / a["collective_s"]
                      if a["collective_s"] > 1e-12 else float("nan"))
            d_comp = (b["flops_per_device"] / a["flops_per_device"]
                      if a["flops_per_device"] else float("nan"))
            out.append(f"| {arch} | {shp} | {d_coll:.2f}× | {d_comp:.2f}× |")
    out.append("")
    return out


def roofline_notes() -> list[str]:
    out = ["### §Roofline-notes (per-cell diagnosis)", ""]
    notes = {
        ("dense", "train_4k"): "params+activation traffic; remat "
            "recompute shows in FLOPs ratio ≈0.7 (8/6·N·D + attention). "
            "Move it down: less remat, fused attention kernel.",
        ("dense", "prefill_32k"): "q-chunked attention materializes "
            "score blocks; ratio ≈0.5 from attention FLOPs (not in 2·N·D)."
            " Move: bigger chunks, flash kernel.",
        ("dense", "decode_32k"): "KV-cache reads dominate (one token of "
            "matmuls vs 32k×KV bytes): legitimately memory-bound; move: "
            "flash-decode (no gathered-KV materialization), KV quant.",
        ("moe", "train_4k"): "expert weight all-gathers (FSDP) + dispatch "
            "gathers; move: expert2d sharding (§Perf), int8 collectives.",
        ("ssm", "train_4k"): "SSD intra-chunk (B,nc,Q,Q,H) decay tensors "
            "in fp32 dominate bytes; move: bf16 intra-chunk, smaller Q.",
        ("ssm", "long_500k"): "state-only decode: tiny absolute terms; "
            "bound by (B,H,N,P) state read/write per layer.",
    }
    out.append("| family | shape | diagnosis |")
    out.append("|---|---|---|")
    for (fam, shp), note in notes.items():
        out.append(f"| {fam} | {shp} | {note} |")
    out.append("")
    return out


def perf_section() -> list[str]:
    out = ["## §Perf — hillclimbing log", ""]
    out.append(
        "Three cells per the assignment: most collective-bound "
        "(kimi-k2/train_4k), worst useful-FLOPs ratio "
        "(internvl2/prefill_32k), and the cell most representative of "
        "the paper's batched-inference technique (internlm2/decode_32k)."
        " Each variant records hypothesis → change → before/after → "
        "verdict. The paper-faithful baseline and beyond-paper optimized "
        "rows are kept separately.")
    out.append("")
    perf_dir = RESULTS / "perf"
    if not perf_dir.exists():
        out.append("_(perf results pending)_")
        return out
    from repro.launch.perf import CELLS
    for cell, spec in CELLS.items():
        out.append(f"### {cell} ({spec['arch']} × {spec['shape']})")
        out.append("")
        base = None
        rows = []
        for name, _, hyp in spec["variants"]:
            r = _load(perf_dir / f"{cell}__{name}.json")
            if r is None:
                continue
            if r.get("status") != "ok":
                rows.append((name, hyp, None, r.get("error", "?")))
                continue
            if name == "baseline":
                base = r
            rows.append((name, hyp, r, None))
        out.append("| variant | compute | memory | collective | vs "
                   "baseline dominant | verdict |")
        out.append("|---|---|---|---|---|---|")
        for name, hyp, r, err in rows:
            if r is None:
                out.append(f"| {name} | - | - | - | - | ERROR {err[:60]} |")
                continue
            if base is None or r is base:
                delta = "—"
                verdict = "baseline"
            else:
                dom = base["dominant"]
                d = r[dom] / base[dom] if base[dom] > 1e-12 else 1.0
                delta = f"{(1-d)*100:+.1f}% {dom[:-2]}"
                verdict = ("**confirmed**" if d < 0.95 else
                           ("refuted (regression)" if d > 1.05
                            else "≈neutral"))
            out.append(f"| {name} | {r['compute_s']:.3g}s | "
                       f"{r['memory_s']:.3g}s | {r['collective_s']:.3g}s |"
                       f" {delta} | {verdict} |")
        out.append("")
        for name, hyp, r, err in rows:
            out.append(f"- **{name}** — hypothesis: {hyp}")
        out.append("")
    out += [
        "### Lessons (hypothesis → measurement, across cells)", "",
        "1. **Ring attention is the one order-of-magnitude win** "
        "(internvl2 prefill: compute 0.99s→0.076s, memory 32.7s→2.2s, "
        "13-15×). When head counts (14, 12) cannot shard a 16-way model "
        "axis, shard the *sequence* and rotate KV blocks — the same "
        "inversion as the paper's CalculateLeafValues lesson: don't force "
        "data through a unit that can't use it; restructure so the unit "
        "you have (here: the ring of chips) does dense, even work.",
        "2. **expert2d refuted at 1M tokens/step**: replacing FSDP expert-"
        "weight all-gathers with activation reshards tripled collective "
        "bytes — top-8 routing makes activations (≈1.25·k·T·D) far larger "
        "than per-layer expert weights. The crossover favors weight-"
        "gathering at large batch; activation-sharding only pays at small "
        "per-step token counts.",
        "3. **XLA GSPMD already emits the flash-decode schedule** for a "
        "sequence-sharded KV cache (explicit shard_map flash-decode "
        "changed collective bytes by <4%). Verify before reimplementing "
        "what the partitioner already does — a refuted hypothesis that "
        "saved a kernel.",
        "4. **Full remat costs ~21% of every term** on kimi-train "
        "(recompute includes re-running MoE dispatch collectives). "
        "`dots` policy recovered only ~1-3% under this accounting; "
        "no-remat is the big win but its ~28 GB/device live activations "
        "do not fit v5e HBM — remat stays, recorded as the price of "
        "fitting.",
        "5. **Routing group size is flat** (moe-group-4096 ≈ +1%): "
        "dispatch slot-table overheads are not a bottleneck at this "
        "scale.",
        "6. **Stopping rule**: three consecutive <5% changes on the "
        "dominant term (remat-dots, moe-group-4096, flash-decode) ended "
        "each cell's climb; the confirmed wins (ring attention; no-remat "
        "where it fits) are recorded as the beyond-paper configuration.",
        "",
    ]
    return out


def bench_section() -> list[str]:
    out = ["## §Paper tables (CPU-analog reproduction)", ""]
    out.append(
        "The paper's speedups are RVV-vectorized vs scalar C++ on a "
        "C910. The CPU analog here: jitted vectorized jnp (the same math"
        " the Pallas TPU kernels execute — pinned to the oracle by "
        "interpret-mode tests) vs jitted scalar `fori_loop` nests, both "
        "through XLA on the same host, isolating vectorization exactly "
        "as the paper does. See bench_output.txt for the CSV; summary:")
    out.append("")
    bench = ROOT / "bench_output.txt"
    if bench.exists():
        out.append("```")
        out.extend(bench.read_text().strip().splitlines())
        out.append("```")
    else:
        out.append("_(run `python -m benchmarks.run` to regenerate)_")
    out.append("")
    return out


def main():
    lines = ["# EXPERIMENTS", ""]
    lines.append(
        "Reproduction + performance report for the CatBoost RVV "
        "vectorization paper on the TPU-v5e-targeted JAX framework. "
        "Companion docs: DESIGN.md (architecture), README.md (usage).")
    lines.append("")
    lines += bench_section()
    lines += dryrun_section()
    lines += roofline_section()
    lines += roofline_notes()
    lines += perf_section()
    print("\n".join(lines))


if __name__ == "__main__":
    main()
