"""Roofline report generator: reads the dry-run JSON cells and renders
the EXPERIMENTS.md SSRoofline table.

Usage:  python -m repro.launch.roofline [--multi-pod] [--markdown]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro import configs
from repro.configs.base import SHAPES, applicable_shapes

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load_cells(multi_pod: bool) -> list[dict]:
    out = []
    pod = "multipod" if multi_pod else "singlepod"
    for arch, cfg in configs.ARCHS.items():
        for shp in applicable_shapes(cfg):
            p = RESULTS / f"{arch}__{shp}__{pod}.json"
            if p.exists():
                out.append(json.loads(p.read_text()))
            else:
                out.append({"arch": arch, "shape": shp,
                            "status": "missing"})
    return out


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def render(cells: list[dict], markdown: bool = True) -> str:
    lines = []
    if markdown:
        lines.append("| arch | shape | compute | memory | collective |"
                     " dominant | MODEL/HLO FLOPs | roofline frac |")
        lines.append("|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | "
                         f"{c.get('status')} {str(c.get('error',''))[:60]} |"
                         " | | | | |" if markdown else
                         f"{c['arch']} {c['shape']} {c.get('status')}")
            continue
        dom = c["dominant"].replace("_s", "")
        row = (c["arch"], c["shape"], _fmt_s(c["compute_s"]),
               _fmt_s(c["memory_s"]), _fmt_s(c["collective_s"]), dom,
               f"{c['useful_flops_ratio']:.2f}",
               f"{c['roofline_fraction']*100:.1f}%")
        if markdown:
            lines.append("| " + " | ".join(row) + " |")
        else:
            lines.append("  ".join(f"{v:>14s}" for v in row))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.multi_pod)
    print(render(cells))
    ok = [c for c in cells if c.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda c: c["roofline_fraction"])
        collective = max(ok, key=lambda c: c["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}"
              f" ({worst['roofline_fraction']*100:.2f}%)")
        print(f"most collective-bound: {collective['arch']}/"
              f"{collective['shape']} ({_fmt_s(collective['collective_s'])})")


if __name__ == "__main__":
    main()
