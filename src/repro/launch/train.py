"""Production training launcher.

On a real multi-pod TPU deployment every host runs:

    python -m repro.launch.train --arch kimi-k2-1t-a32b --multi-pod \
        --coordinator $COORD --process-id $ID --num-processes $N

`jax.distributed.initialize` wires the hosts into one runtime; the mesh
spans all 512 chips; the Trainer handles checkpoints/auto-resume so a
preempted host rejoins by simply re-running this command (elastic
restarts re-shard the logical checkpoint onto whatever mesh comes up).

On this CPU container it runs the same code path on a local mesh with a
reduced (smoke) config — pass --smoke (default) or --dry-run to lower
the full config instead of executing it.
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--coordinator", default="")
    ap.add_argument("--process-id", type=int, default=-1)
    ap.add_argument("--num-processes", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    if args.coordinator and args.num_processes > 0:
        import jax
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id)

    from repro import configs
    from repro.data.pipeline import Prefetcher, TokenSource
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = configs.get(args.arch, smoke=args.smoke)
    import jax
    n_local = len(jax.devices())
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if n_local >= 256 else make_local_mesh())

    ts = TokenSource(cfg.vocab_size, args.seq_len, args.batch)

    def stream():
        step = 0
        while True:
            b = ts.next_batch(step)
            if cfg.frontend:
                b["frontend_embeds"] = np.zeros(
                    (args.batch, cfg.frontend_seq, cfg.d_model), np.float32)
            yield b
            step += 1

    tr = Trainer(cfg, mesh, args.ckpt_dir,
                 TrainerConfig(total_steps=args.steps, ckpt_every=25))
    tr.init_or_restore()
    hist = tr.train(Prefetcher(stream(), depth=2))
    if hist:
        print(f"[train] {cfg.name}: step {tr.step}, "
              f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}, "
              f"stragglers {len(tr.straggler_steps)}")


if __name__ == "__main__":
    main()
