import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""SSPerf hillclimbing driver.

Runs named optimization variants on the three chosen cells, re-lowers,
re-derives the roofline terms, and records hypothesis -> change ->
before -> after per variant into results/perf/.

  python -m repro.launch.perf --cell kimi-train [--variant expert2d]
  python -m repro.launch.perf --all
"""
import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "perf"

# (variant name, cfg overrides, hypothesis text)
CELLS = {
    "kimi-train": {
        "arch": "kimi-k2-1t-a32b", "shape": "train_4k",
        "variants": [
            ("baseline", {},
             "paper-faithful baseline: EP(model) + FSDP(data) experts"),
            ("expert2d", {"moe_shard": "expert2d"},
             "FSDP all-gathers ~2 GB of expert weights per layer per step;"
             " sharding d_ff over 'data' (weights fully sharded, never"
             " gathered) trades them for smaller activation reshards:"
             " expect collective bytes to drop several x"),
            ("no-remat", {"remat": False},
             "remat recomputes the fwd pass inside bwd: expect ~25% fewer"
             " FLOPs and fewer memory ops, at higher live-activation"
             " memory (temp bytes up)"),
            ("remat-dots", {"remat_policy": "dots"},
             "middle ground: save matmul outputs, recompute elementwise"
             " only - expect most of no-remat's byte win while keeping"
             " live activations bounded (no-remat's 28GB/dev activations"
             " do not fit v5e HBM; this should)"),
            ("moe-group-4096", {"moe_group_size": 4096},
             "larger routing groups -> fewer groups x bigger capacity"
             " slack: slightly fewer dispatch ops, bigger slot buffers;"
             " expect small memory-term change, informative either way"),
        ],
    },
    "internvl2-prefill": {
        "arch": "internvl2-1b", "shape": "prefill_32k",
        "variants": [
            ("baseline", {},
             "paper-faithful baseline: q-chunked attention, chunk=1024"),
            ("chunk-4096", {"attn_chunk": 4096},
             "14 heads don't shard on the 16-way model axis, so every"
             " device re-runs full attention; bigger q-chunks amortize"
             " per-chunk mask/softmax overheads and intermediate"
             " materialization: expect memory term down"),
            ("chunk-512", {"attn_chunk": 512},
             "counter-probe: smaller chunks shrink live buffers but add"
             " per-chunk overhead ops; expect memory term UP (confirms"
             " the chunk-size direction)"),
            ("no-remat", {"remat": False},
             "prefill is inference: remat buys nothing (no bwd) but the"
             " policy still wraps the scan body; expect fewer bytes"),
            ("ring-attention", {"attention_impl": "ring"},
             "the correct sequence-parallel attention: Q/K/V sharded on S"
             " over 'model', KV blocks ppermute around the ring with an"
             " online softmax. Each shard computes S/16 of the queries -"
             " the 16x GSPMD replication disappears: expect compute AND"
             " memory terms down ~an order of magnitude"),
            ("seq-parallel", {"sequence_parallel": True},
             "diagnosis: 14 heads cannot shard the 16-way model axis, so"
             " GSPMD REPLICATES the whole forward on every model shard"
             " (useful-FLOPs ratio 0.01 = ~16x redundancy + attention)."
             " Sequence parallelism shards the 32k sequence over 'model'"
             " between blocks: expect compute and memory terms to drop"
             " up to ~16x (attention still gathers around the block)"),
        ],
    },
    "internlm2-decode": {
        "arch": "internlm2-20b", "shape": "decode_32k",
        "variants": [
            ("baseline", {},
             "paper-faithful baseline: plain decode attention; XLA"
             " all-gathers the seq-sharded KV cache every layer"),
            ("flash-decode", {"flash_decode": True},
             "beyond-paper: shard_map flash-decode computes partial"
             " softmax per KV shard and combines via LSE psum - the"
             " 32k-token KV all-gather disappears; expect collective"
             " bytes down >10x and memory term down (no gathered-KV"
             " materialization). Mirrors the paper's lesson inverted:"
             " keep data where it lives, move the tiny reduction"),
        ],
    },
    # The paper's own workload, driven through the prediction API: each
    # variant is one way of issuing repeated predicts against a fixed
    # model (runner="gbdt" -> timed on the ref backend in-process,
    # not a mesh dry-run).
    "gbdt-predict": {
        "runner": "gbdt",
        "variants": [
            ("kwarg-path", {"mode": "kwarg"},
             "seed behaviour: kwarg-threaded raw_predict re-resolves"
             " auto strategy/backend and re-pads the model arrays on"
             " every call - per-call work the paper hoists"),
            ("prepared-plan", {"mode": "prepared"},
             "Predictor.build resolves + pads once and dispatches"
             " through a shape-cached jitted entry: expect per-call"
             " time to drop to the kernel cost alone"),
            ("prepared-tree-block", {"mode": "prepared", "tree_block": 16},
             "CalcTreesBlockedImpl on the prepared plan: tree-block"
             " slices cut at build time; expect parity or better at"
             " equal math (blocks only pay off once leaf tables"
             " outgrow cache)"),
            ("prequantized", {"mode": "pool"},
             "quantized-first evaluation: plan.quantize(x) binarizes"
             " once into a uint8 QuantizedPool, plan.raw(pool) skips"
             " BinarizeFloatsNonSse entirely - the paper's evaluators"
             " never touch float features; expect per-call time to"
             " drop by the binarize share of the pipeline"),
        ],
    },
}


def _run_gbdt_variant(overrides: dict) -> dict:
    """Time one predict-path variant of the GBDT serving workload."""
    import time as _time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import boosting, losses, predict
    from repro.core.boosting import BoostingParams
    from repro.core.predictor import PredictConfig, Predictor
    from repro.data import synthetic

    ds = synthetic.load("covertype", scale=0.003)
    loss = losses.make_loss("multiclass", n_classes=7)
    ens, _ = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                          params=BoostingParams(n_trees=60, depth=5,
                                                learning_rate=0.3))
    xs = np.asarray(ds.x_test, np.float32)
    while len(xs) < 256:
        xs = np.concatenate([xs, xs])
    x = jnp.asarray(xs[:256])

    tree_block = int(overrides.get("tree_block", 0))
    if overrides.get("mode") == "pool":
        plan = Predictor.build(
            ens, PredictConfig(strategy="staged", backend="ref"),
            expected_batch=int(x.shape[0]))
        pool = plan.quantize(x)               # binarize ONCE, outside loop

        def fn(_xb):
            return plan.raw(pool)
    elif overrides.get("mode") == "prepared":
        plan = Predictor.build(
            ens, PredictConfig(strategy="staged", backend="ref",
                               tree_block=tree_block),
            expected_batch=int(x.shape[0]))
        fn = plan.raw
    else:
        def fn(xb):
            return predict.raw_predict(ens, xb, strategy="staged",
                                       backend="ref",
                                       tree_block=tree_block)

    jax.block_until_ready(fn(x))          # warm compile caches
    ts = []
    for _ in range(20):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(_time.perf_counter() - t0)
    return {"status": "ok", "us_per_call": float(np.median(ts)) * 1e6,
            "batch": int(x.shape[0]), "n_trees": ens.n_trees}


def run(cell: str, only_variant: str | None = None, force: bool = False):
    from repro.launch import dryrun
    spec = CELLS[cell]
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = []
    for name, overrides, hypothesis in spec["variants"]:
        if only_variant and name != only_variant:
            continue
        path = RESULTS / f"{cell}__{name}.json"
        if path.exists() and not force:
            out.append(json.loads(path.read_text()))
            continue
        try:
            if spec.get("runner") == "gbdt":
                res = _run_gbdt_variant(overrides)
            else:
                res = dryrun.analyze_cell(spec["arch"], spec["shape"],
                                          multi_pod=False,
                                          cfg_overrides=overrides)
        except Exception as e:   # record failures too: refuted != broken
            import traceback
            res = {"status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        res["variant"] = name
        res["hypothesis"] = hypothesis
        res["overrides"] = overrides
        path.write_text(json.dumps(res, indent=1, default=str))
        out.append(res)
        if res.get("status") != "ok":
            print(f"{cell:20s} {name:16s} ERROR {res.get('error','')[:120]}",
                  flush=True)
        elif "us_per_call" in res:
            print(f"{cell:20s} {name:16s} {res['us_per_call']:.0f}us/call "
                  f"batch={res['batch']}", flush=True)
        else:
            print(f"{cell:20s} {name:16s} comp={res['compute_s']:.3g}s "
                  f"mem={res['memory_s']:.3g}s coll={res['collective_s']:.3g}s"
                  f" dom={res['dominant']}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS))
    ap.add_argument("--variant")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    cells = list(CELLS) if args.all or not args.cell else [args.cell]
    for c in cells:
        run(c, args.variant, args.force)


if __name__ == "__main__":
    main()
