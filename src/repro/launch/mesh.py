"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
is the slowest (DCN) dimension and only ever carries batch-dim (data
parallel) traffic plus the gradient all-reduce.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over the actually-available devices (tests, examples)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))
