"""Serving launcher: GBDT batched scoring or LM generation.

  python -m repro.launch.serve --mode gbdt     # batched GBDT requests
  python -m repro.launch.serve --mode lm --arch glm4-9b
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def serve_gbdt(args):
    import json

    from repro.core import boosting, losses
    from repro.core.boosting import BoostingParams
    from repro.core.predictor import PredictConfig
    from repro.data import synthetic
    from repro.launch.obs_cli import finish_obs, start_tracing
    from repro.serving.engine import ModelRegistry

    start_tracing(args)
    ds = synthetic.load(args.dataset, scale=args.scale)
    loss = losses.make_loss(ds.loss, n_classes=max(ds.n_classes, 2),
                            group_index=ds.group_index_train)
    ens, _ = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                          params=BoostingParams(
                              n_trees=args.trees, depth=ds.params.depth,
                              learning_rate=0.1))
    # One PredictConfig for the registry; each server builds its
    # compiled plan from it at registration (auto resolved there).
    config = PredictConfig(strategy=args.strategy, backend=args.backend,
                           layout=args.layout,
                           tree_block=args.tree_block)
    registry = ModelRegistry(max_batch=args.batch, config=config,
                             min_bucket=args.min_bucket,
                             deadline_ms=args.deadline_ms or None)
    server = registry.register(args.dataset, ens)
    # the multi-model shared-quantizer demo: K tree-slice variants of
    # the model share its quantization schema, so predict_multi
    # binarizes each batch once for all of them (at most one variant
    # per tree)
    n_variants = min(args.multi, ens.n_trees)
    per = max(1, ens.n_trees // n_variants)
    for i in range(1, n_variants):
        registry.register(f"{args.dataset}-v{i}",
                          ens.slice_trees(i * per,
                                          min((i + 1) * per, ens.n_trees)))
    stats = server.predictor.stats
    print(f"[serve:gbdt] model={args.dataset} plan={server.config} "
          f"buckets={server.buckets} "
          f"schema={server.schema_fingerprint}")
    print(f"[serve:gbdt] layout={stats['layout']} "
          f"lowered in {stats['lower_time_s'] * 1e3:.1f}ms "
          f"({stats['build_model_pads']} model pads)")
    t0 = time.perf_counter()
    n = 200
    for i in range(n):
        registry.predict(args.dataset, ds.x_test[i % len(ds.x_test)])
    dt = time.perf_counter() - t0
    print(f"[serve:gbdt] {n} sequential requests in {dt:.2f}s; "
          f"batches={len(server.batcher.batch_sizes)}")
    if args.multi > 1:
        xs = ds.x_test[:min(len(ds.x_test), args.batch)]
        t0 = time.perf_counter()
        out = registry.predict_multi(xs)
        dt = time.perf_counter() - t0
        print(f"[serve:gbdt] predict_multi({len(xs)} rows x "
              f"{len(out)} models, quantize-once) in {dt * 1e3:.1f}ms")
    print(f"[serve:gbdt] metrics: "
          f"{json.dumps(registry.metrics()[args.dataset], default=float)}")
    finish_obs(args, {f"serving/{n}": (
        s.metrics if hasattr(s, "metrics") else s.metrics_snapshot)
        for n, s in ((n, registry.get(n)) for n in registry.names())})
    registry.close()


def serve_lm(args):
    import jax
    from repro import configs
    from repro.models import transformer as tf
    from repro.serving.engine import LMServer

    cfg = configs.get(args.arch, smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0), max_positions=256)
    server = LMServer(cfg, params, max_seq=128 + (
        cfg.frontend_seq if cfg.family == "vlm" else 0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32)
    fe = (np.zeros((2, cfg.frontend_seq, cfg.d_model), np.float32)
          if cfg.frontend else None)
    t0 = time.perf_counter()
    out = server.generate(toks, n_new=16, frontend_embeds=fe)
    dt = time.perf_counter() - t0
    print(f"[serve:lm] {cfg.name} generated {out.shape} tokens "
          f"in {dt:.2f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["gbdt", "lm"], default="gbdt")
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--dataset", default="santander")
    ap.add_argument("--scale", type=float, default=0.004)
    ap.add_argument("--trees", type=int, default=100)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--strategy", choices=["auto", "staged", "fused"],
                    default="auto")
    ap.add_argument("--backend", choices=["auto", "pallas", "ref"],
                    default="auto")
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "soa", "depth_major", "depth_grouped",
                             "bitpacked"],
                    help="physical model layout the plan lowers to "
                         "(auto = picked from the ensemble's depth "
                         "histogram by kernels.tuning.best_layout)")
    ap.add_argument("--tree-block", type=int, default=0,
                    help="staged-path tree block (0 = whole ensemble)")
    ap.add_argument("--min-bucket", type=int, default=16,
                    help="smallest batch-size padding bucket")
    ap.add_argument("--multi", type=int, default=1,
                    help="register K schema-sharing model variants and "
                         "demo the quantize-once predict_multi path")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="arm per-batch deadline-SLO accounting at this "
                         "latency (0 = off); attainment/shed/p99-under-"
                         "deadline land in the metrics snapshot")
    ap.add_argument("--show-kernels", action="store_true",
                    help="print the kernel registry table and exit")
    from repro.launch.obs_cli import add_obs_flags
    add_obs_flags(ap)
    args = ap.parse_args()
    if args.show_kernels:
        from repro.core import layout as layout_mod
        from repro.kernels import registry as kernel_registry
        from repro.kernels import tuning
        print(kernel_registry.format_table())
        if kernel_registry.load_verified():
            print("\nverified: contract-checker verdict per impl "
                  "(results/analysis/contract-report.json; refresh "
                  "with `python -m repro.launch.analyze`)")
        else:
            print("\nverified: no contract report found — run "
                  "`python -m repro.launch.analyze` to populate")
        print()
        print(layout_mod.format_layout_table())
        # the layout this process would resolve for the requested flag
        # (auto shown against two canned depth histograms, since no
        # model is trained under --show-kernels)
        if args.layout != "auto":
            print(f"\nresolved layout: {args.layout} (pinned by --layout)")
        else:
            import numpy as np
            backend = (args.backend if args.backend != "auto"
                       else kernel_registry.default_backend())
            uniform = tuning.best_layout(np.full(100, 6), 1, 54,
                                         backend=backend)
            mixed = tuning.best_layout(np.tile([2, 3, 4, 6], 25), 1, 54,
                                       backend=backend)
            # a mixed-depth model too large for the f32 one-hot working
            # set (> VMEM budget) routes to the integer bitpacked layout
            huge = tuning.best_layout(np.tile([4, 6, 8, 10], 50_000), 1,
                                      512, backend=backend)
            print(f"\nresolved layout (auto, {backend} backend): "
                  f"uniform-depth -> {uniform}, mixed-depth -> {mixed}, "
                  f"huge-mixed -> {huge}")
        return
    (serve_gbdt if args.mode == "gbdt" else serve_lm)(args)


if __name__ == "__main__":
    main()
