"""Train a GBDT on a streamed out-of-core source, then serve it.

The train->serve loop end to end: a `SyntheticSource` is streamed
chunk-by-chunk through `quantize_pool_chunked` (float rows exist
O(chunk) at a time), boosting runs registered `histogram` kernels over
the uint8 pool, and the fitted ensemble goes through `Predictor.build`
to score the same pool — which must match the trainer's reported
training-time predictions EXACTLY (same staged plan, same bits).

    python -m repro.launch.train_gbdt --dataset covertype --scale 0.01 \
        --repeat 4 --trees 20 --check

Per-iteration resume (the PR-5 chunk-index contract, lifted to trees):

    python -m repro.launch.train_gbdt ... --ckpt-dir /tmp/ck --ckpt-every 5
    python -m repro.launch.train_gbdt ... --ckpt-dir /tmp/ck --resume-from -1

`--check` exits non-zero unless serve parity is exact, boosting
performed zero binarize dispatches, histogram dispatches stayed within
the <= depth compiled-shape contract, the streamed source exceeded one
chunk, and the train loss decreased.  Machine-readable metrics go to
stdout; progress to stderr.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import boosting, predictor, quantize
from repro.core.losses import make_loss
from repro.scoring import sources as sources_lib
from repro.training.checkpoint import CheckpointManager
from repro.training.gbdt import GBDTTrainer


def eprint(*args) -> None:
    print(*args, file=sys.stderr)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.train_gbdt",
        description="train on a streamed source, close the serve loop")
    ap.add_argument("--dataset", default="covertype")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=None,
                    help="synthetic dataset seed")
    ap.add_argument("--repeat", type=int, default=4,
                    help="virtual-tile the base split this many times "
                         "(out-of-core row count at in-core cost)")
    ap.add_argument("--chunk", type=int, default=2048,
                    help="streaming chunk rows (0 = planner)")
    ap.add_argument("--trees", type=int, default=20)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--l2", type=float, default=3.0)
    ap.add_argument("--max-bins", type=int, default=64)
    ap.add_argument("--rsm", type=float, default=1.0)
    ap.add_argument("--ordered", action="store_true")
    ap.add_argument("--boost-seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "ref", "pallas"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every k trees (0 = off)")
    ap.add_argument("--resume-from", type=int, default=None,
                    help="resume from checkpointed tree index "
                         "(-1 = latest)")
    ap.add_argument("--check", action="store_true")
    from repro.launch.obs_cli import add_obs_flags
    add_obs_flags(ap)
    args = ap.parse_args(argv)

    if args.ckpt_every and not args.ckpt_dir:
        ap.error("--ckpt-every requires --ckpt-dir")
    if args.resume_from is not None and not args.ckpt_dir:
        ap.error("--resume-from requires --ckpt-dir")

    source = sources_lib.SyntheticSource(
        args.dataset, scale=args.scale, seed=args.seed, split="train",
        repeat=args.repeat)
    ds = source.dataset
    if ds.loss in ("pairlogit", "yetirank"):
        ap.error(f"{args.dataset} uses a grouped ranking loss; "
                 "train_gbdt streams rows without group structure")
    # row i of the source maps to base row i % base_rows
    y = np.tile(np.asarray(ds.y_train), args.repeat)[:source.n_rows]
    loss = make_loss(ds.loss, n_classes=ds.n_classes)

    params = boosting.BoostingParams(
        n_trees=args.trees, depth=args.depth, learning_rate=args.lr,
        l2_reg=args.l2, max_bins=args.max_bins, rsm=args.rsm,
        ordered=args.ordered, seed=args.boost_seed)
    trainer = GBDTTrainer(loss, params, backend=args.backend,
                          name=f"gbdt-{args.dataset}")
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    from repro.launch.obs_cli import finish_obs, start_tracing
    start_tracing(args)
    eprint(f"training {args.trees} trees (depth {args.depth}) on "
           f"{source.n_rows} streamed rows "
           f"({source.base_rows} base x {args.repeat})")
    t0 = time.perf_counter()
    ens, hist = trainer.fit_source(
        source, y, chunk_rows=args.chunk, checkpoint=ckpt,
        checkpoint_every=args.ckpt_every, resume_from=args.resume_from)
    train_s = time.perf_counter() - t0

    # serve round trip: a FRESH plan and an independently re-quantized
    # pool (second streaming pass under the trained borders — also
    # exercises the schema-fingerprint guard)
    plan = predictor.Predictor.build(ens, strategy="staged", layout="soa",
                                     backend=args.backend)
    pool = quantize.quantize_pool_chunked(
        sources_lib.iter_chunks(source, hist["chunk_rows"]), ens.borders,
        backend=args.backend)
    t1 = time.perf_counter()
    served = np.asarray(plan.raw(pool))
    score_s = time.perf_counter() - t1
    parity = float(np.max(np.abs(served - hist["final_raw"])))

    out = {
        "dataset": args.dataset,
        "rows": source.n_rows,
        "base_rows": source.base_rows,
        "chunk_rows": hist["chunk_rows"],
        "n_chunks": hist["n_chunks"],
        "trees": args.trees,
        "depth": args.depth,
        "backend": args.backend,
        "train_s": train_s,
        "serve_score_s": score_s,
        "serve_rows_per_s": source.n_rows / max(score_s, 1e-9),
        "final_metric": hist["final_metric"],
        "serve_parity_max_abs": parity,
        "dispatch_delta": hist["dispatch_delta"],
        "metrics": hist["metrics"],
    }
    print(json.dumps(out, indent=2, default=float))
    finish_obs(args, {f"training/{trainer.metrics.name}":
                      trainer.metrics})

    if args.check:
        failures = []
        if parity != 0.0:
            failures.append(f"train->serve parity not exact: "
                            f"max|diff| = {parity}")
        dd = hist["dispatch_delta"]
        if dd.get("binarize", 0) != 0:
            failures.append(f"boosting dispatched binarize "
                            f"{dd['binarize']}x (expected 0)")
        if dd.get("histogram", 0) > args.depth:
            failures.append(
                f"histogram dispatched {dd['histogram']}x > depth "
                f"{args.depth}: compiled-shape contract broken")
        if source.n_rows <= hist["chunk_rows"]:
            failures.append(
                f"source ({source.n_rows} rows) fits one chunk "
                f"({hist['chunk_rows']}) — not an out-of-core run")
        tl = hist["train_loss"]
        if len(tl) >= 2 and not tl[-1] < tl[0]:
            failures.append(f"train loss did not decrease: "
                            f"{tl[0]} -> {tl[-1]}")
        if failures:
            eprint("CHECK FAILED:")
            for f in failures:
                eprint(f"  - {f}")
            return 1
        eprint(f"CHECK OK: exact serve parity over {source.n_rows} rows "
               f"({hist['n_chunks']} chunks), zero binarize dispatches")
    return 0


if __name__ == "__main__":
    sys.exit(main())
