"""Kernel contract checker CLI.

    python -m repro.launch.analyze             # report + write artifact
    python -m repro.launch.analyze --check     # CI: exit 1 on violations
    python -m repro.launch.analyze -v          # show suppressed findings

Statically verifies every registry capability claim (see
`repro.analysis` / docs/analysis.md): abstract-traces the full
(op × impl × layout × bin-dtype) matrix and lints the jaxprs for
uint8-widening discipline, the bitpacked integer pipeline, VMEM
working sets vs the tuning footprint models, plan transfer/retrace
hygiene and capability consistency.  Nothing is executed or compiled.

By default the run writes results/analysis/contract-report.json — the
committed artifact `registry.format_table()`'s `verified` column reads.
`--check --no-write` is the CI mode: verify without touching the tree.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import checker, report as report_mod


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.analyze",
        description="statically verify kernel registry contracts")
    p.add_argument("--check", action="store_true",
                   help="exit 1 if any unsuppressed finding remains")
    p.add_argument("--no-write", action="store_true",
                   help="do not write the contract-report.json artifact")
    p.add_argument("--out", default=None,
                   help="artifact path (default: results/analysis/"
                        "contract-report.json)")
    p.add_argument("--json", action="store_true",
                   help="print the report as JSON instead of text")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="also show suppressed findings")
    p.add_argument("--ops", default=None,
                   help="comma-separated op filter (skips the "
                        "unused-suppression check)")
    p.add_argument("--impls", default=None,
                   help="comma-separated op:impl filter")
    p.add_argument("--no-plan", action="store_true",
                   help="skip the Predictor plan-entry walk")
    p.add_argument("--no-shard", action="store_true",
                   help="skip the sharded-entry (AbstractMesh) "
                        "shard-parity pass")
    p.add_argument("--no-tuning", action="store_true",
                   help="skip the chunk/layout tuning-model audits")
    args = p.parse_args(argv)

    result = checker.run_check(
        ops_filter=args.ops.split(",") if args.ops else None,
        impls_filter=args.impls.split(",") if args.impls else None,
        include_plan=not args.no_plan,
        include_shard=not args.no_shard,
        include_tuning=not args.no_tuning)

    if args.json:
        print(json.dumps(result.to_json(), indent=2))
    else:
        print(result.format(verbose=args.verbose))

    if not args.no_write:
        path = result.save(args.out)
        if not args.json:
            print(f"wrote {path}")

    return 0 if (result.ok or not args.check) else 1


if __name__ == "__main__":
    sys.exit(main())
