import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, and extract the roofline terms from the
compiled artifact.

This is how the distribution config is proven coherent without hardware:
a sharding mismatch, an OOM-at-compile or an unsupported collective fails
here.  Results are cached as JSON per cell under results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch internlm2-20b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import numpy as np

from repro import configs
from repro.launch.hlo_analysis import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                       collective_bytes_from_hlo)
from repro.configs.base import SHAPES, ShapeConfig, applicable_shapes
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import steps as steps_lib
from repro.models import transformer as tf
from repro.training import optimizer as opt_lib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = sds((B, S), np.int32)
        specs["labels"] = sds((B, S), np.int32)
        if cfg.frontend:
            specs["frontend_embeds"] = sds((B, cfg.frontend_seq,
                                            cfg.d_model), np.float32)
    elif shape.kind == "prefill":
        s_txt = S - (cfg.frontend_seq if cfg.family == "vlm" else 0)
        specs["tokens"] = sds((B, s_txt), np.int32)
        if cfg.frontend:
            specs["frontend_embeds"] = sds((B, cfg.frontend_seq,
                                            cfg.d_model), np.float32)
    else:                                    # decode
        specs["tokens"] = sds((B, 1), np.int32)
    return specs


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               cfg_overrides: dict | None = None):
    """Build + lower + compile one cell. Returns (compiled, meta)."""
    import dataclasses
    # scan_unroll=True (cost probes): XLA cost_analysis counts a `while`
    # body once, so rolled scans under-report FLOPs/bytes/collectives by
    # the trip count; probes unroll every scan to make costs exact.
    cfg = dataclasses.replace(configs.get(arch),
                              **{"scan_unroll": True,
                                 **(cfg_overrides or {})})
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    max_pos = shape.seq_len
    p_abs = tf.abstract_params(cfg, max_positions=max_pos)
    p_specs = shd.param_specs(cfg, mesh, max_positions=max_pos)
    pn = shd.named(mesh, p_specs)
    b_specs = shd.batch_specs(cfg, shape, mesh)
    batch_abs = input_specs(arch, shape_name)
    bn = {k: jax.sharding.NamedSharding(mesh, b_specs.get(k, b_specs["tokens"]))
          for k in batch_abs}

    with mesh:
        if shape.kind == "train":
            opt = opt_lib.make(cfg)
            o_abs = jax.eval_shape(opt.init, p_abs)
            o_specs = shd.opt_state_specs(p_specs, opt.kind)
            on = shd.named(mesh, o_specs)
            fn = steps_lib.make_train_step(cfg, opt, mesh=mesh)
            lowered = jax.jit(
                fn, in_shardings=(pn, on, bn),
                out_shardings=(pn, on, None),
                donate_argnums=(0, 1),
            ).lower(p_abs, o_abs, batch_abs)
        elif shape.kind == "prefill":
            fn = steps_lib.make_prefill_step(cfg, max_seq=shape.seq_len,
                                 mesh=mesh)
            cache_abs = tf.init_cache(cfg, shape.global_batch,
                                      shape.seq_len, abstract=True)
            c_specs = shd.fit_specs(shd.cache_specs(cfg, shape, mesh),
                                    cache_abs, mesh)
            cn = shd.named(mesh, c_specs)
            lowered = jax.jit(
                fn, in_shardings=(pn, bn),
                out_shardings=(None, cn),
            ).lower(p_abs, batch_abs)
        else:
            fn = steps_lib.make_decode_step(cfg, mesh=mesh)
            cache_abs = tf.init_cache(cfg, shape.global_batch,
                                      shape.seq_len, abstract=True)
            c_specs = shd.fit_specs(shd.cache_specs(cfg, shape, mesh),
                                    cache_abs, mesh)
            cn = shd.named(mesh, c_specs)
            lowered = jax.jit(
                fn, in_shardings=(pn, cn, bn["tokens"]),
                out_shardings=(None, cn),
                donate_argnums=(1,),
            ).lower(p_abs, cache_abs, batch_abs["tokens"])
        compiled = lowered.compile()
    return compiled, dict(mesh_shape=tuple(mesh.devices.shape),
                          n_devices=int(mesh.devices.size))


def _cell_costs(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll, "hlo_bytes": len(hlo)}


def _depth_variants(cfg) -> list[dict]:
    """Shallow-depth overrides whose exact (unrolled) costs extrapolate
    linearly to full depth — layers are shape-identical, so per-layer HLO
    cost is a constant and 2-3 probes solve for it."""
    if cfg.family == "hybrid":
        k = cfg.attn_every
        return [{"n_layers": k}, {"n_layers": k + 1}, {"n_layers": 2 * k}]
    if cfg.family == "audio":
        return [{"n_layers": 2, "encoder_layers": 2},
                {"n_layers": 2, "encoder_layers": 3},
                {"n_layers": 3, "encoder_layers": 2}]
    # L=1 interacts with embed/logits optimizations (observed nonlinear
    # costs); L=2 vs L=4 isolates a clean per-layer delta.
    return [{"n_layers": 2}, {"n_layers": 4}]


def _extrapolate(cfg, variants: list[dict], costs: list[dict]) -> dict:
    """Solve the linear per-layer model and evaluate at full depth."""
    def combine(w_base, parts):       # parts: [(weight, cost_dict)]
        out = {"flops": 0.0, "bytes": 0.0, "coll": {}}
        for w, c in parts:
            out["flops"] += w * c["flops"]
            out["bytes"] += w * c["bytes"]
            for k, v in c["coll"].items():
                out["coll"][k] = out["coll"].get(k, 0.0) + w * v
        return out

    def clamp(c):
        return {"flops": max(c["flops"], 0.0), "bytes": max(c["bytes"], 0.0),
                "coll": {k: max(v, 0.0) for k, v in c["coll"].items()}}

    if cfg.family == "hybrid":
        import dataclasses as dc
        from repro.models.transformer import hybrid_n_apps
        k = cfg.attn_every
        a_k = hybrid_n_apps(dc.replace(cfg, n_layers=k))
        a_2k = hybrid_n_apps(dc.replace(cfg, n_layers=2 * k))
        aF = hybrid_n_apps(cfg)
        ck, ck1, c2k = costs
        c_m = clamp(combine(0, [(1, ck1), (-1, ck)]))       # one mamba layer
        napp = max(a_2k - a_k, 1)
        c_a = clamp(combine(1.0 / napp,
                            [(1.0 / napp, c2k), (-1.0 / napp, ck),
                             (-float(k) / napp, c_m)]))      # one attn app
        return combine(0, [(1, ck), (cfg.n_layers - k, c_m),
                           (aF - a_k, c_a)])
    if cfg.family == "audio":
        c22, c23, c32 = costs
        c_enc = clamp(combine(0, [(1, c23), (-1, c22)]))
        c_dec = clamp(combine(0, [(1, c32), (-1, c22)]))
        return combine(0, [(1, c22), (cfg.encoder_layers - 2, c_enc),
                           (cfg.n_layers - 2, c_dec)])
    c2, c4 = costs
    c_l = clamp(combine(0, [(0.5, c4), (-0.5, c2)]))
    return combine(0, [(1, c2), (cfg.n_layers - 2, c_l)])


def analyze_cell(arch: str, shape_name: str, *, multi_pod: bool,
                 cfg_overrides: dict | None = None) -> dict:
    import dataclasses as _dc
    cfg = _dc.replace(configs.get(arch), **(cfg_overrides or {}))
    shape = SHAPES[shape_name]
    ov = dict(cfg_overrides or {})

    # 1) full-depth compile (rolled scans): THE deliverable — proves the
    #    production sharding lowers, compiles, and fits at real depth.
    t0 = time.time()
    compiled, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                cfg_overrides={**ov, "scan_unroll": False})
    compile_s = time.time() - t0
    n_dev = meta["n_devices"]
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem,
                                      "generated_code_size_in_bytes", 0)),
        }
    except Exception:
        mem_info = {}
    full_costs = _cell_costs(compiled)
    del compiled

    # 2) shallow unrolled probes -> exact per-layer costs -> full-depth
    #    roofline terms (XLA counts a while body once; probes are unrolled
    #    so every FLOP/byte/collective is in the counted HLO).
    variants = _depth_variants(cfg)
    probe_costs = []
    probe_compile_s = []
    for var in variants:
        t1 = time.time()
        c, _ = lower_cell(arch, shape_name, multi_pod=multi_pod,
                          cfg_overrides={**ov, **var, "scan_unroll": True})
        probe_compile_s.append(round(time.time() - t1, 1))
        probe_costs.append(_cell_costs(c))
        del c
    ext = _extrapolate(cfg, variants, probe_costs)

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6 * n_active * shape.tokens
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.tokens
    else:
        model_flops = 2 * n_active * shape.global_batch      # one token

    flops_global = ext["flops"] * n_dev
    bytes_global = ext["bytes"] * n_dev
    coll_total = ext["coll"].get("total", 0.0)
    compute_s = flops_global / (n_dev * PEAK_FLOPS)
    memory_s = bytes_global / (n_dev * HBM_BW)
    coll_s = coll_total / (n_dev * LINK_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    return {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": meta["mesh_shape"], "n_devices": n_dev,
        "compile_seconds": round(compile_s, 1),
        "probe_compile_seconds": probe_compile_s,
        "flops_per_device": ext["flops"],
        "bytes_per_device": ext["bytes"],
        "collective_bytes": {k: round(v) for k, v in ext["coll"].items()},
        "full_rolled_costs": {"flops": full_costs["flops"],
                              "bytes": full_costs["bytes"],
                              "coll_total":
                                  full_costs["coll"].get("total", 0)},
        "memory_analysis": mem_info,
        "hlo_text_bytes": full_costs["hlo_bytes"],
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / flops_global
                               if flops_global else 0.0),
        **terms,
        "dominant": dominant,
        "roofline_fraction": (model_flops / (n_dev * PEAK_FLOPS)
                              / max(terms.values())
                              if max(terms.values()) > 0 else 0.0),
        "status": "ok",
    }


def cell_path(arch: str, shape_name: str, multi_pod: bool) -> pathlib.Path:
    pod = "multipod" if multi_pod else "singlepod"
    return RESULTS / f"{arch}__{shape_name}__{pod}.json"


def run_and_save(arch: str, shape_name: str, *, multi_pod: bool,
                 force: bool = False) -> dict:
    path = cell_path(arch, shape_name, multi_pod)
    if path.exists() and not force:
        return json.loads(path.read_text())
    path.parent.mkdir(parents=True, exist_ok=True)
    try:
        res = analyze_cell(arch, shape_name, multi_pod=multi_pod)
    except Exception as e:
        res = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    path.write_text(json.dumps(res, indent=1, default=str))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    pods = []
    if args.multi_pod or not args.single_pod:
        pods.append(True)
    if args.single_pod or not args.multi_pod:
        pods.append(False)
    pods = sorted(set(pods))                 # False (single) first

    cells = []
    if args.all:
        for arch, cfg in configs.ARCHS.items():
            for shp in applicable_shapes(cfg):
                cells.append((arch, shp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    for mp in pods:
        for arch, shp in cells:
            res = run_and_save(arch, shp, multi_pod=mp, force=args.force)
            ok = res.get("status")
            dom = res.get("dominant", "-")
            print(f"[{'2x16x16' if mp else '16x16'}] {arch:20s} {shp:12s} "
                  f"{ok:5s} dominant={dom} "
                  f"compile={res.get('compile_seconds', '-')}s",
                  flush=True)
            if ok != "ok":
                print("   ", res.get("error"), flush=True)


if __name__ == "__main__":
    main()
