"""Bulk-scoring launcher: sweep a whole dataset through compiled plans.

The offline job runner for `repro.scoring` — train (or load) a model,
stream a dataset through `BulkScorer`, write scores / stats, print the
throughput metrics.  The paper's ApplyModelMulti dataset sweep as a
CLI:

  # score synthetic covertype end-to-end, auto chunking, stats summary
  python -m repro.launch.score --dataset covertype --scale 0.01

  # out-of-core: 280k virtual rows -> scores.npy memmap, 3 models
  python -m repro.launch.score --dataset covertype --scale 0.05 \
      --repeat 4 --models 3 --chunk 16384 --out /tmp/scores.npy

  # score an .npy feature matrix through a CatBoost JSON export
  python -m repro.launch.score --from-npy x.npy --model-json model.json \
      --out scores.npy

  # resume an interrupted run at chunk 12
  python -m repro.launch.score ... --out scores.npy --resume-from 12

``--check`` re-scores the dataset through the one-shot `Predictor.raw`
/ `proba` path and exits nonzero on any mismatch — the parity gate
scripts/ci.sh runs.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def eprint(*a):
    print(*a, file=sys.stderr, flush=True)


def _build_plans(args):
    """Train the demo model (or load a CatBoost JSON) and cut K
    schema-sharing variants, exactly like launch/serve.py's --multi."""
    from repro.core.predictor import PredictConfig, Predictor

    config = PredictConfig(strategy=args.strategy, backend=args.backend,
                           layout=args.layout)
    if args.model_json:
        plan = Predictor.from_catboost_json(args.model_json, config)
        return {"model": plan}

    from repro.core import boosting, losses
    from repro.core.boosting import BoostingParams
    from repro.data import synthetic

    ds = synthetic.load(args.dataset, scale=args.scale)
    loss = losses.make_loss(ds.loss if ds.loss in ("multiclass", "logloss")
                            else "logloss",
                            n_classes=max(ds.n_classes, 2))
    y = ds.y_train if ds.n_classes else (ds.y_train > np.median(
        ds.y_train)).astype(np.int32)
    ens, _ = boosting.fit(ds.x_train, y, loss=loss,
                          params=BoostingParams(n_trees=args.trees,
                                                depth=ds.params.depth,
                                                learning_rate=0.1))
    n_variants = max(1, min(args.models, ens.n_trees))
    per = max(1, ens.n_trees // n_variants)
    names = [args.dataset] + [f"{args.dataset}-v{i}"
                              for i in range(1, n_variants)]
    slices = [ens] + [ens.slice_trees(i * per, min((i + 1) * per,
                                                   ens.n_trees))
                      for i in range(1, n_variants)]
    return {name: Predictor.build(e, config)
            for name, e in zip(names, slices)}


def _build_source(args):
    from repro.scoring import NpyMemmapSource, SyntheticSource

    if args.from_npy:
        return NpyMemmapSource(args.from_npy)
    return SyntheticSource(args.dataset, scale=args.scale,
                           split=args.split, repeat=args.repeat)


def _build_sinks(args, plans):
    from repro.scoring import ArraySink, NpySink, StatsSink, TopKSink

    def one(name):
        if args.top_k:
            return TopKSink(args.top_k, column=args.top_k_column)
        if not args.out:
            return StatsSink() if args.stats_only else ArraySink()
        path = args.out if len(plans) == 1 else \
            args.out.replace(".npy", f".{name}.npy")
        return NpySink(path, resume=args.resume_from > 0)

    return {name: one(name) for name in plans}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="covertype",
                    help="synthetic dataset to train on / score")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--split", default="test",
                    choices=["train", "test", "all"])
    ap.add_argument("--repeat", type=int, default=1,
                    help="virtually tile the dataset k times "
                         "(out-of-core row counts at base-memory cost)")
    ap.add_argument("--from-npy", default="",
                    help="score this .npy feature matrix (memmapped) "
                         "instead of a synthetic dataset")
    ap.add_argument("--model-json", default="",
                    help="load a CatBoost JSON export instead of "
                         "training the demo model")
    ap.add_argument("--trees", type=int, default=50)
    ap.add_argument("--models", type=int, default=1,
                    help="score K schema-sharing model variants per "
                         "chunk (quantize once, score many)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="fixed chunk rows (0 = auto from "
                         "kernels.tuning.best_chunk_rows)")
    ap.add_argument("--strategy", choices=["auto", "staged", "fused"],
                    default="auto")
    ap.add_argument("--backend", choices=["auto", "pallas", "ref"],
                    default="auto")
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "soa", "depth_major",
                             "depth_grouped"])
    ap.add_argument("--output", default="raw",
                    choices=["raw", "proba", "classify"])
    ap.add_argument("--no-prequantize", action="store_true",
                    help="score float chunks (binarize inside the "
                         "jitted predict) instead of worker-thread "
                         "quantized pools")
    ap.add_argument("--prefetch-depth", type=int, default=2)
    ap.add_argument("--out", default="",
                    help="write scores to this .npy (memmapped; "
                         "multi-model runs get .<name>.npy suffixes)")
    ap.add_argument("--stats-only", action="store_true",
                    help="stream per-column score stats instead of "
                         "keeping scores")
    ap.add_argument("--top-k", type=int, default=0,
                    help="stream the top-k rows by score instead of "
                         "keeping all scores")
    ap.add_argument("--top-k-column", type=int, default=0)
    ap.add_argument("--resume-from", type=int, default=0,
                    help="first chunk index to score (resume an "
                         "interrupted run; requires --out)")
    ap.add_argument("--check", action="store_true",
                    help="verify bulk output against the one-shot "
                         "Predictor path; exit 1 on mismatch")
    from repro.launch.obs_cli import add_obs_flags
    add_obs_flags(ap)
    args = ap.parse_args()
    if sum([bool(args.out), args.stats_only, bool(args.top_k)]) > 1:
        ap.error("--out, --stats-only and --top-k pick one output mode "
                 "each; pass at most one")
    if args.resume_from and not args.out:
        ap.error("--resume-from needs --out (a row-addressed .npy the "
                 "resumed chunks land in; other sinks would return "
                 "zeros for the skipped rows)")
    if args.check and args.resume_from:
        ap.error("--check verifies a full run; it cannot gate a "
                 "resumed (partial) one")
    if args.check and (args.stats_only or args.top_k):
        ap.error("--check compares full score panels; it needs the "
                 "array or --out output mode")

    from repro.launch.obs_cli import finish_obs, start_tracing
    from repro.scoring import ScoreConfig
    from repro.scoring.scorer import BulkScorer

    start_tracing(args)
    plans = _build_plans(args)
    source = _build_source(args)
    sinks = _build_sinks(args, plans)
    cfg = ScoreConfig(chunk_rows=args.chunk, output=args.output,
                      prefetch_depth=args.prefetch_depth,
                      prequantize=not args.no_prequantize)
    scorer = BulkScorer(plans, cfg)

    eprint(f"[score] {len(plans)} plan(s) x {source.n_rows} rows x "
           f"{source.n_features} features; chunk="
           f"{scorer.resolve_chunk_rows(source.n_rows)} "
           f"({'auto' if not args.chunk else 'fixed'}), "
           f"output={args.output}, "
           f"prequantize={not args.no_prequantize}")
    result = scorer.score(source, sinks, resume_from=args.resume_from)
    m = result.metrics
    eprint(f"[score] {m['rows']} rows in {m['chunks']} chunks "
           f"({result.chunk_shapes} padded shapes, {m['compiles']} "
           f"compiles) -> {m['rows_per_s']:.0f} rows/s; quantize "
           f"{m['quantize_frac']:.0%} of busy time, pad overhead "
           f"{m['pad_overhead']:.1%}")
    print(json.dumps({k: v for k, v in m.items()}, default=float))
    finish_obs(args, {"scoring/bulk": m})
    for name, out in result.outputs.items():
        if isinstance(out, dict) and "mean" in out:      # StatsSink
            eprint(f"[score] {name}: mean={np.round(out['mean'], 4)} "
                   f"std={np.round(out['std'], 4)}")
        elif isinstance(out, dict):                      # TopKSink
            eprint(f"[score] {name}: top rows {out['indices'].tolist()}")
        elif isinstance(out, np.ndarray):
            eprint(f"[score] {name}: scores {out.shape} in memory")
        else:
            eprint(f"[score] {name}: wrote {out}")

    if args.check:
        failed = False
        step = 4096        # the check streams too: O(step) host memory
        for name, plan in plans.items():
            out = result.outputs[name]
            got = (np.load(out, mmap_mode="r") if not
                   isinstance(out, np.ndarray) else out)
            entry = {"raw": plan.raw, "proba": plan.proba,
                     "classify": plan.classify}[args.output]
            err = 0.0
            for s in range(0, source.n_rows, step):
                stop = min(s + step, source.n_rows)
                want = np.asarray(entry(source.read(s, stop)),
                                  np.float32)
                if want.ndim == 1:
                    want = want[:, None]
                if want.size:
                    err = max(err, float(np.max(
                        np.abs(np.asarray(got[s:stop]) - want))))
            eprint(f"[score] check {name}: max |err| = {err:.2e}")
            failed |= not err < 1e-5
        if failed:
            eprint("[score] CHECK FAILED: bulk output diverges from the "
                   "one-shot Predictor path")
            return 1
        eprint("[score] check OK: bulk == one-shot")
    return 0


if __name__ == "__main__":
    sys.exit(main())
