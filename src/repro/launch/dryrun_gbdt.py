import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-mesh dry-run for the paper's OWN model: a CatBoost-scale
GBDT ensemble served at batch on 256/512 chips.

Cells:
  gbdt-predict-1m   1,048,576 x 54 rows, 10k trees depth 8, 7 classes
                    (Covertype-scale model at the paper's 10000-iteration
                    setting) — samples shard over (pod, data), trees over
                    model with a psum combine (core/predict.predict_sharded)
  gbdt-train-iter   one boosting iteration (histograms + split + leaf
                    values) on 1M x 54 sharded rows

  python -m repro.launch.dryrun_gbdt [--multi-pod]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_analysis import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                       collective_bytes_from_hlo)
from repro.launch.mesh import make_production_mesh

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

N_ROWS, N_FEATS = 1_048_576, 54
N_TREES, DEPTH, N_CLASSES, N_BINS = 10_000, 8, 7, 255


def _ensemble_abs():
    sds = jax.ShapeDtypeStruct
    return dict(
        split_features=sds((N_TREES, DEPTH), np.int32),
        split_bins=sds((N_TREES, DEPTH), np.int32),
        leaf_values=sds((N_TREES, 2 ** DEPTH, N_CLASSES), np.float32),
        borders=sds((N_BINS, N_FEATS), np.float32),
        x=sds((N_ROWS, N_FEATS), np.float32),
    )


def lower_predict(mesh):
    from repro.kernels import ref

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def predict(sf, sb, lv, borders, x):
        from repro.compat import shard_map

        def local(sf, sb, lv, borders, xs):
            bins = ref.binarize(xs, borders)
            idx = ref.leaf_index(bins, sf, sb)
            part = ref.leaf_gather(idx, lv)
            return jax.lax.psum(part, "model")

        fn = shard_map(local, mesh=mesh,
                       in_specs=(P("model"), P("model"), P("model"), P(),
                                 P(dp)),
                       out_specs=P(dp))
        return fn(sf, sb, lv, borders, x)

    a = _ensemble_abs()
    shardings = (NamedSharding(mesh, P("model")),) * 3 + (
        NamedSharding(mesh, P()), NamedSharding(mesh, P(dp)))
    return jax.jit(predict, in_shardings=shardings).lower(
        a["split_features"], a["split_bins"], a["leaf_values"],
        a["borders"], a["x"])


def lower_train_iter(mesh):
    """One boosting iteration: grad/hess + histogram splits + leaf values,
    data sharded over (pod, data)."""
    from repro.core import boosting, losses

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    loss = losses.MultiClass(n_classes=N_CLASSES)

    def one_iter(bins, y, raw):
        g, h = loss.grad_hess(raw, y)
        sf, sb, sum_g, sum_h, leaf = boosting._build_tree(
            bins, g, h, jnp.full((N_FEATS,), N_BINS - 1, jnp.int32),
            jax.random.PRNGKey(0), depth=DEPTH, max_bins=64,
            l2=3.0, rsm=1.0)
        w = -0.5 * sum_g / (sum_h + 3.0)
        return sf, sb, w, raw + w[leaf]

    sds = jax.ShapeDtypeStruct
    shardings = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P(dp)),
                 NamedSharding(mesh, P(dp, None)))
    return jax.jit(one_iter, in_shardings=shardings).lower(
        sds((N_ROWS, N_FEATS), np.int32), sds((N_ROWS,), np.int32),
        sds((N_ROWS, N_CLASSES), np.float32))


def run_cell(name: str, multi_pod: bool, force: bool = False) -> dict:
    pod = "multipod" if multi_pod else "singlepod"
    path = RESULTS / f"gbdt-{name}__paper__{pod}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    path.parent.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    try:
        t0 = time.time()
        with mesh:
            lowered = (lower_predict(mesh) if name == "predict-1m"
                       else lower_train_iter(mesh))
            compiled = lowered.compile()
        compile_s = time.time() - t0
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        try:
            mem = compiled.memory_analysis()
            mem_info = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
            }
        except Exception:
            mem_info = {}
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        # model flops: binarize compares + index + gather madds
        if name == "predict-1m":
            model_flops = N_ROWS * (N_FEATS * N_BINS
                                    + N_TREES * DEPTH + N_TREES * N_CLASSES)
        else:
            model_flops = N_ROWS * N_FEATS * DEPTH * 2 * N_CLASSES
        terms = {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll["total"] / (n_dev * LINK_BW),
        }
        res = {
            "arch": f"gbdt-{name}", "shape": "paper", "multi_pod": multi_pod,
            "n_devices": n_dev, "compile_seconds": round(compile_s, 1),
            "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
            "collective_bytes": coll, "memory_analysis": mem_info,
            "model_flops": model_flops,
            "useful_flops_ratio": (model_flops / (flops_dev * n_dev)
                                   if flops_dev else 0.0),
            **terms,
            "dominant": max(terms, key=terms.get),
            "roofline_fraction": (model_flops / (n_dev * PEAK_FLOPS)
                                  / max(terms.values())
                                  if max(terms.values()) > 0 else 0.0),
            "hlo_text_bytes": len(hlo),
            "status": "ok",
        }
    except Exception as e:
        res = {"arch": f"gbdt-{name}", "shape": "paper",
               "multi_pod": multi_pod, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    path.write_text(json.dumps(res, indent=1, default=str))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    pods = ([True] if args.multi_pod else []) + \
        ([False] if args.single_pod or not args.multi_pod else [])
    for mp in pods:
        for cell in ("predict-1m", "train-iter"):
            r = run_cell(cell, mp, args.force)
            print(f"[{'2x16x16' if mp else '16x16'}] gbdt-{cell:12s} "
                  f"{r['status']} dom={r.get('dominant','-')} "
                  f"compile={r.get('compile_seconds','-')}s "
                  f"{r.get('error','')[:100]}", flush=True)


if __name__ == "__main__":
    main()
