"""Shared `--trace-out` / `--metrics-out` wiring for the launchers.

Every job CLI (serve, score, train_gbdt) exposes the same two flags:

  --trace-out FILE    enable the global span tracer for the run and
                      export Chrome trace-event JSON on exit (load the
                      file in https://ui.perfetto.dev or
                      chrome://tracing)
  --metrics-out FILE  export the job's metrics snapshots through a
                      `MetricsHub`: `.prom` suffix writes the
                      Prometheus textfile format, anything else JSON

The helpers keep flag names, export-format selection, and the
enable/export/disable lifecycle identical across launchers.
"""
from __future__ import annotations

import sys
from typing import Any, Mapping


def add_obs_flags(ap) -> None:
    ap.add_argument("--trace-out", default="",
                    help="trace the run and write Chrome trace-event "
                         "JSON here (Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default="",
                    help="write a MetricsHub export here (.prom = "
                         "Prometheus textfile, else JSON)")


def start_tracing(args) -> bool:
    """Enable the global tracer when --trace-out was passed.  Returns
    whether tracing is on (callers need no tracer handle: export goes
    through `finish_obs`)."""
    if not getattr(args, "trace_out", ""):
        return False
    from repro.obs.trace import get_tracer
    tracer = get_tracer()
    tracer.clear()
    tracer.enable()
    return True


def finish_obs(args, metrics_sources: Mapping[str, Any] | None = None
               ) -> None:
    """Export whatever --trace-out / --metrics-out asked for.

    `metrics_sources` maps hub namespaces to snapshot sources (any
    form `MetricsHub.register` accepts: metrics objects, callables,
    plain dicts)."""
    if getattr(args, "trace_out", ""):
        from repro.obs.trace import get_tracer
        tracer = get_tracer()
        obj = tracer.export_chrome(args.trace_out)
        tracer.disable()
        n = sum(1 for r in obj["traceEvents"] if r["ph"] != "M")
        print(f"[obs] {n} trace events -> {args.trace_out} "
              f"(dropped={obj['otherData']['dropped_events']})",
              file=sys.stderr)
    if getattr(args, "metrics_out", "") and metrics_sources:
        from repro.obs import MetricsHub
        hub = MetricsHub()
        for ns, src in metrics_sources.items():
            hub.register(ns, src)
        if args.metrics_out.endswith(".prom"):
            hub.export_prometheus(args.metrics_out)
        else:
            hub.export_json(args.metrics_out)
        print(f"[obs] metrics ({', '.join(hub.namespaces())}) -> "
              f"{args.metrics_out}", file=sys.stderr)
