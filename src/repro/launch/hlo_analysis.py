"""HLO post-processing: collective byte accounting + hardware model.

Kept import-side-effect-free (dryrun.py sets XLA_FLAGS at import; this
module is safe for tests and the roofline report).
"""
from __future__ import annotations

import re

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s ICI

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\b")
_SHAPE_RE = re.compile(r"\b(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum result-operand bytes of every collective op in optimized HLO."""
    per_kind: dict[str, float] = {}
    for line in hlo.splitlines():
        s = line.strip()
        m = _COLL_RE.search(s)
        if not m or "=" not in s:
            continue
        kind = m.group(1)
        lhs = s.split("=", 1)[1]
        op_pos = lhs.find(m.group(0))
        shapes = _SHAPE_RE.findall(lhs[:op_pos])
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
    per_kind["total"] = sum(per_kind.values())
    return per_kind
