"""Serving substrate: request batching + GBDT/LM engines + metrics."""
from repro.serving import batching, engine, metrics  # noqa: F401
from repro.serving.batching import (Batcher, BucketedBatcher,  # noqa: F401
                                    bucket_for, pad_rows, pow2_buckets)
from repro.serving.engine import (GBDTServer, ModelRegistry,  # noqa: F401
                                  ReplicaGroup)
from repro.serving.metrics import ServerMetrics  # noqa: F401
