"""Serving substrate: request batching + GBDT/LM engines."""
from repro.serving import engine  # noqa: F401
