"""Per-model serving metrics: throughput, latency percentiles, recompiles.

Thread-safe counters + a bounded latency reservoir.  `note_trace()` is
designed to be called from *inside* a jitted function body: jax runs the
Python body only when it traces (i.e. on a cache miss), so the call
counts exactly the recompiles — the quantity the bucketing layer exists
to bound.

`PercentileReservoir` is the one percentile implementation both the
online (`ServerMetrics`) and offline (`repro.scoring.ScoringMetrics`)
dashboards sample latencies through, and both snapshots report
`rows_per_s` — online requests/s and offline bulk throughput in the
same unit, directly comparable.

Rates come in two flavours (both exported):

* lifetime — counter / seconds since construction (or `reset()`); the
  long-run average, but it decays toward zero on an idle server.
* interval — delta since the *previous* `snapshot()` call; what a
  poller (the MetricsHub, a Prometheus scrape) should alert on.

Deadline SLO (ROADMAP item 5): give `ServerMetrics` a `deadline_ms`
and every batch's latency is classified hit/miss per valid row;
`note_shed()` counts requests rejected before scoring.  Snapshots then
report `deadline_attainment`, `shed_rate`, and `p99_under_deadline_ms`
(p99 over the latencies that met the deadline — the tail experienced
by requests the SLO actually served).  Definitions: docs/observability.md.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Any

import numpy as np


class PercentileReservoir:
    """Bounded uniform sample of a value stream for percentile queries.

    Classic reservoir sampling: every value ever added has an equal
    chance of being in the sample, so a burst of slow warmup compiles
    cannot pin p99 forever the way a sliding window's eviction order
    would.  Not thread-safe on its own — callers (ServerMetrics,
    ScoringMetrics) hold their own lock around `add`/`percentile`.
    """

    def __init__(self, max_samples: int = 8192, seed: int = 0):
        if max_samples < 1:
            raise ValueError(f"max_samples must be >= 1, got {max_samples}")
        self.max_samples = max_samples
        self._values: list[float] = []
        self._seen = 0
        self._rng = random.Random(seed)

    def add(self, value: float) -> None:
        self._seen += 1
        if len(self._values) < self.max_samples:
            self._values.append(value)
        else:
            j = self._rng.randrange(self._seen)
            if j < self.max_samples:
                self._values[j] = value

    def percentile(self, q: float) -> float:
        """q-th percentile of the sample (0.0 when empty)."""
        if not self._values:
            return 0.0
        return float(np.percentile(np.asarray(self._values), q))

    def __len__(self) -> int:
        return len(self._values)

    @property
    def seen(self) -> int:
        """Total values ever added (>= len(self): the sample is bounded)."""
        return self._seen

    def merge(self, other: "PercentileReservoir") -> None:
        """Absorb another reservoir's sample into this one.

        Each side's sample is a uniform draw from its own stream;
        subsampling the concatenation proportionally to the stream
        sizes keeps the merged sample an (approximately) uniform draw
        from the combined stream — the fleet-view aggregation
        `ScoringMetrics.merge` / `ServerMetrics.merge` percentile
        queries run on.  `seen` adds exactly.
        """
        if not isinstance(other, PercentileReservoir):
            raise TypeError(f"cannot merge {type(other).__name__} into "
                            "PercentileReservoir")
        merged = self._values + other._values
        total = self._seen + other._seen
        if len(merged) > self.max_samples:
            # allocate the bounded sample across the two sides
            # proportionally to their *stream* sizes (not their sample
            # sizes), then uniform-subsample within each side
            n_self = min(round(self.max_samples * self._seen
                               / max(total, 1)), len(self._values))
            n_other = min(self.max_samples - n_self, len(other._values))
            n_self = min(self.max_samples - n_other, len(self._values))
            merged = (self._rng.sample(self._values, n_self)
                      + self._rng.sample(other._values, n_other))
        self._values = merged
        self._seen = total


class ServerMetrics:
    MAX_LAT_SAMPLES = 8192

    def __init__(self, name: str = "model",
                 deadline_ms: float | None = None):
        self.name = name
        # Physical model layout the server's plan lowered to (set by
        # GBDTServer once its Predictor is built; None until then).
        # Exported in snapshots so dashboards can see which layout a
        # deployed model is actually serving with.
        self.layout: str | None = None
        self.deadline_ms = deadline_ms
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self.requests = 0
        self.batches = 0
        self.padded_rows = 0
        self.served_rows = 0
        self.traces = 0
        self.deadline_hits = 0
        self.deadline_misses = 0
        self.shed_requests = 0
        self._lat = PercentileReservoir(self.MAX_LAT_SAMPLES)
        # latencies restricted to batches that met the deadline; the
        # tail of *served-within-SLO* traffic (p99_under_deadline_ms)
        self._lat_ok = PercentileReservoir(self.MAX_LAT_SAMPLES)
        # interval-rate markers: state of the previous snapshot() call
        self._prev_t = self._t0
        self._prev_requests = 0
        self._prev_rows = 0

    # -- recording ---------------------------------------------------------
    def note_trace(self) -> None:
        """Call from inside the jitted predict body: runs once per trace."""
        with self._lock:
            self.traces += 1

    def note_batch(self, n_valid: int, n_padded: int,
                   latency_s: float) -> None:
        with self._lock:
            self.batches += 1
            self.requests += n_valid
            self.served_rows += n_valid
            self.padded_rows += n_padded - n_valid
            self._lat.add(latency_s)
            if self.deadline_ms is not None:
                if latency_s * 1e3 <= self.deadline_ms:
                    self.deadline_hits += n_valid
                    self._lat_ok.add(latency_s)
                else:
                    self.deadline_misses += n_valid

    def note_shed(self, n: int = 1) -> None:
        """Requests rejected before scoring (queue full / deadline
        already blown on arrival).  Sheds never enter the latency
        reservoir — they were not served."""
        with self._lock:
            self.shed_requests += n

    def reset(self) -> None:
        """Zero all counters and restart both rate clocks.  The model
        name / layout / deadline configuration survive."""
        with self._lock:
            self._t0 = time.perf_counter()
            self.requests = self.batches = 0
            self.padded_rows = self.served_rows = self.traces = 0
            self.deadline_hits = self.deadline_misses = 0
            self.shed_requests = 0
            self._lat = PercentileReservoir(self.MAX_LAT_SAMPLES)
            self._lat_ok = PercentileReservoir(self.MAX_LAT_SAMPLES)
            self._prev_t = self._t0
            self._prev_requests = self._prev_rows = 0

    # -- reporting ---------------------------------------------------------
    def _locked_snapshot(self, advance_interval: bool) -> dict[str, Any]:
        """Build the snapshot dict; caller holds self._lock.

        `advance_interval=False` leaves the interval markers untouched
        so a read (e.g. inside `merge`) does not consume another
        poller's interval window."""
        now = time.perf_counter()
        dt = max(now - self._t0, 1e-9)
        idt = max(now - self._prev_t, 1e-9)
        pad_total = self.served_rows + self.padded_rows
        slo_total = self.deadline_hits + self.deadline_misses
        offered = self.requests + self.shed_requests
        snap = {
            "model": self.name,
            "layout": self.layout,
            "requests": self.requests,
            "batches": self.batches,
            "recompiles": self.traces,
            "requests_per_s": self.requests / dt,
            # same unit the offline ScoringMetrics reports, so the
            # online and bulk dashboards are directly comparable
            # (for a server, every served row was a request row)
            "rows_per_s": self.served_rows / dt,
            "interval_requests_per_s":
                (self.requests - self._prev_requests) / idt,
            "interval_rows_per_s":
                (self.served_rows - self._prev_rows) / idt,
            "batch_p50_ms": self._lat.percentile(50) * 1e3,
            "batch_p99_ms": self._lat.percentile(99) * 1e3,
            "pad_overhead": (self.padded_rows / pad_total
                             if pad_total else 0.0),
            "deadline_ms": self.deadline_ms,
            "deadline_hits": self.deadline_hits,
            "deadline_misses": self.deadline_misses,
            # no SLO traffic yet -> vacuously attained, nothing shed
            "deadline_attainment": (self.deadline_hits / slo_total
                                    if slo_total else 1.0),
            "shed_requests": self.shed_requests,
            "shed_rate": (self.shed_requests / offered
                          if offered else 0.0),
            "p99_under_deadline_ms": self._lat_ok.percentile(99) * 1e3,
        }
        if advance_interval:
            self._prev_t = now
            self._prev_requests = self.requests
            self._prev_rows = self.served_rows
        return snap

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return self._locked_snapshot(advance_interval=True)

    @staticmethod
    def merge(parts: list["ServerMetrics"]) -> dict[str, Any]:
        """One fleet view over per-shard/per-replica metrics.

        Count-like fields (requests, batches, recompiles, SLO counters)
        and the throughput rates sum — R replicas each serving X rows/s
        really do serve R*X fleet rows/s — while the latency
        percentiles come from the *merged* reservoirs (a request on any
        replica is one draw from the fleet's latency distribution;
        averaging per-shard p99s would be wrong).  Layout is reported
        when every part agrees, else "mixed".

        Everything for a part — its snapshot fields AND its reservoir —
        is gathered in one locked pass, so counts and percentiles come
        from the same instant even under concurrent `note_batch` load.
        """
        if not parts:
            raise ValueError("ServerMetrics.merge needs at least one part")
        lat = PercentileReservoir(ServerMetrics.MAX_LAT_SAMPLES)
        lat_ok = PercentileReservoir(ServerMetrics.MAX_LAT_SAMPLES)
        snaps: list[dict[str, Any]] = []
        pad_rows = served = 0
        for p in parts:
            with p._lock:
                # non-advancing read: merge must not eat the interval
                # window a dashboard poller is accumulating per part
                snaps.append(p._locked_snapshot(advance_interval=False))
                lat.merge(p._lat)
                lat_ok.merge(p._lat_ok)
                pad_rows += p.padded_rows
                served += p.served_rows
        layouts = {s["layout"] for s in snaps}
        deadlines = {s["deadline_ms"] for s in snaps}
        pad_total = served + pad_rows
        hits = sum(s["deadline_hits"] for s in snaps)
        misses = sum(s["deadline_misses"] for s in snaps)
        shed = sum(s["shed_requests"] for s in snaps)
        requests = sum(s["requests"] for s in snaps)
        offered = requests + shed
        return {
            "model": snaps[0]["model"],
            "replicas": len(parts),
            "layout": layouts.pop() if len(layouts) == 1 else "mixed",
            "requests": requests,
            "batches": sum(s["batches"] for s in snaps),
            "recompiles": sum(s["recompiles"] for s in snaps),
            "requests_per_s": sum(s["requests_per_s"] for s in snaps),
            "rows_per_s": sum(s["rows_per_s"] for s in snaps),
            "interval_requests_per_s":
                sum(s["interval_requests_per_s"] for s in snaps),
            "interval_rows_per_s":
                sum(s["interval_rows_per_s"] for s in snaps),
            "batch_p50_ms": lat.percentile(50) * 1e3,
            "batch_p99_ms": lat.percentile(99) * 1e3,
            "pad_overhead": (pad_rows / pad_total if pad_total else 0.0),
            "deadline_ms": (deadlines.pop() if len(deadlines) == 1
                            else None),
            "deadline_hits": hits,
            "deadline_misses": misses,
            "deadline_attainment": (hits / (hits + misses)
                                    if hits + misses else 1.0),
            "shed_requests": shed,
            "shed_rate": shed / offered if offered else 0.0,
            "p99_under_deadline_ms": lat_ok.percentile(99) * 1e3,
        }

    def __repr__(self) -> str:
        s = self.snapshot()
        return (f"<ServerMetrics {s['model']}: {s['requests']} req "
                f"{s['requests_per_s']:.0f}/s recompiles={s['recompiles']} "
                f"p50={s['batch_p50_ms']:.1f}ms "
                f"p99={s['batch_p99_ms']:.1f}ms>")
