"""Per-model serving metrics: throughput, latency percentiles, recompiles.

Thread-safe counters + a bounded latency reservoir.  `note_trace()` is
designed to be called from *inside* a jitted function body: jax runs the
Python body only when it traces (i.e. on a cache miss), so the call
counts exactly the recompiles — the quantity the bucketing layer exists
to bound.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Any

import numpy as np


class ServerMetrics:
    MAX_LAT_SAMPLES = 8192

    def __init__(self, name: str = "model"):
        self.name = name
        # Physical model layout the server's plan lowered to (set by
        # GBDTServer once its Predictor is built; None until then).
        # Exported in snapshots so dashboards can see which layout a
        # deployed model is actually serving with.
        self.layout: str | None = None
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self.requests = 0
        self.batches = 0
        self.padded_rows = 0
        self.served_rows = 0
        self.traces = 0
        self._lat_s: list[float] = []
        self._lat_seen = 0
        self._rng = random.Random(0)

    # -- recording ---------------------------------------------------------
    def note_trace(self) -> None:
        """Call from inside the jitted predict body: runs once per trace."""
        with self._lock:
            self.traces += 1

    def note_batch(self, n_valid: int, n_padded: int,
                   latency_s: float) -> None:
        with self._lock:
            self.batches += 1
            self.requests += n_valid
            self.served_rows += n_valid
            self.padded_rows += n_padded - n_valid
            # reservoir sampling: every batch has an equal chance of being
            # in the percentile sample, so warmup compiles can't pin p99
            self._lat_seen += 1
            if len(self._lat_s) < self.MAX_LAT_SAMPLES:
                self._lat_s.append(latency_s)
            else:
                j = self._rng.randrange(self._lat_seen)
                if j < self.MAX_LAT_SAMPLES:
                    self._lat_s[j] = latency_s

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            dt = max(time.perf_counter() - self._t0, 1e-9)
            lat = np.asarray(self._lat_s) * 1e3
            pad_total = self.served_rows + self.padded_rows
            return {
                "model": self.name,
                "layout": self.layout,
                "requests": self.requests,
                "batches": self.batches,
                "recompiles": self.traces,
                "requests_per_s": self.requests / dt,
                "batch_p50_ms": float(np.percentile(lat, 50)) if lat.size
                else 0.0,
                "batch_p99_ms": float(np.percentile(lat, 99)) if lat.size
                else 0.0,
                "pad_overhead": (self.padded_rows / pad_total
                                 if pad_total else 0.0),
            }

    def __repr__(self) -> str:
        s = self.snapshot()
        return (f"<ServerMetrics {s['model']}: {s['requests']} req "
                f"{s['requests_per_s']:.0f}/s recompiles={s['recompiles']} "
                f"p50={s['batch_p50_ms']:.1f}ms "
                f"p99={s['batch_p99_ms']:.1f}ms>")
