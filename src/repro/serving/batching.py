"""Request batching with bucketed padding.

The paper's speedups exist only for vector-width batches, so the server
aggregates requests (deadline-or-size, `Batcher`).  But a deadline
batcher under real traffic emits a *different batch size every flush*,
and every distinct size is a fresh XLA trace + compile — unbounded
recompilation, the classic dynamic-shape serving failure.

`BucketedBatcher` fixes that: each flushed batch is zero-padded up to
the smallest configured bucket that holds it (buckets default to powers
of two up to ``max_batch``), so the jitted predict function only ever
sees ``len(buckets)`` distinct shapes.  Retraces are bounded by the
bucket count regardless of traffic; padded rows are sliced off before
replies.  Power-of-two buckets are also what the fused Pallas kernel
wants: its sample-block shapes divide them evenly, so bucket padding
and kernel block padding coincide (see docs/serving.md).
"""
from __future__ import annotations

import queue
import threading
import time
import dataclasses
from typing import Callable, Sequence

import numpy as np


# --------------------------------------------------------------------------
# Bucket utilities
# --------------------------------------------------------------------------
def pow2_buckets(max_batch: int, min_bucket: int = 16) -> tuple[int, ...]:
    """Power-of-two bucket ladder: (min_bucket, ..., >= max_batch).

    The top bucket is the first power of two >= max_batch, so any batch
    the Batcher can legally form has a home.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    b = 1
    while b < min_bucket:
        b *= 2
    out = [b]
    while out[-1] < max_batch:
        out.append(out[-1] * 2)
    return tuple(out)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (callers chunk anything above the top bucket)."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    for b in sorted(buckets):
        if b >= n:
            return b
    raise ValueError(f"batch of {n} exceeds the largest bucket "
                     f"{max(buckets)}; chunk it first")


def chunks(n: int, size: int):
    """(start, stop) ranges cutting n rows into `size`-row chunks — the
    bulk paths (`predict_batch`, `predict_pool`) chunk oversized inputs
    at the largest bucket with this so they share the online path's
    compile cache."""
    if size < 1:
        raise ValueError(f"chunk size must be >= 1, got {size}")
    for start in range(0, n, size):
        yield start, min(start + size, n)


def pad_rows(xs: np.ndarray, target: int) -> np.ndarray:
    """Zero-pad axis 0 of xs up to target rows (no-op when equal)."""
    n = xs.shape[0]
    if n == target:
        return xs
    if n > target:
        raise ValueError(f"cannot pad {n} rows down to {target}")
    pad = np.zeros((target - n,) + xs.shape[1:], xs.dtype)
    return np.concatenate([xs, pad], axis=0)


# --------------------------------------------------------------------------
# Batchers
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    rid: int
    payload: np.ndarray
    future: "queue.Queue"


class Batcher:
    """Deadline-or-size request batching (max_batch or max_wait_ms)."""

    def __init__(self, serve_fn: Callable[[np.ndarray], np.ndarray], *,
                 max_batch: int = 256, max_wait_ms: float = 2.0):
        self.serve_fn = serve_fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self.batch_sizes: list[int] = []
        self.thread = threading.Thread(target=self._loop, daemon=True)
        self.thread.start()

    def _run_batch(self, xs: np.ndarray) -> np.ndarray:
        return np.asarray(self.serve_fn(xs))

    def _loop(self):
        while not self._stop.is_set():
            try:
                first: Request = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.max_batch:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    batch.append(self.q.get(timeout=left))
                except queue.Empty:
                    break
            xs = np.stack([r.payload for r in batch])
            self.batch_sizes.append(len(batch))
            ys = self._run_batch(xs)
            for r, y in zip(batch, ys):
                r.future.put(y)

    def submit(self, rid: int, payload: np.ndarray) -> "queue.Queue":
        fut: queue.Queue = queue.Queue(maxsize=1)
        self.q.put(Request(rid, payload, fut))
        return fut

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2)


class BucketedBatcher(Batcher):
    """Batcher that pads each flushed batch to a size bucket before the
    serve_fn sees it, bounding JIT retraces by the bucket count."""

    def __init__(self, serve_fn: Callable[[np.ndarray], np.ndarray], *,
                 max_batch: int = 256, max_wait_ms: float = 2.0,
                 buckets: Sequence[int] | None = None,
                 min_bucket: int = 16, metrics=None):
        self.buckets = tuple(sorted(buckets)) if buckets else \
            pow2_buckets(max_batch, min_bucket)
        if max_batch > self.buckets[-1]:
            raise ValueError(
                f"max_batch={max_batch} exceeds largest bucket "
                f"{self.buckets[-1]}")
        self.bucket_counts: dict[int, int] = {b: 0 for b in self.buckets}
        self.metrics = metrics            # ServerMetrics or None
        super().__init__(serve_fn, max_batch=max_batch,
                         max_wait_ms=max_wait_ms)

    def _run_batch(self, xs: np.ndarray) -> np.ndarray:
        n = xs.shape[0]
        bucket = bucket_for(n, self.buckets)
        self.bucket_counts[bucket] += 1
        t0 = time.perf_counter()
        ys = np.asarray(self.serve_fn(pad_rows(xs, bucket)))
        if self.metrics is not None:
            self.metrics.note_batch(n, bucket, time.perf_counter() - t0)
        return ys[:n]
