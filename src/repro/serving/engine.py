"""Serving engines — the paper's use case is batched prediction (its
Table 5 speedups exist only when samples arrive in batches; single-sample
inference gains nothing from vectorization, as the paper notes in its
limitations).  The batcher aggregates requests into vector-width batches.

* GBDTServer: batched oblivious-tree scoring with the vectorized predict
  pipeline; optional device-mesh sharding.
* EmbeddingGBDTPipeline: the paper's image-embeddings workload as a
  production pattern — backbone embeddings -> KNN features -> GBDT head
  (any of the 10 assigned LM backbones can produce the embeddings).
* LMServer: prefill/decode serving for the assigned architectures.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn, predict
from repro.core.trees import ObliviousEnsemble


@dataclasses.dataclass
class Request:
    rid: int
    payload: np.ndarray
    future: "queue.Queue"


class Batcher:
    """Deadline-or-size request batching (max_batch or max_wait_ms)."""

    def __init__(self, serve_fn: Callable[[np.ndarray], np.ndarray], *,
                 max_batch: int = 256, max_wait_ms: float = 2.0):
        self.serve_fn = serve_fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self.batch_sizes: list[int] = []
        self.thread = threading.Thread(target=self._loop, daemon=True)
        self.thread.start()

    def _loop(self):
        while not self._stop.is_set():
            try:
                first: Request = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.max_batch:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    batch.append(self.q.get(timeout=left))
                except queue.Empty:
                    break
            xs = np.stack([r.payload for r in batch])
            self.batch_sizes.append(len(batch))
            ys = np.asarray(self.serve_fn(xs))
            for r, y in zip(batch, ys):
                r.future.put(y)

    def submit(self, rid: int, payload: np.ndarray) -> "queue.Queue":
        fut: queue.Queue = queue.Queue(maxsize=1)
        self.q.put(Request(rid, payload, fut))
        return fut

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2)


class GBDTServer:
    def __init__(self, ensemble: ObliviousEnsemble, *,
                 mesh=None, max_batch: int = 256,
                 max_wait_ms: float = 2.0):
        self.ensemble = ensemble
        self.mesh = mesh
        self._jit = jax.jit(lambda x: predict.predict_proba(
            self.ensemble, x, strategy="staged", backend="ref"))

        def serve(xs: np.ndarray) -> np.ndarray:
            x = jnp.asarray(xs, jnp.float32)
            if self.mesh is not None:
                raw = predict.predict_sharded(self.ensemble, x, self.mesh)
                return np.asarray(jax.nn.softmax(raw, axis=-1))
            return np.asarray(self._jit(x))

        self.batcher = Batcher(serve, max_batch=max_batch,
                               max_wait_ms=max_wait_ms)

    def predict(self, x: np.ndarray, timeout: float = 30.0) -> np.ndarray:
        fut = self.batcher.submit(0, np.asarray(x, np.float32))
        return fut.get(timeout=timeout)

    def close(self):
        self.batcher.close()


class EmbeddingGBDTPipeline:
    """backbone embeddings -> KNN features -> GBDT (paper's
    image-embeddings workload, generalized to any backbone)."""

    def __init__(self, featurizer: knn.KNNFeaturizer,
                 ensemble: ObliviousEnsemble,
                 embed_fn: Optional[Callable] = None):
        self.featurizer = featurizer
        self.ensemble = ensemble
        self.embed_fn = embed_fn          # raw input -> embedding (stub ok)

    def predict(self, inputs: np.ndarray) -> np.ndarray:
        emb = (self.embed_fn(inputs) if self.embed_fn is not None
               else jnp.asarray(inputs))
        feats = self.featurizer.transform(emb)
        x = jnp.concatenate([emb, feats], axis=1)
        return np.asarray(predict.predict_class(self.ensemble, x,
                                                backend="ref"))


class LMServer:
    """Minimal continuous-batching LM server: prefill then step decode."""

    def __init__(self, cfg, params, *, max_seq: int = 512):
        import functools
        from repro.models import transformer as tf
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(functools.partial(tf.prefill, cfg,
                                                  max_seq=max_seq))
        self._decode = jax.jit(functools.partial(tf.decode_step, cfg))

    def generate(self, tokens: np.ndarray, n_new: int,
                 frontend_embeds: Optional[np.ndarray] = None
                 ) -> np.ndarray:
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if frontend_embeds is not None:
            batch["frontend_embeds"] = jnp.asarray(frontend_embeds)
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        for _ in range(n_new):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)
