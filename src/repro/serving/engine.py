"""Serving engines — the paper's use case is batched prediction (its
Table 5 speedups exist only when samples arrive in batches; single-sample
inference gains nothing from vectorization, as the paper notes in its
limitations).  Request aggregation and bucket padding live in
`repro.serving.batching`; per-model counters in `repro.serving.metrics`.

* GBDTServer: batched oblivious-tree scoring with the vectorized predict
  pipeline — strategy (staged/fused/auto), backend, tree blocking and
  Pallas block shapes are all configurable; incoming batches are padded
  to size buckets so retraces stay bounded; optional device-mesh
  sharding.
* ModelRegistry: several named ensembles served from one process, each
  with its own server config and metrics.
* EmbeddingGBDTPipeline: the paper's image-embeddings workload as a
  production pattern — backbone embeddings -> KNN features -> GBDT head
  (any of the 10 assigned LM backbones can produce the embeddings).
* LMServer: prefill/decode serving for the assigned architectures.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn
from repro.core.predictor import PredictConfig, Predictor, proba_from_raw
from repro.core.quantize import QuantizedPool
from repro.core.trees import ObliviousEnsemble
from repro.obs.trace import get_tracer
from repro.serving.batching import (Batcher, BucketedBatcher,  # noqa: F401
                                    Request, bucket_for, chunks)
from repro.serving.metrics import ServerMetrics

_TRACER = get_tracer()


class GBDTServer:
    """Batched GBDT scoring service over a compiled prediction plan.

    At construction the server builds one `Predictor` — `auto` choices
    resolved, model arrays padded to block multiples, jitted entry
    points cached — and every batch is scored through that plan; nothing
    model-side is re-prepared per request.  Every batch the batcher
    flushes is padded up to one of ``batcher.buckets`` before it reaches
    the plan, so the number of XLA traces is bounded by the bucket count
    — the `metrics.recompiles` counter asserts this in tests.

    Pass a `PredictConfig` as ``config``; the loose ``strategy`` /
    ``backend`` / ``tree_block`` / ``block_n`` / ``block_t`` kwargs are
    the deprecated equivalents kept for existing callers.

    Quantized-first path: ``quantize(xs)`` binarizes a batch once into
    a `QuantizedPool`; ``predict_pool(pool)`` scores it with zero
    binarize work.  Servers whose models share a feature schema share
    pools (see `ModelRegistry.predict_multi`).
    """

    def __init__(self, ensemble: ObliviousEnsemble, *,
                 config: Optional[PredictConfig] = None,
                 strategy: str = "auto", backend: str = "auto",
                 tree_block: int = 0,
                 block_n: Optional[int] = None,
                 block_t: Optional[int] = None,
                 mesh=None, max_batch: int = 256,
                 max_wait_ms: float = 2.0,
                 buckets: Optional[Sequence[int]] = None,
                 min_bucket: int = 16,
                 name: str = "gbdt",
                 deadline_ms: Optional[float] = None):
        legacy_kw = {"strategy": strategy, "backend": backend,
                     "tree_block": tree_block, "block_n": block_n,
                     "block_t": block_t}
        if config is None:
            config = PredictConfig(**legacy_kw)
        else:
            defaults = PredictConfig()
            clashing = [k for k, v in legacy_kw.items()
                        if v != getattr(defaults, k)]
            if clashing:
                raise TypeError(
                    "pass either config= or the deprecated predict "
                    f"kwargs, not both: {sorted(clashing)}")
        self.ensemble = ensemble
        self.mesh = mesh
        # deadline_ms arms the SLO accounting: every scored batch is
        # classified hit/miss against it and predict() timeouts count
        # as sheds (see serving.metrics.ServerMetrics / docs)
        self.metrics = ServerMetrics(name, deadline_ms=deadline_ms)
        # One plan per server: the tuner sizes fused blocks for the
        # largest bucket; the plan's trace counter feeds `recompiles`.
        # Mesh servers score through `Predictor.sharded`, which ships
        # this same lowered model to every shard — one lowering serves
        # both the local and the mesh path.
        self.predictor = Predictor.build(ensemble, config,
                                         expected_batch=max_batch,
                                         on_trace=self.metrics.note_trace)
        # the sharded path replicates the plan's own lowered model, so
        # mesh and local servers report the same resolved layout
        self.metrics.layout = self.predictor.config.layout
        # sharded predict stays on the paper-faithful staged pipeline
        # unless the caller explicitly asked for fused (fused-inside-
        # shard_map is not a serving-supported combination for `auto`)
        self._sharded = None
        if mesh is not None:
            sharded_strategy = ("staged" if config.strategy == "auto"
                                else config.strategy)
            self._sharded = self.predictor.sharded(
                mesh, strategy=sharded_strategy)

        def serve(xs: np.ndarray) -> np.ndarray:
            # lands on the batcher thread's track in exported traces
            with _TRACER.span("serve/batch", "serve", model=name,
                              rows=int(len(xs))):
                if self._sharded is not None:
                    raw = self._sharded(jnp.asarray(xs, jnp.float32))
                    return np.asarray(proba_from_raw(raw,
                                                     ensemble.n_outputs))
                return np.asarray(self.predictor.proba(xs))

        self.batcher = BucketedBatcher(serve, max_batch=max_batch,
                                       max_wait_ms=max_wait_ms,
                                       buckets=buckets,
                                       min_bucket=min_bucket,
                                       metrics=self.metrics)
        self._serve_padded = serve

    @property
    def config(self) -> PredictConfig:
        """The resolved plan configuration this server scores with."""
        return self.predictor.config

    @property
    def buckets(self) -> tuple[int, ...]:
        return self.batcher.buckets

    def predict(self, x: np.ndarray, timeout: float = 30.0) -> np.ndarray:
        """Single request through the deadline batcher (blocking).

        A timeout is accounted as a shed request (`metrics.shed_rate`)
        and surfaces as `TimeoutError` — the caller never got a score,
        so the latency reservoir is untouched."""
        fut = self.batcher.submit(0, np.asarray(x, np.float32))
        try:
            return fut.get(timeout=timeout)
        except queue.Empty:
            self.metrics.note_shed()
            raise TimeoutError(
                f"predict timed out after {timeout}s (counted as shed; "
                "batcher queue may be saturated)") from None

    def predict_batch(self, xs: np.ndarray) -> np.ndarray:
        """Synchronous bulk scoring through the same bucketed jit path.

        Oversized inputs are chunked at the largest bucket, so this
        shares the compile cache with the online path no matter the
        caller's array size.
        """
        xs = np.asarray(xs, np.float32)
        if len(xs) == 0:
            return self._empty_proba()
        top = self.buckets[-1]
        out = [self.batcher._run_batch(xs[start:stop])
               for start, stop in chunks(len(xs), top)]
        return np.concatenate(out, axis=0)

    # -- quantized-pool path (the shared-quantizer serving win) ------------
    @property
    def schema_fingerprint(self) -> str:
        """Which `QuantizedPool`s this server may score; servers sharing
        it share pools (ModelRegistry.predict_multi quantizes once per
        distinct fingerprint)."""
        return self.predictor.schema_fingerprint

    def quantize(self, xs) -> QuantizedPool:
        """Binarize a batch once for reuse across predicts/servers."""
        return self.predictor.quantize(np.asarray(xs, np.float32))

    def predict_pool(self, pool: QuantizedPool) -> np.ndarray:
        """Synchronous bulk scoring of a pre-quantized pool: binarize
        never runs.  Chunks at the largest bucket and pads each chunk
        up to a bucket, so retraces stay bounded by the bucket count
        exactly like the float path; each chunk is recorded in
        `metrics` the same way the batcher records float batches.

        Mesh servers score pools through the sharded pool entry: the
        pre-quantized bins panel is row-sharded across the mesh and the
        plan's lowered model is replicated, so binarize never runs
        there either."""
        if len(pool) == 0:
            return self._empty_proba()
        top = self.buckets[-1]
        out = []
        for start, stop in chunks(len(pool), top):
            chunk = pool.slice_rows(start, stop)
            bucket = bucket_for(len(chunk), self.buckets)
            t0 = time.perf_counter()
            padded = chunk.pad_rows(bucket)
            if self._sharded is not None:
                raw = self._sharded(padded)
                ys = np.asarray(proba_from_raw(raw,
                                               self.ensemble.n_outputs))
            else:
                ys = np.asarray(self.predictor.proba(padded))
            self.metrics.note_batch(len(chunk), bucket,
                                    time.perf_counter() - t0)
            out.append(ys[:len(chunk)])
        return np.concatenate(out, axis=0)

    def score_source(self, source, sinks=None, *,
                     config=None, resume_from: int = 0, **score_kw):
        """Bulk-apply this server's compiled plan to a whole dataset —
        the bridge from online serving to offline jobs (nightly
        rescore of the same deployed model, same plan, same compile
        caches).  `source` is a `repro.scoring.RowSource`, `sinks` a
        `ScoreSink` (or None for an in-memory array); returns the
        `ScoreResult` whose metrics snapshot reports `rows_per_s` in
        the same unit as this server's `metrics.snapshot()`.

        Defaults to ``output="proba"`` — what this server's online
        predicts return — unless the config says otherwise.

        Mesh servers run the bulk job through the same mesh: the
        scorer's chunk loop stays intact, each chunk scored through the
        sharded entry (`BulkScorer(mesh=...)`).
        """
        from repro.scoring.scorer import BulkScorer, ScoreConfig

        if config is None:
            score_kw.setdefault("output", "proba")
            config = ScoreConfig(**score_kw)
        elif score_kw:
            raise TypeError("pass either a ScoreConfig or config kwargs, "
                            f"not both: {sorted(score_kw)}")
        return BulkScorer(self.predictor, config, mesh=self.mesh).score(
            source, sinks, resume_from=resume_from)

    def _empty_proba(self) -> np.ndarray:
        width = 2 if self.ensemble.n_outputs == 1 else \
            self.ensemble.n_outputs
        return np.zeros((0, width), np.float32)

    def close(self):
        self.batcher.close()


class ReplicaGroup:
    """R `GBDTServer`s over disjoint submeshes, behind one model name.

    Requests round-robin across replicas; each replica runs the full
    sharded predict pipeline on its own devices, so any single request
    sees exactly the single-replica parity contract.  The group
    presents the `GBDTServer` scoring surface (`predict`,
    `predict_batch`, `predict_pool`, `quantize`, `schema_fingerprint`,
    `score_source`) so `ModelRegistry` routes to it transparently, and
    `metrics_snapshot()` is the fleet view (`ServerMetrics.merge`).
    """

    def __init__(self, name: str, servers: Sequence["GBDTServer"]):
        if not servers:
            raise ValueError("ReplicaGroup needs at least one server")
        self.name = name
        self.servers = list(servers)
        self._rr = 0
        self._rr_lock = threading.Lock()

    def _next(self) -> "GBDTServer":
        with self._rr_lock:
            server = self.servers[self._rr % len(self.servers)]
            self._rr += 1
        return server

    # -- GBDTServer surface -------------------------------------------------
    @property
    def ensemble(self):
        return self.servers[0].ensemble

    @property
    def mesh(self):
        return self.servers[0].mesh

    @property
    def schema_fingerprint(self) -> str:
        return self.servers[0].schema_fingerprint

    def quantize(self, xs) -> QuantizedPool:
        # borders are identical across replicas (same ensemble), so a
        # pool quantized once is scoreable on any of them
        return self.servers[0].quantize(xs)

    def predict(self, x, timeout: float = 30.0):
        return self._next().predict(x, timeout=timeout)

    def predict_batch(self, xs):
        return self._next().predict_batch(xs)

    def predict_pool(self, pool):
        return self._next().predict_pool(pool)

    def score_source(self, source, sinks=None, **kw):
        return self._next().score_source(source, sinks, **kw)

    def metrics_snapshot(self) -> dict[str, Any]:
        merged = ServerMetrics.merge([s.metrics for s in self.servers])
        merged["model"] = self.name
        return merged

    def close(self):
        for s in self.servers:
            s.close()


class ModelRegistry:
    """Several named GBDT ensembles served from one process.

    Each model gets its own `GBDTServer` (own batcher thread, own
    compiled `Predictor` plan, own metrics); registry-level `metrics()`
    aggregates the per-model snapshots for export.

    Replica groups: ``register(name, ens, replicas=R, mesh=mesh)``
    splits the mesh into R disjoint submeshes
    (`repro.distributed.gbdt.replica_submeshes`) and serves the model
    from one `GBDTServer` per submesh behind a round-robin
    `ReplicaGroup` — K models x R replicas share one physical mesh,
    and `predict_multi` still quantizes once per feature schema across
    all of them.

    Cache invalidation: a `Predictor` plan is immutable — it holds the
    padded model arrays and jit caches for the ensemble it was built
    from.  Swapping an ensemble under a name (``register(...,
    replace=True)``) therefore tears down the whole old server, plan
    included, and builds a fresh one; handing a new ensemble to an
    existing plan is not supported.
    """

    def __init__(self, **default_server_kw: Any):
        self._default_kw = default_server_kw
        self._servers: dict[str, GBDTServer | ReplicaGroup] = {}

    def register(self, name: str, ensemble: ObliviousEnsemble,
                 replace: bool = False, *, replicas: int = 1,
                 **server_kw: Any) -> "GBDTServer | ReplicaGroup":
        if name in self._servers:
            if not replace:
                raise KeyError(f"model {name!r} already registered "
                               "(pass replace=True to swap it)")
            # Swap = full teardown: the old server's Predictor plan
            # (padded arrays + jit caches) is bound to the old ensemble
            # and must not survive the swap.
            self._servers.pop(name).close()
        kw = {**self._default_kw, **server_kw, "name": name}
        if replicas > 1:
            from repro.distributed.gbdt import replica_submeshes

            mesh = kw.pop("mesh", None)
            if mesh is None:
                raise ValueError(
                    "replicas > 1 needs a mesh to split (pass mesh= "
                    "to register() or to the registry defaults)")
            subs = replica_submeshes(mesh, replicas)
            servers = [GBDTServer(ensemble,
                                  **{**kw, "mesh": sub,
                                     "name": f"{name}/r{i}"})
                       for i, sub in enumerate(subs)]
            group = ReplicaGroup(name, servers)
            self._servers[name] = group
            return group
        server = GBDTServer(ensemble, **kw)
        self._servers[name] = server
        return server

    def load(self, name: str, path, **server_kw: Any) -> GBDTServer:
        return self.register(name, ObliviousEnsemble.load(path),
                             **server_kw)

    def get(self, name: str) -> "GBDTServer | ReplicaGroup":
        if name not in self._servers:
            raise KeyError(f"unknown model {name!r}; registered: "
                           f"{sorted(self._servers)}")
        return self._servers[name]

    def names(self) -> list[str]:
        return sorted(self._servers)

    def predict(self, name: str, x: np.ndarray,
                timeout: float = 30.0) -> np.ndarray:
        return self.get(name).predict(x, timeout=timeout)

    def predict_batch(self, name: str, xs: np.ndarray) -> np.ndarray:
        return self.get(name).predict_batch(xs)

    def predict_multi(self, xs: np.ndarray,
                      names: Optional[Sequence[str]] = None
                      ) -> dict[str, np.ndarray]:
        """Score one batch through several models, quantizing once per
        feature schema.

        Servers whose ensembles share borders (same
        `schema_fingerprint`) get the batch binarized a single time —
        the `QuantizedPool` is then scored through each plan's
        pool path, which skips binarize entirely.  This is the
        quantize-once/score-many serving pattern the quantized-first
        API exists for (multi-model registries routinely serve model
        variants trained on one quantized dataset).  Mesh servers and
        replica groups take the same path: the sharded pool entry
        row-shards the already-quantized bins panel, so one quantize
        still covers every model — and every replica — that shares the
        schema.
        """
        if names is None:
            names = self.names()
        targets = [(n, self.get(n)) for n in names]
        pools: dict[str, QuantizedPool] = {}
        out: dict[str, np.ndarray] = {}
        for name, server in targets:
            fp = server.schema_fingerprint
            if fp not in pools:
                pools[fp] = server.quantize(xs)
            out[name] = server.predict_pool(pools[fp])
        return out

    def metrics(self) -> dict[str, dict[str, Any]]:
        return {n: (s.metrics_snapshot() if isinstance(s, ReplicaGroup)
                    else s.metrics.snapshot())
                for n, s in self._servers.items()}

    def unregister(self, name: str) -> None:
        self._servers.pop(name).close()

    def close(self) -> None:
        for s in self._servers.values():
            s.close()
        self._servers.clear()


class EmbeddingGBDTPipeline:
    """backbone embeddings -> KNN features -> GBDT (paper's
    image-embeddings workload, generalized to any backbone)."""

    def __init__(self, featurizer: knn.KNNFeaturizer,
                 ensemble: ObliviousEnsemble,
                 embed_fn: Optional[Callable] = None,
                 config: Optional[PredictConfig] = None):
        self.featurizer = featurizer
        self.ensemble = ensemble
        self.embed_fn = embed_fn          # raw input -> embedding (stub ok)
        self.predictor = Predictor.build(
            ensemble, config or PredictConfig(backend="ref"))

    def predict(self, inputs: np.ndarray) -> np.ndarray:
        emb = (self.embed_fn(inputs) if self.embed_fn is not None
               else jnp.asarray(inputs))
        feats = self.featurizer.transform(emb)
        x = jnp.concatenate([emb, feats], axis=1)
        return np.asarray(self.predictor.classify(x))


class LMServer:
    """Minimal continuous-batching LM server: prefill then step decode."""

    def __init__(self, cfg, params, *, max_seq: int = 512):
        import functools
        from repro.models import transformer as tf
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(functools.partial(tf.prefill, cfg,
                                                  max_seq=max_seq))
        self._decode = jax.jit(functools.partial(tf.decode_step, cfg))

    def generate(self, tokens: np.ndarray, n_new: int,
                 frontend_embeds: Optional[np.ndarray] = None
                 ) -> np.ndarray:
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if frontend_embeds is not None:
            batch["frontend_embeds"] = jnp.asarray(frontend_embeds)
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        for _ in range(n_new):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)
