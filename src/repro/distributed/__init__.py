"""Distribution substrate: sharding rules, collectives, overlap tricks."""
from repro.distributed import collectives, gbdt, sharding  # noqa: F401
