"""Distribution substrate: sharding rules, collectives, overlap tricks."""
from repro.distributed import collectives, sharding  # noqa: F401
