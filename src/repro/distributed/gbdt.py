"""Replica-group meshes for GBDT serving.

A registry serving K models from one device mesh can trade data
parallelism for request parallelism: split the mesh into R disjoint
submeshes ("replica groups") and round-robin requests across them.
Each replica still runs the full sharded predict pipeline over its own
devices, so within a replica the row-sharding parity contract holds
unchanged; across replicas the only shared state is the ensemble and
its quantizer (pools remain shareable — same borders, same
fingerprint).

`repro.serving.engine.ModelRegistry.register(..., replicas=R)` is the
consumer: it builds one `GBDTServer` per submesh and merges their
metrics with `ServerMetrics.merge`.
"""
from __future__ import annotations

import numpy as np

from repro.compat import make_mesh


def replica_submeshes(mesh, n_replicas: int, *, axis_name: str = None):
    """Split a device mesh into ``n_replicas`` disjoint 1-D submeshes.

    Devices are taken in the mesh's flattened order and dealt out in
    contiguous runs, so a replica's devices stay as physically close as
    the parent mesh laid them (contiguous runs on a host-platform mesh
    are contiguous cores).  Every submesh is 1-D over ``axis_name``
    (default: the parent's first axis name) — replica groups are a
    data-parallel construct; a caller that wants hybrid row x tree
    sharding *within* a replica can still pass the submesh to
    `Predictor.sharded` with ``model_axis`` naming an axis of size 1,
    which degrades to pure row sharding.

    Raises ``ValueError`` unless the device count divides evenly —
    silently uneven replicas would skew round-robin load balancing.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    devices = np.asarray(mesh.devices).reshape(-1)
    if len(devices) % n_replicas:
        raise ValueError(
            f"cannot split {len(devices)} devices into {n_replicas} "
            "equal replica groups")
    per = len(devices) // n_replicas
    axis = axis_name if axis_name is not None else mesh.axis_names[0]
    return [make_mesh((per,), (axis,),
                      devices=devices[i * per:(i + 1) * per])
            for i in range(n_replicas)]
