"""Sharding rule engine: parameter / batch / cache / optimizer-state
PartitionSpecs for the production meshes.

Strategy (DESIGN.md SS5):
  * batch dims shard over ("pod", "data")   [data parallel]
  * TP over "model": attention head projections (when head counts divide
    the axis), MLP d_ff, vocab logits
  * MoE: expert axis over "model" (EP) when n_experts divides it, else
    d_ff inside experts (TP) — cfg.moe_shard
  * FSDP (cfg.fsdp): weights additionally shard over "data" on the
    non-TP matrix dim; optimizer state follows (ZeRO-ish)
  * decode KV caches shard the *sequence* dim over "model" (GQA kv-head
    counts of 1/2/8 cannot divide a 16-way axis; sequence always can)
  * mamba TP note: d_inner-sharding would split B/C state projections
    across shards (collectives inside the recurrence); we keep SSM block
    weights DP/FSDP-only and shard the decode state over heads instead.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_size(mesh: Mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def param_specs(cfg: ModelConfig, mesh: Mesh, *, max_positions: int = 0):
    """Spec tree matching transformer.param_shapes(cfg) structure."""
    shapes = tf.param_shapes(cfg, max_positions=max_positions)
    ms = _model_size(mesh)
    fsdp = "data" if (cfg.fsdp and "data" in mesh.axis_names) else None
    q_ok = cfg.n_heads and cfg.n_heads % ms == 0
    kv_ok = cfg.n_kv_heads and cfg.n_kv_heads % ms == 0
    ep_ok = cfg.n_experts and cfg.n_experts % ms == 0 \
        and cfg.moe_shard in ("expert", "expert2d")

    def spec_for(path: str, shape: tuple) -> P:
        stacked = path.startswith(("blocks/", "enc_blocks/", "dec_blocks/"))
        lead = (None,) if stacked else ()
        name = path.split("/")[-1]
        if name.startswith("x_"):
            name = name[2:]
        if name in ("embed",):
            return P(None, "model")
        if name == "lm_head":
            return P(fsdp, "model")
        if name in ("wq", "wo") and not q_ok:
            return P(*lead, fsdp, None) if name == "wq" \
                else P(*lead, None, fsdp)
        if name in ("wk", "wv") and not kv_ok:
            return P(*lead, fsdp, None)
        if name in ("wq", "wk", "wv"):
            return P(*lead, fsdp, "model")
        if name == "wo":
            return P(*lead, "model", fsdp)
        if name == "router":
            return P(*lead, fsdp, None)
        if name in ("w_gate", "w_in") and cfg.n_experts and stacked:
            if ep_ok and cfg.moe_shard == "expert2d":
                # EP on model x d_ff on data: weights fully sharded, no
                # FSDP all-gather; activations reshard instead
                return P(*lead, "model", None, "data")
            return (P(*lead, "model", fsdp, None) if ep_ok
                    else P(*lead, None, fsdp, "model"))
        if name == "w_out" and cfg.n_experts and stacked:
            if ep_ok and cfg.moe_shard == "expert2d":
                return P(*lead, "model", "data", None)
            return (P(*lead, "model", None, fsdp) if ep_ok
                    else P(*lead, None, "model", fsdp))
        if name in ("w_gate", "w_in"):
            return P(*lead, fsdp, "model")
        if name == "w_out":
            return P(*lead, "model", fsdp)
        if name == "b_in":
            return P(*lead, "model")
        if name == "in_proj":                    # ssm: DP/FSDP only
            return P(*lead, fsdp, None)
        if name == "out_proj":
            return P(*lead, None, fsdp)
        return P()                               # norms, biases, A_log, ...

    def fit(spec: P, shape: tuple) -> P:
        """Drop sharding on dims the axis sizes don't divide evenly
        (pjit in_shardings require exact divisibility)."""
        out = []
        for i, ax in enumerate(spec):
            if ax is None or i >= len(shape):
                out.append(None if i >= len(shape) else ax)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = mesh_size(mesh, axes)
            out.append(ax if shape[i] % size == 0 else None)
        return P(*out[:len(shape)])

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in tree.items()}
        return fit(spec_for(prefix[:-1], tree), tree)

    return walk(shapes)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    b = shape.global_batch
    bspec = dp if (b % max(mesh_size(mesh, dp), 1) == 0 and dp) else None
    out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.frontend:
        out["frontend_embeds"] = P(bspec, None, None)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    """Specs matching transformer.init_cache structure.  Sequence dims
    shard over "model" (flash-decode style); batch over data axes."""
    dp = dp_axes(mesh)
    b = shape.global_batch
    bspec = dp if (b % max(mesh_size(mesh, dp), 1) == 0 and dp) else None
    ms = _model_size(mesh)
    seq_ok = "model" if ms > 1 else None
    specs: dict = {"pos": P()}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        specs["k"] = P(None, bspec, seq_ok, None, None)
        specs["v"] = P(None, bspec, seq_ok, None, None)
    if cfg.family == "audio":
        specs["xk"] = P(None, bspec, seq_ok, None, None)
        specs["xv"] = P(None, bspec, seq_ok, None, None)
    if cfg.family in ("ssm", "hybrid"):
        dims = tf.ssm_dims(cfg)
        h_ok = "model" if dims["n_heads"] % ms == 0 else None
        specs["h"] = P(None, bspec, h_ok, None, None)
        specs["conv"] = P(None, bspec, None, None)
    if cfg.family == "hybrid":
        specs["ak"] = P(None, bspec, seq_ok, None, None)
        specs["av"] = P(None, bspec, seq_ok, None, None)
    return specs


def fit_specs(spec_tree, shape_tree, mesh: Mesh):
    """Drop sharding on any dim the mesh axes don't divide evenly.
    `shape_tree` leaves: arrays / ShapeDtypeStructs matching spec_tree."""
    def fit(spec, leaf):
        shape = leaf.shape
        out = []
        for i in range(len(shape)):
            ax = spec[i] if i < len(spec) else None
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            out.append(ax if shape[i] % mesh_size(mesh, axes) == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(
        lambda s, l: fit(s, l), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(p_specs, kind: str):
    """Optimizer-state spec tree mirroring repro.training.optimizer."""
    if kind in ("adamw", "sgd"):
        trees = {"m": p_specs} if kind == "sgd" else {"m": p_specs,
                                                      "v": p_specs}
        return {**trees, "count": P()}
    if kind == "adafactor":
        def vr(spec):
            return P(*spec[:-1]) if len(spec) >= 2 else spec

        def vc(spec):
            return P(*spec[:-2], spec[-1]) if len(spec) >= 2 else P()

        is_spec = lambda x: isinstance(x, P)
        return {"vr": jax.tree_util.tree_map(vr, p_specs, is_leaf=is_spec),
                "vc": jax.tree_util.tree_map(vc, p_specs, is_leaf=is_spec),
                "count": P()}
    raise ValueError(kind)


def named(mesh: Mesh, spec_tree):
    is_spec = lambda x: isinstance(x, P)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  spec_tree, is_leaf=is_spec)


def shard_tree(tree, mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        tree, spec_tree,
        is_leaf=lambda x: not isinstance(x, dict))
