"""Distributed-optimization building blocks.

* compressed_allreduce — int8/bf16 quantized gradient all-reduce with
  error feedback (residual carried across steps).  At 1000+-node scale
  gradient all-reduce bytes dominate the interconnect; int8 cuts them 4x
  vs fp32 at the cost of quantization noise that error feedback absorbs.
* ring_allgather_matmul — shard_map ppermute ring that overlaps the
  all-gather of a weight shard with the partial matmul (compute/comm
  overlap, the classic latency-hiding schedule).
* flash_decode — sequence-sharded decode attention: each model shard
  attends over its slice of the KV cache and partial softmaxes combine
  with log-sum-exp weights (psum), avoiding the all-gather of 32k-token
  caches.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import pvary, shard_map


# --------------------------------------------------------------------------
# Gradient compression with error feedback
# --------------------------------------------------------------------------
def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_grads(grads, residuals, axis_name: str,
                          mode: str = "int8"):
    """All-reduce `grads` across `axis_name` with compression + error
    feedback.  Call INSIDE shard_map.  Returns (mean grads, residuals)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g = g.astype(jnp.float32) + r
        if mode == "bf16":
            sent = g.astype(jnp.bfloat16)
            recon = sent.astype(jnp.float32)
            reduced = jax.lax.psum(sent.astype(jnp.float32), axis_name)
        else:
            # shards must agree on the scale (a per-shard scale cannot
            # dequantize the summed ints): one scalar pmax, then int8.
            scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / 127.0 \
                + 1e-12
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            recon = q.astype(jnp.float32) * scale
            # int8 psum: widen to int32 for the reduction, rescale after.
            reduced = jax.lax.psum(q.astype(jnp.int32), axis_name) \
                .astype(jnp.float32) * scale
        return reduced / n, g - recon

    out = jax.tree_util.tree_map(one, grads, residuals)
    pick = lambda i: jax.tree_util.tree_map(
        lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), pick(1)


# --------------------------------------------------------------------------
# Overlapped ring all-gather matmul
# --------------------------------------------------------------------------
def ring_allgather_matmul(mesh: Mesh, axis: str = "model") -> Callable:
    """y = x @ W with W row-sharded over `axis`; the ring permutes W shards
    while multiplying the resident shard — overlap instead of a blocking
    all-gather.  x: (B, K) replicated rows, W: (K, N) sharded on K."""
    n_shards = mesh.shape[axis]

    def local(x, w_shard):
        idx = jax.lax.axis_index(axis)
        k_per = w_shard.shape[0]

        def body(i, carry):
            acc, w_cur, src = carry
            x_slice = jax.lax.dynamic_slice_in_dim(
                x, src * k_per, k_per, axis=1)
            acc = acc + x_slice @ w_cur
            w_nxt = jax.lax.ppermute(
                w_cur, axis,
                [(j, (j + 1) % n_shards) for j in range(n_shards)])
            return acc, w_nxt, (src - 1) % n_shards

        acc0 = jnp.zeros((x.shape[0], w_shard.shape[1]), x.dtype)
        # mark the accumulator as device-varying over the ring axis so the
        # loop carry types line up with the permuted weight shard
        acc0 = pvary(acc0, (axis,))
        acc, _, _ = jax.lax.fori_loop(0, n_shards, body,
                                      (acc0, w_shard, idx))
        return acc

    # After a full ring rotation every shard holds the complete sum; the
    # vma checker cannot prove that, hence check_vma=False.
    return shard_map(local, mesh=mesh,
                     in_specs=(P(None, None), P(axis, None)),
                     out_specs=P(None, None), check_vma=False)


# --------------------------------------------------------------------------
# Ring attention: sequence-sharded full attention (prefill / train)
# --------------------------------------------------------------------------
def ring_attention(mesh: Mesh, *, axis: str = "model",
                   dp=("data",), unroll: bool = False) -> Callable:
    """Causal GQA attention with Q, K, V sharded on the SEQUENCE dim over
    `axis`.  KV blocks rotate around the ring (ppermute) while each shard
    accumulates its query block with an online softmax — no shard ever
    holds more than S/n of the sequence, and no head-count divisibility
    is required (the cure for small archs whose 14/12 heads cannot shard
    a 16-way model axis: without this, GSPMD replicates the whole
    attention on every shard).

    q, k, v: (B, S, H|KVH, Dh) with S sharded over `axis`.
    """
    n = mesh.shape[axis]

    def local(q, k, v):
        B, S_loc, H, Dh = q.shape
        KVH = k.shape[2]
        G = H // KVH
        idx = jax.lax.axis_index(axis)
        q_off = idx * S_loc
        qg = q.reshape(B, S_loc, KVH, G, Dh)
        scale = Dh ** -0.5
        qpos = q_off + jnp.arange(S_loc)

        o0 = pvary(jnp.zeros((B, KVH, G, S_loc, Dh), jnp.float32),
                   (axis,))
        m0 = pvary(jnp.full((B, KVH, G, S_loc), -1e30, jnp.float32),
                   (axis,))
        l0 = pvary(jnp.zeros((B, KVH, G, S_loc), jnp.float32),
                   (axis,))

        def step(j, carry):
            o, m, l, kc, vc = carry
            src = (idx - j) % n                  # origin shard of kc block
            kpos = src * S_loc + jnp.arange(S_loc)
            s = jnp.einsum("bqhgd,bshd->bhgqs", qg, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = qpos[:, None] >= kpos[None, :]          # causal
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            o = o * corr[..., None] + pv
            perm = [(r, (r + 1) % n) for r in range(n)]
            kc = jax.lax.ppermute(kc, axis, perm)
            vc = jax.lax.ppermute(vc, axis, perm)
            return o, m_new, l, kc, vc

        carry = (o0, m0, l0, k, v)
        if unroll:       # cost probes: loop bodies must be in counted HLO
            for j in range(n):
                carry = step(j, carry)
            o, m, l = carry[:3]
        else:
            o, m, l, _, _ = jax.lax.fori_loop(0, n, step, carry)
        out = o / jnp.maximum(l[..., None], 1e-30)         # (B,KVH,G,S,Dh)
        out = jnp.moveaxis(out, 3, 1).reshape(B, S_loc, H, Dh)
        return out.astype(q.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, axis, None, None), P(dp, axis, None, None),
                  P(dp, axis, None, None)),
        out_specs=P(dp, axis, None, None), check_vma=False)


# --------------------------------------------------------------------------
# Flash-decode: sequence-sharded decode attention
# --------------------------------------------------------------------------
def flash_decode(mesh: Mesh, *, axis: str = "model",
                 dp: tuple = ("data",)) -> Callable:
    """One-token GQA attention with the KV cache sharded on the sequence
    dim.  Each shard computes a partial softmax over its S/n slice; the
    partials combine exactly via LSE weights in a single psum — no
    KV all-gather.

    q: (B, H, Dh) replicated over `axis`; k/v: (B, S, KVH, Dh) sharded on
    S; valid_len: scalar count of valid positions (global).
    """

    def local(q, k, v, valid_len):
        B, H, Dh = q.shape
        S_loc, KVH = k.shape[1], k.shape[2]
        G = H // KVH
        idx = jax.lax.axis_index(axis)
        offset = idx * S_loc
        qg = q.reshape(B, KVH, G, Dh)
        scores = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                            preferred_element_type=jnp.float32) \
            * (Dh ** -0.5)
        kpos = offset + jnp.arange(S_loc)
        scores = jnp.where(kpos[None, None, None, :] < valid_len,
                           scores, -1e30)
        m_loc = jnp.max(scores, axis=-1)                      # (B,KVH,G)
        p = jnp.exp(scores - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                           preferred_element_type=jnp.float32)
        # exact combine: global max, rescale partial sums
        m_glob = jax.lax.pmax(m_loc, axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, axis)
        o_glob = jax.lax.psum(o_loc * corr[..., None], axis)
        out = o_glob / jnp.maximum(l_glob[..., None], 1e-30)
        return out.reshape(B, H, Dh).astype(q.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, axis, None, None),
                  P(dp, axis, None, None), P()),
        out_specs=P(dp, None, None))
