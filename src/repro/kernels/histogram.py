"""Pallas TPU kernel: gradient histogram accumulation for GBDT training.

Training-side hot-spot (the paper optimizes prediction; the framework
also owns training, whose inner loop is this histogram):

    hist[f, leaf*B + bin] += g[n]   for every sample n, feature f

On CPU/GPU this is a scatter-add; TPU has no fast scatter — the same
observation as the paper's CalculateLeafValues.  Same cure as well: turn
the scatter into a one-hot matmul.  For a sample block, build the one-hot
selector over the combined (leaf, bin) axis and contract over samples on
the MXU:

    onehot[n, l*B+b] = [seg[n] == l*B+b]           (VPU compare vs iota)
    hist_f          += onehot^T @ g                (MXU, per feature)

Grid: (F / block_f, N / block_n) with N as the serial reduction axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(bins_ref, leaf_ref, g_ref, out_ref, *, n_bins: int,
                 n_leaves: int):
    n_blk = pl.program_id(1)
    bins = bins_ref[...]                   # (bf, bn) int32|uint8 (feat-major)
    leaf = leaf_ref[...]                   # (1, bn) int32
    g = g_ref[...]                         # (bn, C) f32
    bf, bn = bins.shape
    S = n_leaves * n_bins

    if bins.dtype == jnp.uint8:
        # uint8 pool bins: decompose the combined-axis one-hot into a
        # bin-digit compare (uint8 vs uint8 — the bins panel is never
        # widened) AND a leaf-digit compare against the narrow (1, bn)
        # leaf row; only the boolean hit mask becomes f32 for the MXU.
        s = jax.lax.broadcasted_iota(jnp.int32, (1, 1, S), 2)
        b_of_s = (s % n_bins).astype(jnp.uint8)           # (1, 1, S)
        l_of_s = s // n_bins                              # (1, 1, S)
        onehot = ((bins[:, :, None] == b_of_s)
                  & (leaf[:, :, None] == l_of_s)).astype(jnp.float32)
    else:
        seg = leaf * n_bins + bins                        # (bf, bn)
        # one-hot over the combined (leaf, bin) axis, batched over
        # features:
        iota = jax.lax.broadcasted_iota(jnp.int32, (bf, bn, S), 2)
        onehot = (iota == seg[:, :, None]).astype(jnp.float32)
    # per-feature MXU contraction over samples: (bf, S, bn) @ (bn, C)
    acc = jax.lax.dot_general(
        onehot, g,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bf, S, C)

    @pl.when(n_blk == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(n_blk != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("n_bins", "n_leaves",
                                             "block_f", "block_n",
                                             "interpret"))
def histogram(bins_t: jax.Array, leaf: jax.Array, g: jax.Array, *,
              n_bins: int, n_leaves: int, block_f: int = 8,
              block_n: int = 256, interpret: bool = False) -> jax.Array:
    """bins_t: (F, N) int32 or uint8 feature-major bins; leaf: (N,)
    int32; g: (N, C) f32  ->  hist (F, n_leaves*n_bins, C) f32.

    Pre-padded: F % block_f == 0, N % block_n == 0; padded samples must
    carry g == 0 (they then contribute nothing).  uint8 bins take the
    widening-free compare path (see `_hist_kernel`).
    """
    F, N = bins_t.shape
    C = g.shape[1]
    S = n_leaves * n_bins
    grid = (F // block_f, N // block_n)
    return pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins, n_leaves=n_leaves),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_f, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, C), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_f, S, C), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, S, C), jnp.float32),
        interpret=interpret,
    )(bins_t, leaf.reshape(1, N), g)


def histogram_ref(bins_t: jax.Array, leaf: jax.Array, g: jax.Array, *,
                  n_bins: int, n_leaves: int) -> jax.Array:
    """Pure-jnp oracle (the boosting trainer's segment_sum path).
    Accepts int32 or uint8 bins; promotion to int32 segment ids is
    benign here — the oracle optimizes for clarity, not bandwidth."""
    F, N = bins_t.shape
    seg = leaf[None, :] * n_bins + bins_t.astype(jnp.int32)  # (F, N)
    return jax.vmap(lambda s: jax.ops.segment_sum(
        g, s, num_segments=n_leaves * n_bins))(seg)
