"""Pluggable kernel registry: named implementations per op.

The paper's evaluator picks one vectorized implementation per hotspot at
startup (scalar / SSE / RVV dispatch in CatBoost's `EvaluatorImpl`);
the ROADMAP's multi-backend north star needs the same architecture
here instead of `backend="auto"|"ref"|"pallas"` string kwargs threaded
through every call site.  Each op registers named implementations with
capability metadata:

  op            one of: binarize, leaf_index, leaf_gather, l2sq,
                fused_predict, histogram
  impl name     "ref" (pure jnp oracle), "pallas" (TPU kernel,
                interpret mode off-TPU), and dtype-specialized variants
                such as "pallas_u8" / "ref_u8" (uint8 bin stream — the
                paper's actual representation)
  dtypes        bin-stream dtypes the implementation produces/consumes
  platforms     where the implementation is production-fit (everything
                runs everywhere; interpret-mode Pallas off-TPU is a
                correctness tool, not a fast path)
  constraints   human-readable shape/dtype constraints for docs

`kernels.ops` registers every implementation at import time and its
public wrappers dispatch through `resolve()`/`dispatch()`; the old
`backend=` kwargs are thin shims over the same lookup.  `table()` makes
the whole dispatch surface introspectable for benchmarks and docs.

Call accounting: `dispatch` ticks a per-op counter.  Like
`ops.pad_stats`, the counter ticks when the dispatch code *runs* — once
per XLA trace for jitted callers, once per call for eager ones — so
"zero binarize dispatches while scoring a quantized pool" is a
checkable invariant (tests/test_quantized.py pins it).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

from repro.obs.trace import get_tracer

_TRACER = get_tracer()

# The six kernel ops every backend family must cover (histogram is the
# training-side op; the other five serve prediction).
CORE_OPS = ("binarize", "leaf_index", "leaf_gather", "l2sq",
            "fused_predict", "histogram")


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered implementation of one op."""
    op: str
    name: str                          # registry key, e.g. "pallas_u8"
    fn: Callable[..., Any]
    family: str                        # legacy backend family: ref | pallas
    dtypes: tuple[str, ...]            # bin-stream dtypes it handles
    platforms: tuple[str, ...]         # production-fit platforms
    layouts: tuple[str, ...]           # physical model layouts it consumes
    constraints: str                   # human-readable constraint note
    # Declared contract-checker exceptions, "<rule>: <reason>" each (see
    # repro.analysis / docs/analysis.md).  An intentional deviation from
    # a lint rule is suppressed HERE, next to the capability claims it
    # qualifies — never silently inside the checker.  The checker flags
    # suppressions that no longer match any finding, so stale entries
    # cannot linger.
    suppressions: tuple[str, ...] = ()


_REGISTRY: dict[str, dict[str, KernelImpl]] = {}
_CALL_STATS: dict[str, int] = {}


@functools.cache
def _platform() -> str:
    """Process platform, resolved once (mirrors `ops.default_platform`;
    duplicated here so the registry stays importable without ops)."""
    import jax
    return jax.default_backend()


def register(op: str, name: str, *, family: Optional[str] = None,
             dtypes: tuple[str, ...] = ("int32",),
             platforms: tuple[str, ...] = ("cpu", "tpu"),
             layouts: tuple[str, ...] = ("soa",),
             constraints: str = "",
             suppressions: tuple[str, ...] = ()) -> Callable:
    """Decorator: register `fn` as implementation `name` of `op`.

    `layouts` names the physical model layouts (see `repro.core.layout`)
    whose arrays the implementation consumes: "soa" kernels take the
    (T, D) split arrays, "depth_major" kernels take the lowered
    (T, D, F) one-hot / (D, T) bit-plane arrays.  The "depth_grouped"
    layout evaluates group-by-group through soa kernels, so soa impls
    typically claim it too.  Ops whose inputs carry no model structure
    (binarize, l2sq) are layout-independent and claim every layout.

    Returns the function unchanged, so module-level names keep working.
    Registering the same (op, name) twice is an error — it would
    silently shadow a live implementation.
    """
    def deco(fn: Callable) -> Callable:
        impls = _REGISTRY.setdefault(op, {})
        if name in impls:
            raise ValueError(f"kernel impl {op}:{name} already registered")
        impls[name] = KernelImpl(
            op=op, name=name, fn=fn,
            family=family or ("pallas" if name.startswith("pallas")
                              else "ref"),
            dtypes=tuple(dtypes), platforms=tuple(platforms),
            layouts=tuple(layouts), constraints=constraints,
            suppressions=tuple(suppressions))
        return fn
    return deco


def unregister(op: str, name: str) -> None:
    """Remove a registered implementation.

    For test fixtures only: lets a deliberately-broken toy impl be
    registered against the contract checker and cleaned up without
    leaking into later tests.  Unknown (op, name) raises KeyError."""
    impls = _REGISTRY.get(op)
    if impls is None or name not in impls:
        raise KeyError(f"kernel impl {op}:{name} not registered")
    del impls[name]
    if not impls:
        del _REGISTRY[op]


def ops() -> list[str]:
    """Registered op names, sorted."""
    return sorted(_REGISTRY)


def implementations(op: str) -> dict[str, KernelImpl]:
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; registered: {ops()}")
    return dict(_REGISTRY[op])


def get(op: str, name: str) -> KernelImpl:
    impls = implementations(op)
    if name not in impls:
        raise KeyError(f"op {op!r} has no implementation {name!r}; "
                       f"available: {sorted(impls)}")
    return impls[name]


def has(op: str, name: str) -> bool:
    return name in _REGISTRY.get(op, {})


def default_backend() -> str:
    """The `auto` resolution: pallas on TPU, the jnp reference
    elsewhere (interpret-mode Pallas is a correctness tool, far too
    slow for CPU production use)."""
    return "pallas" if _platform() == "tpu" else "ref"


def known_backends() -> tuple[str, ...]:
    """Backend names valid as a `PredictConfig.backend` / legacy
    `backend=` value: implementation names registered for *every* core
    op (dtype-specialized variants like `pallas_u8` exist only where
    the dtype matters, so they are per-op names, not backends)."""
    names: Optional[set] = None
    for op in CORE_OPS:
        impls = set(_REGISTRY.get(op, {}))
        names = impls if names is None else names & impls
    return tuple(sorted(names or ()))


# Layout -> implementation-name suffix tried when the backend's base
# implementation does not consume that layout's arrays.
_LAYOUT_SUFFIX = {"depth_major": "dm", "bitpacked": "bp"}


def resolve(op: str, backend: str = "auto", *,
            dtype: Optional[str] = None,
            layout: Optional[str] = None) -> str:
    """Map a legacy `backend=` value (or an exact impl name) to the
    implementation name to run.

    `auto` resolves via `default_backend()`.  When `layout` is given
    and the resolved implementation does not consume that layout's
    arrays, the layout-suffixed sibling (`<name>_dm` for depth_major)
    is tried first — layout determines the *signature*, so it routes
    before dtype.  When `dtype` is given and the implementation does
    not handle it, the dtype-suffixed sibling (`<name>_u8` for uint8)
    is tried before raising.
    """
    name = default_backend() if backend == "auto" else backend
    impls = implementations(op)
    if name not in impls:
        raise KeyError(f"op {op!r} has no implementation {name!r}; "
                       f"available: {sorted(impls)} (legacy backends: "
                       f"{known_backends()} or 'auto')")
    if layout is not None and layout not in impls[name].layouts:
        suffix = _LAYOUT_SUFFIX.get(layout)
        alt = f"{name}_{suffix}" if suffix else None
        if alt is not None and alt in impls \
                and layout in impls[alt].layouts:
            name = alt
        else:
            raise ValueError(
                f"op {op!r} implementation {name!r} does not consume "
                f"layout {layout!r} (consumes {impls[name].layouts}); "
                f"no {layout}-capable variant registered")
    if dtype is not None and dtype not in impls[name].dtypes:
        alt = f"{name}_u8" if dtype == "uint8" else None
        if alt is not None and alt in impls:
            return alt
        raise ValueError(
            f"op {op!r} implementation {name!r} does not handle "
            f"dtype {dtype!r} (handles {impls[name].dtypes}); no "
            f"{dtype}-capable variant registered")
    return name


def dispatch(op: str, backend: str, *args: Any,
             dtype: Optional[str] = None,
             layout: Optional[str] = None, **kw: Any) -> Any:
    """Resolve and call: the single entry every `kernels.ops` public
    wrapper (and its legacy `backend=` shim) funnels through.

    When the obs tracer is enabled, each dispatch records a
    `dispatch/<op>` span tagged (op, impl, layout, bin-dtype, operand
    shapes, block kwargs, row pad utilization) — the per-kernel
    attribution the paper does loop-by-loop on hardware.  Disabled cost
    is one attribute load + bool test; no span kwargs are built."""
    impl = get(op, resolve(op, backend, dtype=dtype, layout=layout))
    _CALL_STATS[op] = _CALL_STATS.get(op, 0) + 1
    if not _TRACER.enabled:
        return impl.fn(*args, **kw)
    attrs: dict[str, Any] = {"op": op, "impl": impl.name,
                             "layout": layout or "-",
                             "dtype": dtype or "-"}
    shapes = [tuple(int(d) for d in a.shape)
              for a in args if hasattr(a, "shape")]
    if shapes:
        attrs["shapes"] = str(shapes)
    blocks = {k: v for k, v in kw.items()
              if k.startswith("block") and isinstance(v, int) and v > 0}
    attrs.update(blocks)
    # fraction of the row-blocked grid that is real data (the span's
    # pad-utilization tag; 1.0 = no block padding on the row axis)
    row_block = blocks.get("block_m") or blocks.get("block_rows")
    if row_block and shapes:
        rows = shapes[0][0]
        padded = -(-rows // row_block) * row_block
        attrs["pad_util_rows"] = rows / padded if padded else 1.0
    _TRACER.counter("dispatch_count", "kernel",
                    **{op: float(_CALL_STATS[op])})
    with _TRACER.span(f"dispatch/{op}", "kernel", **attrs):
        return impl.fn(*args, **kw)


def impls_for_layout(op: str, layout: str) -> list[str]:
    """Implementation names of `op` that consume `layout`'s arrays
    (the CI capability smoke asserts this is non-empty for every op a
    layout claims)."""
    return sorted(name for name, impl in implementations(op).items()
                  if layout in impl.layouts)


# --------------------------------------------------------------------------
# Accounting + introspection
# --------------------------------------------------------------------------
def call_stats() -> dict[str, int]:
    """Per-op dispatch counts (ticks once per trace under jit — see the
    module docstring)."""
    return dict(_CALL_STATS)


def reset_call_stats() -> None:
    _CALL_STATS.clear()


def table() -> list[dict[str, str]]:
    """One row per (op, implementation): the introspection surface for
    docs and benchmarks.  Rows are plain dicts, sorted by (op, name)."""
    rows = []
    for op in ops():
        for name in sorted(_REGISTRY[op]):
            impl = _REGISTRY[op][name]
            rows.append({
                "op": op,
                "impl": name,
                "family": impl.family,
                "dtypes": "/".join(impl.dtypes),
                "platforms": "/".join(impl.platforms),
                "layouts": "/".join(impl.layouts),
                "constraints": impl.constraints,
                "suppressions": " ; ".join(impl.suppressions),
            })
    return rows


def load_verified() -> dict[str, str]:
    """Per-implementation verdicts ("op:impl" -> "ok"/"FAIL"/...) from
    the contract checker's last committed report
    (results/analysis/contract-report.json).  Missing or unreadable
    report -> {} (the verified column renders "-")."""
    import json
    import pathlib
    path = (pathlib.Path(__file__).resolve().parents[3]
            / "results" / "analysis" / "contract-report.json")
    try:
        with open(path, encoding="utf-8") as f:
            verified = json.load(f).get("verified", {})
    except (OSError, ValueError):
        return {}
    return {str(k): str(v) for k, v in verified.items()}


def format_table(verified: Optional[dict[str, str]] = None) -> str:
    """`table()` rendered as a markdown table (docs/api.md embeds the
    output of this function; `launch.serve --show-kernels` prints it).

    The `verified` column carries the contract checker's per-impl
    verdict (`repro.launch.analyze`); by default it is sourced from the
    checker's last committed report via `load_verified()`.  Pass a dict
    to override, or `{}` to render the column blank.

    The `dispatch_count` column is this process's `call_stats()` total
    for the row's op (counts are per-op — the registry ticks before
    impl resolution is observable per-call)."""
    if verified is None:
        verified = load_verified()
    stats = call_stats()
    rows = table()
    for r in rows:
        r["verified"] = verified.get(f"{r['op']}:{r['impl']}", "-")
        r["dispatch_count"] = str(stats.get(r["op"], 0))
    cols = ("op", "impl", "family", "dtypes", "platforms", "layouts",
            "verified", "dispatch_count", "constraints")
    widths = {c: max(len(c), *(len(r[c]) for r in rows)) for c in cols}
    def line(vals):
        return "| " + " | ".join(v.ljust(widths[c])
                                 for c, v in zip(cols, vals)) + " |"
    out = [line(cols),
           "|" + "|".join("-" * (widths[c] + 2) for c in cols) + "|"]
    out += [line([r[c] for c in cols]) for r in rows]
    return "\n".join(out)
