"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground-truth implementations of the paper's four hotspots
(BinarizeFloatsNonSse, CalcIndexesBasic, CalculateLeafValues[Multi],
L2SqrDistance) plus the beyond-paper fused predict.  Each Pallas kernel is
validated against the function of the same name here (tests/test_kernels*.py).

Conventions (match CatBoost's oblivious-tree model):
  x              (N, F)  float32   raw feature matrix
  borders        (B, F)  float32   per-feature bin borders, padded with +inf
  bins           (N, F)  int32     binarized features: #borders strictly below x
  split_features (T, D)  int32     feature id used at depth d of tree t
  split_bins     (T, D)  int32     border id; go right iff bins[f] >= split_bin
  leaf_values    (T, 2^D, C) float32
  leaf index     idx[n, t] = sum_d  2^d * [ bins[n, sf[t,d]] >= sb[t,d] ]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binarize(x: jax.Array, borders: jax.Array) -> jax.Array:
    """bins[n, f] = #{b : x[n, f] > borders[b, f]}  (CatBoost: value > border)."""
    # (N, 1, F) > (1, B, F) -> sum over B
    return jnp.sum(x[:, None, :] > borders[None, :, :], axis=1, dtype=jnp.int32)


def binarize_u8(x: jax.Array, borders: jax.Array) -> jax.Array:
    """`binarize` with the paper's actual bin representation: uint8.

    CatBoost caps features at 255 borders precisely so the binarized
    pool fits one byte per (sample, feature); requires B <= 255 (bin
    ids span [0, B], so 255 is the largest id and still fits).

    This is the *pool builder* — the CPU-side counterpart of CatBoost's
    `BinarizeFloats` (which runs `upper_bound` binary search per value),
    so it binarizes by per-column `searchsorted` over the sorted border
    stack: O(N F log B) instead of the O(N F B) all-pairs comparison
    panel `binarize` keeps.  `binarize` itself intentionally stays the
    comparison-sum form: it is the numerics oracle for the Pallas
    bit-plane kernels (the paper's `vmsgeu` loop), which compute
    exactly that panel.  Results are bit-identical: borders columns are
    sorted ascending with +inf padding, so #{b : x > b} ==
    searchsorted(borders, x, 'left'); NaN (which every comparison
    rejects -> bin 0) is masked explicitly since searchsorted would
    sort it past +inf.
    """
    if borders.shape[0] > 255:
        raise ValueError(f"uint8 bins need <= 255 borders, got "
                         f"{borders.shape[0]} (see quantize.compute_borders"
                         " max_bins cap)")

    def col(b, xc):
        idx = jnp.searchsorted(b, xc, side="left")
        return jnp.where(jnp.isnan(xc), 0, idx)

    return jax.vmap(col, in_axes=(1, 1), out_axes=1)(
        borders, x).astype(jnp.uint8)


def leaf_index(bins: jax.Array, split_features: jax.Array,
               split_bins: jax.Array) -> jax.Array:
    """idx[n, t] = sum_d 2^d * [bins[n, sf[t, d]] >= sb[t, d]]  -> (N, T) int32.

    `bins` may be int32 or uint8 (the quantized-pool representation):
    the comparison against int32 `split_bins` promotes, so one oracle
    serves both bin streams."""
    T, D = split_features.shape
    gathered = bins[:, split_features.reshape(-1)].reshape(bins.shape[0], T, D)
    go_right = (gathered >= split_bins[None, :, :]).astype(jnp.int32)
    pow2 = (1 << jnp.arange(D, dtype=jnp.int32))[None, None, :]
    return jnp.sum(go_right * pow2, axis=-1, dtype=jnp.int32)


def leaf_index_depth_major(bins: jax.Array, onehot: jax.Array,
                           split_bins_dm: jax.Array,
                           pow2: jax.Array) -> jax.Array:
    """`leaf_index` over the depth-major lowered layout -> (N, T) int32.

    Consumes what `layout.lower(..., "depth_major")` precomputes: the
    one-hot feature-gather matrix `onehot` (T, D, F) f32 (row (t, d) is
    onehot(sf[t, d])), split bins transposed to bit-plane order
    `split_bins_dm` (D, T) int32, and the hoisted per-depth power-of-two
    vector `pow2` (D, 1) f32.  The feature gather is a straight matmul
    against the precomputed one-hot — no iota / one-hot rebuild per call
    (the paper's pow2 hoisting applied to model structure).  Exact: bin
    ids <= 255 and a one-hot matmul touch only f32-exact integers.
    """
    T, D, F = onehot.shape
    N = bins.shape[0]
    binsf = bins.astype(jnp.float32)
    gathered = jnp.einsum("tdf,nf->ntd", onehot, binsf)        # (N, T, D)
    go_right = gathered >= split_bins_dm.T[None, :, :].astype(jnp.float32)
    return jnp.sum(go_right.astype(jnp.float32)
                   * pow2[:, 0][None, None, :],
                   axis=-1).astype(jnp.int32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a 0/1 plane along axis 0 into uint32 lanes -> (ceil(N/32), ...).

    The paper's word-packing: 32 docs' comparison bits become one
    machine word (RVV's `vmsgeu` mask register, LMUL'd into words).
    Ragged tails are zero-padded, so lane bit k of word w is doc
    `32*w + k` and every bit past N is 0.  Bits are disjoint across
    lane positions, so the sum of shifted bits equals their bitwise OR.
    """
    n = bits.shape[0]
    w = -(-max(n, 1) // 32)
    b = jnp.asarray(bits).astype(jnp.uint32)
    pad = [(0, w * 32 - n)] + [(0, 0)] * (b.ndim - 1)
    b = jnp.pad(b, pad).reshape((w, 32) + b.shape[1:])
    shifts = jnp.arange(32, dtype=jnp.uint32).reshape(
        (1, 32) + (1,) * (b.ndim - 2))
    return jnp.sum(b << shifts, axis=1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    """Inverse of `pack_bits`: uint32 lanes -> the first `n` 0/1 rows (int32)."""
    shifts = jnp.arange(32, dtype=jnp.uint32).reshape(
        (1, 32) + (1,) * (words.ndim - 1))
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    out = bits.reshape((words.shape[0] * 32,) + words.shape[1:])
    return out[:n].astype(jnp.int32)


def leaf_index_bitpacked(bins: jax.Array, split_features_bp: jax.Array,
                         split_bins_bp: jax.Array, *,
                         via_words: bool = False) -> jax.Array:
    """`leaf_index` over the bitpacked lowered layout -> (N, T) int32.

    Consumes the bit-plane transposed model arrays of
    `layout.lower(..., "bitpacked")`: `split_features_bp` (D, T) int32
    and `split_bins_bp` (D, T) in the narrowest dtype that holds the
    thresholds (uint8 when they fit — comparing uint8 bins against a
    uint8 plane never widens the gathered panel).  Depth d's comparison
    result is a single bit per doc; the index register accumulates bit
    d via shift/or on integers — no one-hot, no float arithmetic, no
    MXU.  `via_words=True` additionally routes each depth's comparison
    plane through `pack_bits`/`unpack_bits` (the paper-literal 32-doc
    uint32 lane representation); since pack/unpack is the identity on
    bit planes (property-tested), both paths are equal by construction.
    """
    D, T = split_features_bp.shape
    n = bins.shape[0]
    gathered = bins[:, split_features_bp.reshape(-1)].reshape(n, D, T)
    go = gathered >= split_bins_bp[None, :, :]              # bool (N, D, T)
    idx = jnp.zeros((n, T), jnp.int32)
    for d in range(D):                                      # static unroll
        bit = go[:, d, :]
        if via_words:
            bit = unpack_bits(pack_bits(bit), n)
        idx = idx | (bit.astype(jnp.int32) << d)
    return idx


def leaf_gather(idx: jax.Array, leaf_values: jax.Array) -> jax.Array:
    """pred[n, c] = sum_t leaf_values[t, idx[n, t], c]  -> (N, C) float32."""
    N, T = idx.shape
    _, L, C = leaf_values.shape
    taken = jnp.take_along_axis(
        leaf_values[None, :, :, :],                        # (1, T, L, C)
        idx[:, :, None, None].astype(jnp.int32),           # (N, T, 1, 1)
        axis=2,
    )                                                      # (N, T, 1, C)
    return jnp.sum(taken[:, :, 0, :], axis=1)


def l2sq_rowwise(q: jax.Array, refs: jax.Array) -> jax.Array:
    """Paper-faithful L2SqrDistance: one query vs many refs -> (N,) float32."""
    d = refs - q[None, :]
    return jnp.sum(d * d, axis=-1)


def l2sq_matrix(a: jax.Array, b: jax.Array) -> jax.Array:
    """Full pairwise distance matrix (M, N): ||a||^2 + ||b||^2 - 2 a.b^T."""
    a_sq = jnp.sum(a * a, axis=-1)[:, None]
    b_sq = jnp.sum(b * b, axis=-1)[None, :]
    cross = a @ b.T
    return jnp.maximum(a_sq + b_sq - 2.0 * cross, 0.0)


def fused_predict(x: jax.Array, borders: jax.Array, split_features: jax.Array,
                  split_bins: jax.Array, leaf_values: jax.Array) -> jax.Array:
    """binarize -> leaf_index -> leaf_gather in one logical op  -> (N, C)."""
    bins = binarize(x, borders)
    idx = leaf_index(bins, split_features, split_bins)
    return leaf_gather(idx, leaf_values)


def fused_predict_depth_major(x: jax.Array, borders: jax.Array,
                              onehot: jax.Array, split_bins_dm: jax.Array,
                              pow2: jax.Array,
                              leaf_values: jax.Array) -> jax.Array:
    """`fused_predict` over the depth-major lowered layout -> (N, C)."""
    bins = binarize(x, borders)
    idx = leaf_index_depth_major(bins, onehot, split_bins_dm, pow2)
    return leaf_gather(idx, leaf_values)


def fused_predict_bitpacked(x: jax.Array, borders: jax.Array,
                            split_features_bp: jax.Array,
                            split_bins_bp: jax.Array,
                            leaf_values: jax.Array) -> jax.Array:
    """`fused_predict` over the bitpacked lowered layout -> (N, C)."""
    bins = binarize(x, borders)
    idx = leaf_index_bitpacked(bins, split_features_bp, split_bins_bp)
    return leaf_gather(idx, leaf_values)
