"""Pallas TPU kernel for feature binarization (paper: BinarizeFloatsNonSse).

The paper's RVV loop broadcasts each border against a vector of feature
values, compares (vmfgt_vf_f32m4_b8) and mask-adds ones (vadd_vv_u8m1_m),
accumulating the bin index.  The TPU adaptation tiles a (block_n, block_f)
sample x feature panel into VMEM and runs the same compare-accumulate over
the border axis on the 8x128 VPU; the border matrix for the feature panel
stays VMEM-resident for the whole sample block.

Grid: (N / block_n, F / block_f); borders are padded with +inf so that the
loop bound is a single static B for every feature.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _binarize_kernel(x_ref, borders_ref, out_ref, *, n_borders: int):
    x = x_ref[...]                       # (bn, bf) f32
    borders = borders_ref[...]           # (B, bf)  f32

    def body(b, acc):
        border_row = jax.lax.dynamic_index_in_dim(borders, b, axis=0,
                                                  keepdims=True)  # (1, bf)
        return acc + (x > border_row).astype(jnp.int32)

    acc0 = jnp.zeros(x.shape, dtype=jnp.int32)
    # Accumulate in int32 (the compare-add loop), store in the output
    # dtype: uint8 for the quantized-pool path (the paper's one-byte bin
    # stream — vadd_vv_u8m1_m accumulates in u8 directly), int32 legacy.
    out_ref[...] = jax.lax.fori_loop(0, n_borders, body, acc0).astype(
        out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_f", "interpret",
                                    "out_dtype"))
def binarize(x: jax.Array, borders: jax.Array, *, block_n: int = 256,
             block_f: int = 128, interpret: bool = False,
             out_dtype=jnp.int32) -> jax.Array:
    """bins[n, f] = #{b : x[n, f] > borders[b, f]}  -> (N, F) `out_dtype`.

    Inputs must be pre-padded: N % block_n == 0, F % block_f == 0 (ops.py
    handles padding).  Padded border rows must be +inf.  `out_dtype`
    uint8 requires B <= 255 (validated in ops.py; 8-bit stores use the
    (32, 128) tile on real TPUs — interpret mode has no such constraint).
    """
    N, F = x.shape
    B = borders.shape[0]
    grid = (N // block_n, F // block_f)
    return pl.pallas_call(
        functools.partial(_binarize_kernel, n_borders=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_f), lambda i, j: (i, j)),
            pl.BlockSpec((B, block_f), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, F), out_dtype),
        interpret=interpret,
    )(x, borders)
