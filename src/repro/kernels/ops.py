"""Jit'd public wrappers around the Pallas kernels.

Handles shape padding (block-size alignment), backend dispatch (Pallas on
TPU, interpret=True Pallas or the pure-jnp reference on CPU) and
un-padding.  This is the only module the rest of the framework imports
from `repro.kernels`.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import binarize as _binarize_k
from repro.kernels import fused_predict as _fused_k
from repro.kernels import l2dist as _l2_k
from repro.kernels import leaf_gather as _gather_k
from repro.kernels import leaf_index as _index_k
from repro.kernels import ref as _ref
from repro.kernels import tuning as _tuning

Backend = Literal["auto", "pallas", "ref"]

# Sentinel bin id guaranteeing `bins < PAD_SPLIT_BIN` (padded trees go left).
# Canonical definition — `core.trees` re-exports it.
PAD_SPLIT_BIN = 1 << 30

# Lane width the kernels align the feature axis to (VPU lane / MXU edge).
FEATURE_ALIGN = 128


@functools.cache
def default_platform() -> str:
    """`jax.default_backend()`, resolved once per process.

    The platform cannot change mid-process, and querying it inside traced
    code paths (every `auto` dispatch used to) is wasted work on each
    predict call — plan builders and the auto dispatch both read this
    cached value instead.
    """
    return jax.default_backend()


def _on_tpu() -> bool:
    return default_platform() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# Pad-op accounting, split by which side of the problem was padded:
#   model — ensemble arrays (borders / splits / leaf values); a prepared
#           plan must incur these exactly once, at build time
#   data  — per-batch arrays (x / bins / idx); unavoidable per call
# Counters tick only when a pad actually happens (width 0 is free) and
# only when the padding code runs, i.e. once per trace under jit.
_PAD_STATS = {"model": 0, "data": 0}


def pad_stats() -> dict[str, int]:
    return dict(_PAD_STATS)


def reset_pad_stats() -> None:
    for k in _PAD_STATS:
        _PAD_STATS[k] = 0


def _pad_dim(a: jax.Array, axis: int, target: int, value=0,
             kind: str = "data") -> jax.Array:
    pad = target - a.shape[axis]
    if pad == 0:
        return a
    _PAD_STATS[kind] += 1
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _use_pallas(backend: Backend) -> bool:
    if backend == "pallas":
        return True
    if backend == "ref":
        return False
    # auto: Pallas on TPU; pure-jnp reference on CPU (interpret mode is a
    # correctness tool, far too slow for CPU production use).
    return _on_tpu()


# --------------------------------------------------------------------------
# Public ops
# --------------------------------------------------------------------------
def binarize(x: jax.Array, borders: jax.Array, *, backend: Backend = "auto",
             block_n: int = 256, block_f: int = 128) -> jax.Array:
    """(N, F) f32, (B, F) f32 -> (N, F) int32 bin indices."""
    if not _use_pallas(backend):
        return _ref.binarize(x, borders)
    N, F = x.shape
    Np, Fp = _round_up(max(N, 1), block_n), _round_up(max(F, 1), block_f)
    xp = _pad_dim(_pad_dim(x, 0, Np), 1, Fp)
    bp = _pad_dim(borders, 1, Fp, value=np.float32(np.inf), kind="model")
    out = _binarize_k.binarize(xp, bp, block_n=block_n, block_f=block_f,
                               interpret=_interpret())
    return out[:N, :F]


def leaf_index(bins: jax.Array, split_features: jax.Array,
               split_bins: jax.Array, *, backend: Backend = "auto",
               block_n: int = 256, block_t: int = 16) -> jax.Array:
    """(N, F) i32, (T, D) i32, (T, D) i32 -> (N, T) int32 leaf ids."""
    if not _use_pallas(backend):
        return _ref.leaf_index(bins, split_features, split_bins)
    N, F = bins.shape
    T, D = split_features.shape
    Np, Tp = _round_up(N, block_n), _round_up(T, block_t)
    Fp = _round_up(F, 128)
    binsp = _pad_dim(_pad_dim(bins, 0, Np), 1, Fp)
    sfp = _pad_dim(split_features, 0, Tp, kind="model")
    sbp = _pad_dim(split_bins, 0, Tp, value=PAD_SPLIT_BIN, kind="model")
    out = _index_k.leaf_index(binsp, sfp, sbp, block_n=block_n,
                              block_t=block_t, interpret=_interpret())
    return out[:N, :T]


def leaf_gather(idx: jax.Array, leaf_values: jax.Array, *,
                backend: Backend = "auto", block_n: int = 128,
                block_t: int = 16) -> jax.Array:
    """(N, T) i32, (T, L, C) f32 -> (N, C) f32 summed leaf values."""
    if not _use_pallas(backend):
        return _ref.leaf_gather(idx, leaf_values)
    N, T = idx.shape
    _, L, C = leaf_values.shape
    Np, Tp = _round_up(N, block_n), _round_up(T, block_t)
    idxp = _pad_dim(_pad_dim(idx, 0, Np), 1, Tp)
    lvp = _pad_dim(leaf_values, 0, Tp, kind="model")  # zero leaves: no-op trees
    out = _gather_k.leaf_gather(idxp, lvp, block_n=block_n, block_t=block_t,
                                interpret=_interpret())
    return out[:N]


def l2sq_rowwise(q: jax.Array, refs: jax.Array, *, backend: Backend = "auto",
                 block_n: int = 256, block_k: int = 128) -> jax.Array:
    """(K,), (N, K) -> (N,) squared L2 distances."""
    if not _use_pallas(backend):
        return _ref.l2sq_rowwise(q, refs)
    N, K = refs.shape
    Np, Kp = _round_up(N, block_n), _round_up(K, block_k)
    qp = _pad_dim(q, 0, Kp)
    rp = _pad_dim(_pad_dim(refs, 0, Np), 1, Kp)
    out = _l2_k.l2sq_rowwise(qp, rp, block_n=block_n, block_k=block_k,
                             interpret=_interpret())
    return out[:N]


def l2sq_matrix(a: jax.Array, b: jax.Array, *, backend: Backend = "auto",
                block_m: int = 128, block_n: int = 128,
                block_k: int = 128) -> jax.Array:
    """(M, K), (N, K) -> (M, N) squared L2 distance matrix."""
    if not _use_pallas(backend):
        return _ref.l2sq_matrix(a, b)
    M, K = a.shape
    N, _ = b.shape
    Mp, Np_, Kp = (_round_up(M, block_m), _round_up(N, block_n),
                   _round_up(K, block_k))
    ap = _pad_dim(_pad_dim(a, 0, Mp), 1, Kp)
    bp = _pad_dim(_pad_dim(b, 0, Np_), 1, Kp)
    out = _l2_k.l2sq_matrix(ap, bp, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=_interpret())
    return out[:M, :N]


def fused_predict(x: jax.Array, borders: jax.Array, split_features: jax.Array,
                  split_bins: jax.Array, leaf_values: jax.Array, *,
                  backend: Backend = "auto", block_n: int | None = None,
                  block_t: int | None = None) -> jax.Array:
    """Fused binarize+index+gather -> (N, C) f32.

    Inputs need no pre-padding: N/T/F are padded here to the block
    multiples (padded trees get zero leaf values and an impossible
    split bin, so they contribute nothing).  When block_n/block_t are
    None the shapes come from the VMEM footprint model in
    `kernels.tuning` (the RVV-LMUL analog), sized to this ensemble and
    batch instead of a fixed (128, 16).
    """
    if not _use_pallas(backend):
        return _ref.fused_predict(x, borders, split_features, split_bins,
                                  leaf_values)
    N, F = x.shape
    T, D = split_features.shape
    _, L, C = leaf_values.shape
    if block_n is None or block_t is None:
        tn, tt = _tuning.best_fused_blocks(
            F, D, L, C, borders.shape[0], n_rows=N, n_trees=T)
        block_n = block_n or tn
        block_t = block_t or tt
    Np = _round_up(N, block_n)
    Tp = _round_up(T, block_t)
    Fp = _round_up(F, FEATURE_ALIGN)
    xp = _pad_dim(_pad_dim(x, 0, Np), 1, Fp)
    bp = _pad_dim(borders, 1, Fp, value=np.float32(np.inf), kind="model")
    sfp = _pad_dim(split_features, 0, Tp, kind="model")
    sbp = _pad_dim(split_bins, 0, Tp, value=PAD_SPLIT_BIN, kind="model")
    lvp = _pad_dim(leaf_values, 0, Tp, kind="model")
    out = _fused_k.fused_predict(xp, bp, sfp, sbp, lvp, block_n=block_n,
                                 block_t=block_t, interpret=_interpret())
    return out[:N]


# --------------------------------------------------------------------------
# Prepadded-model fast paths (the compiled-plan Predictor's hot loop)
# --------------------------------------------------------------------------
# These entry points take ensemble arrays that a plan builder
# (`core.predictor.Predictor.build`) has already padded to block
# multiples, so only the data side (x / bins / idx) is padded per call —
# the per-call model `jnp.pad`s the paper hoists out of the loop are gone.
# Invariants the builder guarantees for the pallas backend:
#   borders  F padded to a FEATURE_ALIGN multiple with +inf
#   splits   T padded to a block_t multiple (bins=PAD_SPLIT_BIN: go left)
#   leaves   T padded with zeros (padded trees contribute nothing)
# On the ref backend the same arrays work unpadded — ref kernels accept
# any shape — so a ref plan carries the original arrays through.

def fused_predict_prepadded(x: jax.Array, borders: jax.Array,
                            split_features: jax.Array, split_bins: jax.Array,
                            leaf_values: jax.Array, *,
                            backend: Backend = "auto",
                            block_n: int = 128,
                            block_t: int = 16) -> jax.Array:
    """Fused predict on a prepadded model -> (N, C) f32."""
    if not _use_pallas(backend):
        xp = _pad_dim(x, 1, borders.shape[1])
        return _ref.fused_predict(xp, borders, split_features, split_bins,
                                  leaf_values)
    N = x.shape[0]
    Np = _round_up(max(N, 1), block_n)
    xp = _pad_dim(_pad_dim(x, 0, Np), 1, borders.shape[1])
    out = _fused_k.fused_predict(xp, borders, split_features, split_bins,
                                 leaf_values, block_n=block_n,
                                 block_t=block_t, interpret=_interpret())
    return out[:N]


def binarize_prepadded(x: jax.Array, borders: jax.Array, *,
                       backend: Backend = "auto",
                       block_n: int = 256) -> jax.Array:
    """Binarize against prepadded borders -> (N, Fp) int32.

    Keeps the padded feature columns (bins for +inf-border features are
    zero) so the downstream prepadded stages see an aligned F axis.
    """
    Fp = borders.shape[1]
    xp = _pad_dim(x, 1, Fp)
    if not _use_pallas(backend):
        return _ref.binarize(xp, borders)
    N = x.shape[0]
    Np = _round_up(max(N, 1), block_n)
    xp = _pad_dim(xp, 0, Np)
    out = _binarize_k.binarize(xp, borders, block_n=block_n,
                               block_f=FEATURE_ALIGN,
                               interpret=_interpret())
    return out[:N]


def leaf_index_prepadded(bins: jax.Array, split_features: jax.Array,
                         split_bins: jax.Array, *,
                         backend: Backend = "auto", block_n: int = 256,
                         block_t: int = 16) -> jax.Array:
    """Leaf indices on prepadded splits -> (N, Tp) int32 (padded trees
    land in leaf 0, which holds a zero leaf value)."""
    if not _use_pallas(backend):
        return _ref.leaf_index(bins, split_features, split_bins)
    N = bins.shape[0]
    Np = _round_up(max(N, 1), block_n)
    binsp = _pad_dim(bins, 0, Np)
    out = _index_k.leaf_index(binsp, split_features, split_bins,
                              block_n=block_n, block_t=block_t,
                              interpret=_interpret())
    return out[:N]


def leaf_gather_prepadded(idx: jax.Array, leaf_values: jax.Array, *,
                          backend: Backend = "auto", block_n: int = 128,
                          block_t: int = 16) -> jax.Array:
    """Sum prepadded leaf values at idx -> (N, C) f32."""
    if not _use_pallas(backend):
        return _ref.leaf_gather(idx, leaf_values)
    N = idx.shape[0]
    Np = _round_up(max(N, 1), block_n)
    idxp = _pad_dim(idx, 0, Np)
    out = _gather_k.leaf_gather(idxp, leaf_values, block_n=block_n,
                                block_t=block_t, interpret=_interpret())
    return out[:N]
