"""Public kernel ops: registry-dispatched wrappers around the Pallas
kernels and their jnp oracles.

Every op (binarize, leaf_index, leaf_gather, l2sq, fused_predict) has
named implementations registered in `kernels.registry` — "ref" (pure
jnp), "pallas" (real kernels; interpret mode off-TPU), and uint8
bin-stream variants ("ref_u8", "pallas_u8") for the quantized-pool
path.  The implementations here own shape padding (block-size
alignment) and un-padding; the public wrappers are thin shims that map
the legacy `backend="auto"|"ref"|"pallas"` kwarg onto a registry lookup
(`registry.resolve`) and dispatch.  This module is the only one the
rest of the framework imports from `repro.kernels`; pass exact
implementation names (e.g. `backend="pallas_u8"`) to pin a variant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import binarize as _binarize_k
from repro.kernels import fused_predict as _fused_k
from repro.kernels import histogram as _hist_k
from repro.kernels import l2dist as _l2_k
from repro.kernels import leaf_gather as _gather_k
from repro.kernels import leaf_index as _index_k
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels import tuning as _tuning

# Legacy alias: a backend value is "auto", a registry backend family
# ("ref" / "pallas"), or an exact implementation name ("pallas_u8").
Backend = str

# Sentinel bin id guaranteeing `bins < PAD_SPLIT_BIN` (padded trees go left).
# Canonical definition — `core.trees` re-exports it.
PAD_SPLIT_BIN = 1 << 30

# Lane width the kernels align the feature axis to (VPU lane / MXU edge).
FEATURE_ALIGN = 128

# Largest border count whose bin ids fit the uint8 quantized-pool
# representation (CatBoost's 255-border cap: ids span [0, B] <= 255).
MAX_U8_BORDERS = 255


@functools.cache
def default_platform() -> str:
    """`jax.default_backend()`, resolved once per process.

    The platform cannot change mid-process, and querying it inside traced
    code paths (every `auto` dispatch used to) is wasted work on each
    predict call — plan builders and the auto dispatch both read this
    cached value instead.
    """
    return jax.default_backend()


def _on_tpu() -> bool:
    return default_platform() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# Pad-op accounting, split by which side of the problem was padded:
#   model — ensemble arrays (borders / splits / leaf values); a prepared
#           plan must incur these exactly once, at build time
#   data  — per-batch arrays (x / bins / idx); unavoidable per call
# Counters tick only when a pad actually happens (width 0 is free) and
# only when the padding code runs, i.e. once per trace under jit.
_PAD_STATS = {"model": 0, "data": 0}


def pad_stats() -> dict[str, int]:
    return dict(_PAD_STATS)


def reset_pad_stats() -> None:
    for k in _PAD_STATS:
        _PAD_STATS[k] = 0


def _pad_dim(a: jax.Array, axis: int, target: int, value=0,
             kind: str = "data") -> jax.Array:
    pad = target - a.shape[axis]
    if pad == 0:
        return a
    _PAD_STATS[kind] += 1
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def pad_features(bins: jax.Array, target_f: int) -> jax.Array:
    """Data-side pad of a bin matrix's feature axis up to `target_f`
    (the prepadded model's aligned width).  Zero bins are what +inf
    padding borders would have produced, so the pad is exact."""
    return _pad_dim(bins, 1, target_f)


def _require_u8_borders(borders: jax.Array) -> None:
    if borders.shape[0] > MAX_U8_BORDERS:
        raise ValueError(
            f"uint8 bins need <= {MAX_U8_BORDERS} borders, got "
            f"{borders.shape[0]} (see quantize.compute_borders's "
            "max_bins cap)")


# Layout capability shorthands (see repro.core.layout): ops that read
# no tree-structure arrays work under every physical layout; soa tree
# kernels also serve depth_grouped, which evaluates group-by-group
# through them.  bitpacked has its own `_bp` structure kernels, so soa
# tree kernels do NOT claim it.
ALL_LAYOUTS = ("soa", "depth_major", "depth_grouped", "bitpacked")
SOA_LAYOUTS = ("soa", "depth_grouped")


# --------------------------------------------------------------------------
# Registered implementations: binarize
# --------------------------------------------------------------------------
@registry.register("binarize", "ref", dtypes=("int32",),
                   layouts=ALL_LAYOUTS,
                   constraints="any shape; pure-jnp oracle")
def _binarize_ref(x, borders, *, prepadded=False, **_blocks):
    if prepadded:
        x = _pad_dim(x, 1, borders.shape[1])
    return _ref.binarize(x, borders)


@registry.register("binarize", "ref_u8", dtypes=("uint8",),
                   layouts=ALL_LAYOUTS,
                   constraints="<= 255 borders; uint8 bins out")
def _binarize_ref_u8(x, borders, *, prepadded=False, **_blocks):
    if prepadded:
        x = _pad_dim(x, 1, borders.shape[1])
    return _ref.binarize_u8(x, borders)


def _binarize_pallas_impl(x, borders, *, block_n, block_f, prepadded,
                          out_dtype):
    if prepadded:
        # Borders already F-aligned (+inf pad columns); only the data
        # side is padded per call.  Padded feature columns stay in the
        # output so downstream prepadded stages see an aligned F axis.
        Fp = borders.shape[1]
        xp = _pad_dim(x, 1, Fp)
        N = x.shape[0]
        Np = _round_up(max(N, 1), block_n)
        xp = _pad_dim(xp, 0, Np)
        out = _binarize_k.binarize(xp, borders, block_n=block_n,
                                   block_f=FEATURE_ALIGN,
                                   interpret=_interpret(),
                                   out_dtype=out_dtype)
        return out[:N]
    N, F = x.shape
    Np, Fp = _round_up(max(N, 1), block_n), _round_up(max(F, 1), block_f)
    xp = _pad_dim(_pad_dim(x, 0, Np), 1, Fp)
    bp = _pad_dim(borders, 1, Fp, value=np.float32(np.inf), kind="model")
    out = _binarize_k.binarize(xp, bp, block_n=block_n, block_f=block_f,
                               interpret=_interpret(), out_dtype=out_dtype)
    return out[:N, :F]


@registry.register("binarize", "pallas", dtypes=("int32",),
                   layouts=ALL_LAYOUTS,
                   constraints="pads N/F to block multiples")
def _binarize_pallas(x, borders, *, block_n=256, block_f=128,
                     prepadded=False):
    return _binarize_pallas_impl(x, borders, block_n=block_n,
                                 block_f=block_f, prepadded=prepadded,
                                 out_dtype=jnp.int32)


@registry.register("binarize", "pallas_u8", dtypes=("uint8",),
                   layouts=ALL_LAYOUTS,
                   constraints="<= 255 borders; u8 stores tile (32, 128) "
                               "on real TPUs")
def _binarize_pallas_u8(x, borders, *, block_n=256, block_f=128,
                        prepadded=False):
    _require_u8_borders(borders)
    return _binarize_pallas_impl(x, borders, block_n=block_n,
                                 block_f=block_f, prepadded=prepadded,
                                 out_dtype=jnp.uint8)


# --------------------------------------------------------------------------
# Registered implementations: leaf_index
# --------------------------------------------------------------------------
# Declared widening exception (PR 6's depth_grouped-on-uint8 audit):
# the jnp oracle's `gathered >= split_bins` promotes the gathered uint8
# panel to int32 (XLA type promotion against the int32 split_bins).
# Cost: a transient (N, T, D) int32 comparison panel instead of uint8 —
# acceptable for the clarity-first oracle, where XLA:CPU fuses the
# widening into the compare and no VMEM contract applies.  The
# production uint8 paths (pallas_u8 one-hot contract, ref_bp/pallas_bp
# narrowed-threshold compare) stay unwidened and unsuppressed.
@registry.register("leaf_index", "ref", dtypes=("int32", "uint8"),
                   layouts=SOA_LAYOUTS,
                   constraints="any shape; bins int32 or uint8",
                   suppressions=(
                       "widening: jnp oracle promotes the gathered "
                       "panel to int32 by comparison against int32 "
                       "split_bins; clarity-first oracle, no VMEM "
                       "contract (depth_grouped routes here too)",))
def _leaf_index_ref(bins, sf, sb, *, prepadded=False, **_blocks):
    return _ref.leaf_index(bins, sf, sb)


def _leaf_index_pallas_impl(kernel, bins, sf, sb, *, block_n, block_t,
                            prepadded):
    if prepadded:
        N = bins.shape[0]
        Np = _round_up(max(N, 1), block_n)
        binsp = _pad_dim(bins, 0, Np)
        out = kernel(binsp, sf, sb, block_n=block_n, block_t=block_t,
                     interpret=_interpret())
        return out[:N]
    N, F = bins.shape
    T, D = sf.shape
    Np, Tp = _round_up(N, block_n), _round_up(T, block_t)
    Fp = _round_up(F, FEATURE_ALIGN)
    binsp = _pad_dim(_pad_dim(bins, 0, Np), 1, Fp)
    sfp = _pad_dim(sf, 0, Tp, kind="model")
    sbp = _pad_dim(sb, 0, Tp, value=PAD_SPLIT_BIN, kind="model")
    out = kernel(binsp, sfp, sbp, block_n=block_n, block_t=block_t,
                 interpret=_interpret())
    return out[:N, :T]


@registry.register("leaf_index", "pallas", dtypes=("int32",),
                   layouts=SOA_LAYOUTS,
                   constraints="pads N/T to block multiples")
def _leaf_index_pallas(bins, sf, sb, *, block_n=256, block_t=16,
                       prepadded=False):
    return _leaf_index_pallas_impl(_index_k.leaf_index, bins, sf, sb,
                                   block_n=block_n, block_t=block_t,
                                   prepadded=prepadded)


@registry.register("leaf_index", "pallas_u8", dtypes=("uint8",),
                   layouts=SOA_LAYOUTS,
                   constraints="uint8 bins (quantized pool); u8 loads tile "
                               "(32, 128) on real TPUs")
def _leaf_index_pallas_u8(bins, sf, sb, *, block_n=256, block_t=16,
                          prepadded=False):
    return _leaf_index_pallas_impl(_index_k.leaf_index_u8, bins, sf, sb,
                                   block_n=block_n, block_t=block_t,
                                   prepadded=prepadded)


# Depth-major layout variants: consume the lowered (onehot, sb_dm, pow2)
# arrays instead of (split_features, split_bins).  The model side is
# always produced pre-padded by `layout.lower`, so only the data side
# is padded here.
@registry.register("leaf_index", "ref_dm", dtypes=("int32", "uint8"),
                   layouts=("depth_major",),
                   constraints="depth-major lowered model; any shape")
def _leaf_index_ref_dm(bins, onehot, sb_dm, pow2, *, prepadded=False,
                       **_blocks):
    return _ref.leaf_index_depth_major(bins, onehot, sb_dm, pow2)


@registry.register("leaf_index", "pallas_dm", dtypes=("int32", "uint8"),
                   layouts=("depth_major",),
                   constraints="depth-major lowered model (T/F pre-padded "
                               "at lower time); pads N per call")
def _leaf_index_pallas_dm(bins, onehot, sb_dm, pow2, *, block_n=256,
                          block_t=16, prepadded=False):
    N = bins.shape[0]
    Np = _round_up(max(N, 1), block_n)
    binsp = _pad_dim(bins, 0, Np)
    out = _index_k.leaf_index_dm(binsp, onehot, sb_dm, pow2,
                                 block_n=block_n, block_t=block_t,
                                 interpret=_interpret())
    return out[:N]


# Bitpacked layout variants: consume the bit-plane transposed
# (split_features_bp, split_bins_bp) arrays, both (D, T).  Integer-only
# index assembly — no one-hot, no MXU (see kernels/leaf_index.py).
@registry.register("leaf_index", "ref_bp", dtypes=("int32", "uint8"),
                   layouts=("bitpacked",),
                   constraints="bitpacked bit-plane lowered model; any "
                               "shape; integer-only shift/or assembly")
def _leaf_index_ref_bp(bins, sf_bp, sb_bp, *, prepadded=False, **_blocks):
    return _ref.leaf_index_bitpacked(bins, sf_bp, sb_bp)


@registry.register("leaf_index", "pallas_bp", dtypes=("int32", "uint8"),
                   layouts=("bitpacked",),
                   constraints="bitpacked lowered model (T pre-padded at "
                               "lower time); pads N per call; packs 32-doc "
                               "uint32 lanes, block_n % 32 == 0")
def _leaf_index_pallas_bp(bins, sf_bp, sb_bp, *, block_n=256, block_t=16,
                          prepadded=False):
    T = sf_bp.shape[1]
    if T % block_t:
        # direct registry dispatch may hand an unpadded T; plans always
        # lower the model pre-padded to the block multiple
        block_t = next(bt for bt in (64, 32, 16, 8, 4, 2, 1) if T % bt == 0)
    N = bins.shape[0]
    Np = _round_up(max(N, 1), block_n)
    binsp = _pad_dim(bins, 0, Np)
    out = _index_k.leaf_index_bp(binsp, sf_bp, sb_bp, block_n=block_n,
                                 block_t=block_t, interpret=_interpret())
    return out[:N]


# --------------------------------------------------------------------------
# Registered implementations: leaf_gather
# --------------------------------------------------------------------------
@registry.register("leaf_gather", "ref", dtypes=("int32",),
                   layouts=ALL_LAYOUTS,
                   constraints="any shape; pure-jnp oracle")
def _leaf_gather_ref(idx, leaf_values, *, prepadded=False, **_blocks):
    return _ref.leaf_gather(idx, leaf_values)


@registry.register("leaf_gather", "pallas", dtypes=("int32",),
                   layouts=ALL_LAYOUTS,
                   constraints="pads N/T to block multiples")
def _leaf_gather_pallas(idx, leaf_values, *, block_n=128, block_t=16,
                        prepadded=False):
    if prepadded:
        N = idx.shape[0]
        Np = _round_up(max(N, 1), block_n)
        idxp = _pad_dim(idx, 0, Np)
        out = _gather_k.leaf_gather(idxp, leaf_values, block_n=block_n,
                                    block_t=block_t, interpret=_interpret())
        return out[:N]
    N, T = idx.shape
    Np, Tp = _round_up(N, block_n), _round_up(T, block_t)
    idxp = _pad_dim(_pad_dim(idx, 0, Np), 1, Tp)
    lvp = _pad_dim(leaf_values, 0, Tp, kind="model")  # zero leaves: no-op trees
    out = _gather_k.leaf_gather(idxp, lvp, block_n=block_n, block_t=block_t,
                                interpret=_interpret())
    return out[:N]


# --------------------------------------------------------------------------
# Registered implementations: l2sq (rank-dispatched rowwise / matrix)
# --------------------------------------------------------------------------
@registry.register("l2sq", "ref", dtypes=("float32",),
                   layouts=ALL_LAYOUTS,
                   constraints="rowwise (K,)x(N,K) or matrix (M,K)x(N,K)")
def _l2sq_ref(a, b, **_blocks):
    return _ref.l2sq_rowwise(a, b) if a.ndim == 1 else _ref.l2sq_matrix(a, b)


@registry.register("l2sq", "pallas", dtypes=("float32",),
                   layouts=ALL_LAYOUTS,
                   constraints="rowwise (K,)x(N,K) or matrix (M,K)x(N,K); "
                               "pads to block multiples")
def _l2sq_pallas(a, b, *, block_m=128, block_n=128, block_k=128):
    if a.ndim == 1:
        N, K = b.shape
        Np, Kp = _round_up(N, block_n), _round_up(K, block_k)
        qp = _pad_dim(a, 0, Kp)
        rp = _pad_dim(_pad_dim(b, 0, Np), 1, Kp)
        out = _l2_k.l2sq_rowwise(qp, rp, block_n=block_n, block_k=block_k,
                                 interpret=_interpret())
        return out[:N]
    M, K = a.shape
    N, _ = b.shape
    Mp, Np_, Kp = (_round_up(M, block_m), _round_up(N, block_n),
                   _round_up(K, block_k))
    ap = _pad_dim(_pad_dim(a, 0, Mp), 1, Kp)
    bp = _pad_dim(_pad_dim(b, 0, Np_), 1, Kp)
    out = _l2_k.l2sq_matrix(ap, bp, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=_interpret())
    return out[:M, :N]


# --------------------------------------------------------------------------
# Registered implementations: fused_predict
# --------------------------------------------------------------------------
@registry.register("fused_predict", "ref", dtypes=("int32",),
                   layouts=SOA_LAYOUTS,
                   constraints="any shape; pure-jnp oracle")
def _fused_ref(x, borders, sf, sb, lv, *, prepadded=False, **_blocks):
    if prepadded:
        x = _pad_dim(x, 1, borders.shape[1])
    return _ref.fused_predict(x, borders, sf, sb, lv)


@registry.register("fused_predict", "pallas", dtypes=("int32", "uint8"),
                   layouts=SOA_LAYOUTS,
                   constraints="pads N/T/F to block multiples; u8 bins "
                               "scratch when <= 255 borders")
def _fused_pallas(x, borders, sf, sb, lv, *, block_n=None, block_t=None,
                  prepadded=False):
    # uint8 scratch quarters the VMEM the binarized block occupies
    # across tree blocks whenever the bin ids fit a byte — exact either
    # way, so this is not a user-facing choice.
    scratch = (jnp.uint8 if borders.shape[0] <= MAX_U8_BORDERS
               else jnp.int32)
    if prepadded:
        N = x.shape[0]
        Np = _round_up(max(N, 1), block_n)
        xp = _pad_dim(_pad_dim(x, 0, Np), 1, borders.shape[1])
        out = _fused_k.fused_predict(xp, borders, sf, sb, lv,
                                     block_n=block_n, block_t=block_t,
                                     interpret=_interpret(),
                                     bins_scratch_dtype=scratch)
        return out[:N]
    N, F = x.shape
    T, D = sf.shape
    _, L, C = lv.shape
    if block_n is None or block_t is None:
        tn, tt = _tuning.best_fused_blocks(
            F, D, L, C, borders.shape[0], n_rows=N, n_trees=T)
        block_n = block_n or tn
        block_t = block_t or tt
    Np = _round_up(N, block_n)
    Tp = _round_up(T, block_t)
    Fp = _round_up(F, FEATURE_ALIGN)
    xp = _pad_dim(_pad_dim(x, 0, Np), 1, Fp)
    bp = _pad_dim(borders, 1, Fp, value=np.float32(np.inf), kind="model")
    sfp = _pad_dim(sf, 0, Tp, kind="model")
    sbp = _pad_dim(sb, 0, Tp, value=PAD_SPLIT_BIN, kind="model")
    lvp = _pad_dim(lv, 0, Tp, kind="model")
    out = _fused_k.fused_predict(xp, bp, sfp, sbp, lvp, block_n=block_n,
                                 block_t=block_t, interpret=_interpret(),
                                 bins_scratch_dtype=scratch)
    return out[:N]


@registry.register("fused_predict", "ref_dm", dtypes=("int32",),
                   layouts=("depth_major",),
                   constraints="depth-major lowered model; any shape")
def _fused_ref_dm(x, borders, onehot, sb_dm, pow2, lv, *, prepadded=False,
                  **_blocks):
    if prepadded:
        x = _pad_dim(x, 1, borders.shape[1])
    return _ref.fused_predict_depth_major(x, borders, onehot, sb_dm,
                                          pow2, lv)


@registry.register("fused_predict", "pallas_dm", dtypes=("int32", "uint8"),
                   layouts=("depth_major",),
                   constraints="depth-major lowered model (T/F pre-padded "
                               "at lower time); pads N per call; u8 bins "
                               "scratch when <= 255 borders")
def _fused_pallas_dm(x, borders, onehot, sb_dm, pow2, lv, *,
                     block_n=None, block_t=None, prepadded=False):
    scratch = (jnp.uint8 if borders.shape[0] <= MAX_U8_BORDERS
               else jnp.int32)
    if block_n is None or block_t is None:
        # same autotune fallback as the soa impl (plans always pass
        # concrete blocks; direct registry dispatch may not) — except
        # the model side is already lowered here, so block_t must
        # divide the pre-padded T rather than drive its padding
        T, D, F = onehot.shape
        _, L, C = lv.shape
        tn, tt = _tuning.best_fused_blocks(
            F, D, L, C, borders.shape[0], n_rows=x.shape[0], n_trees=T)
        block_n = block_n or tn
        if block_t is None:
            block_t = next(bt for bt in (tt, 64, 32, 16, 8, 4, 2, 1)
                           if T % bt == 0)
    N = x.shape[0]
    Np = _round_up(max(N, 1), block_n)
    xp = _pad_dim(_pad_dim(x, 0, Np), 1, borders.shape[1])
    out = _fused_k.fused_predict_dm(xp, borders, onehot, sb_dm, pow2, lv,
                                    block_n=block_n, block_t=block_t,
                                    interpret=_interpret(),
                                    bins_scratch_dtype=scratch)
    return out[:N]


@registry.register("fused_predict", "ref_bp", dtypes=("int32",),
                   layouts=("bitpacked",),
                   constraints="bitpacked lowered model; any shape")
def _fused_ref_bp(x, borders, sf_bp, sb_bp, lv, *, prepadded=False,
                  **_blocks):
    if prepadded:
        x = _pad_dim(x, 1, borders.shape[1])
    return _ref.fused_predict_bitpacked(x, borders, sf_bp, sb_bp, lv)


@registry.register("fused_predict", "pallas_bp", dtypes=("int32", "uint8"),
                   layouts=("bitpacked",),
                   constraints="bitpacked lowered model (T pre-padded at "
                               "lower time); pads N per call; u8 bins "
                               "scratch when <= 255 borders")
def _fused_pallas_bp(x, borders, sf_bp, sb_bp, lv, *, block_n=None,
                     block_t=None, prepadded=False):
    scratch = (jnp.uint8 if borders.shape[0] <= MAX_U8_BORDERS
               else jnp.int32)
    D, T = sf_bp.shape
    if block_n is None or block_t is None:
        # same autotune fallback as the dm impl: the model side is
        # lowered, so block_t must divide the pre-padded T
        _, L, C = lv.shape
        tn, tt = _tuning.best_fused_blocks(
            borders.shape[1], D, L, C, borders.shape[0], n_rows=x.shape[0],
            n_trees=T)
        block_n = block_n or tn
        if block_t is None:
            block_t = next(bt for bt in (tt, 64, 32, 16, 8, 4, 2, 1)
                           if T % bt == 0)
    N = x.shape[0]
    Np = _round_up(max(N, 1), block_n)
    xp = _pad_dim(_pad_dim(x, 0, Np), 1, borders.shape[1])
    out = _fused_k.fused_predict_bp(xp, borders, sf_bp, sb_bp, lv,
                                    block_n=block_n, block_t=block_t,
                                    interpret=_interpret(),
                                    bins_scratch_dtype=scratch)
    return out[:N]


# --------------------------------------------------------------------------
# Registered implementations: histogram (training-side hot loop)
# --------------------------------------------------------------------------
# Layout-independent like binarize: the inputs carry no lowered model
# structure, only the feature-major bin stream and per-sample stats.
# Declared widening exception: the segment-sum oracle widens pool bins
# to int32 segment ids (`leaf * n_bins + bins`) — the exact shape of
# the PR-7 histogram bug, intentional here because the oracle optimizes
# for clarity over bandwidth (histogram.histogram_ref's docstring).
# The production uint8 path is histogram:pallas_u8, which compares the
# byte stream unwidened and carries no suppression.
@registry.register("histogram", "ref", dtypes=("int32", "uint8"),
                   layouts=ALL_LAYOUTS,
                   constraints="any shape; segment-sum oracle",
                   suppressions=(
                       "widening: segment-sum oracle forms int32 "
                       "segment ids from pool bins; benign oracle "
                       "clarity (production u8 path is pallas_u8)",))
def _histogram_ref(bins_t, leaf, g, *, n_bins, n_leaves, **_blocks):
    return _hist_k.histogram_ref(bins_t, leaf, g, n_bins=n_bins,
                                 n_leaves=n_leaves)


def _histogram_pallas_impl(bins_t, leaf, g, *, n_bins, n_leaves,
                           block_f, block_n):
    F, N = bins_t.shape
    if block_f is None or block_n is None:
        bf, bn = _tuning.best_hist_blocks(
            F, n_leaves, n_bins, g.shape[1], n_rows=N,
            bins_bytes=1 if bins_t.dtype == jnp.uint8 else 4)
        block_f = block_f or bf
        block_n = block_n or bn
    Fp = _round_up(max(F, 1), block_f)
    Np = _round_up(max(N, 1), block_n)
    # padded samples carry g == 0 so they accumulate nothing; padded
    # features land in hist rows [F:] and are sliced off
    binsp = _pad_dim(_pad_dim(bins_t, 0, Fp), 1, Np)
    leafp = _pad_dim(leaf, 0, Np)
    gp = _pad_dim(g, 0, Np)
    out = _hist_k.histogram(binsp, leafp, gp, n_bins=n_bins,
                            n_leaves=n_leaves, block_f=block_f,
                            block_n=block_n, interpret=_interpret())
    return out[:F]


@registry.register("histogram", "pallas", dtypes=("int32",),
                   layouts=ALL_LAYOUTS,
                   constraints="pads F/N to block multiples; padded "
                               "samples get g == 0")
def _histogram_pallas(bins_t, leaf, g, *, n_bins, n_leaves, block_f=None,
                      block_n=None):
    return _histogram_pallas_impl(bins_t, leaf, g, n_bins=n_bins,
                                  n_leaves=n_leaves, block_f=block_f,
                                  block_n=block_n)


@registry.register("histogram", "pallas_u8", dtypes=("uint8",),
                   layouts=ALL_LAYOUTS,
                   constraints="uint8 pool bins compared unwidened "
                               "against the bin digit; <= 256 bins")
def _histogram_pallas_u8(bins_t, leaf, g, *, n_bins, n_leaves,
                         block_f=None, block_n=None):
    return _histogram_pallas_impl(bins_t, leaf, g, n_bins=n_bins,
                                  n_leaves=n_leaves, block_f=block_f,
                                  block_n=block_n)


# --------------------------------------------------------------------------
# Public ops — legacy `backend=` kwargs as shims over registry dispatch
# --------------------------------------------------------------------------
def _bins_dtype(bins: jax.Array) -> str:
    return "uint8" if bins.dtype == jnp.uint8 else "int32"


def binarize(x: jax.Array, borders: jax.Array, *, backend: Backend = "auto",
             block_n: int = 256, block_f: int = 128) -> jax.Array:
    """(N, F) f32, (B, F) f32 -> (N, F) int32 bin indices."""
    return registry.dispatch("binarize", backend, x, borders,
                             block_n=block_n, block_f=block_f)


def binarize_u8(x: jax.Array, borders: jax.Array, *,
                backend: Backend = "auto", block_n: int = 256,
                block_f: int = 128) -> jax.Array:
    """(N, F) f32, (B, F) f32 -> (N, F) uint8 bin indices (B <= 255).

    The quantized-pool representation: one byte per (sample, feature),
    exactly the stream the paper's CalcIndexes loop consumes."""
    return registry.dispatch("binarize", backend, x, borders,
                             dtype="uint8", block_n=block_n,
                             block_f=block_f)


def leaf_index(bins: jax.Array, split_features: jax.Array,
               split_bins: jax.Array, *, backend: Backend = "auto",
               block_n: int = 256, block_t: int = 16) -> jax.Array:
    """(N, F) i32|u8, (T, D) i32, (T, D) i32 -> (N, T) int32 leaf ids.

    uint8 bins route to the u8 kernel variant automatically."""
    return registry.dispatch("leaf_index", backend, bins, split_features,
                             split_bins, dtype=_bins_dtype(bins),
                             block_n=block_n, block_t=block_t)


def leaf_gather(idx: jax.Array, leaf_values: jax.Array, *,
                backend: Backend = "auto", block_n: int = 128,
                block_t: int = 16) -> jax.Array:
    """(N, T) i32, (T, L, C) f32 -> (N, C) f32 summed leaf values."""
    return registry.dispatch("leaf_gather", backend, idx, leaf_values,
                             block_n=block_n, block_t=block_t)


def histogram(bins_t: jax.Array, leaf: jax.Array, g: jax.Array, *,
              n_bins: int, n_leaves: int, backend: Backend = "auto",
              block_f: int | None = None,
              block_n: int | None = None) -> jax.Array:
    """(F, N) i32|u8 feature-major bins, (N,) i32 leaf ids, (N, C) f32
    per-sample stats -> (F, n_leaves*n_bins, C) f32 histogram.

    The training-side hot loop (one call per tree level): stats are
    accumulated per (feature, leaf, bin) cell.  uint8 pool bins route
    to the u8 kernel variant, which never widens the bins panel.
    `g` usually carries gradients and hessians concatenated on the
    stats axis so both histograms cost one pass."""
    return registry.dispatch("histogram", backend, bins_t, leaf, g,
                             dtype=_bins_dtype(bins_t), n_bins=n_bins,
                             n_leaves=n_leaves, block_f=block_f,
                             block_n=block_n)


def l2sq_rowwise(q: jax.Array, refs: jax.Array, *, backend: Backend = "auto",
                 block_n: int = 256, block_k: int = 128) -> jax.Array:
    """(K,), (N, K) -> (N,) squared L2 distances."""
    return registry.dispatch("l2sq", backend, q, refs,
                             block_n=block_n, block_k=block_k)


def l2sq_matrix(a: jax.Array, b: jax.Array, *, backend: Backend = "auto",
                block_m: int = 128, block_n: int = 128,
                block_k: int = 128) -> jax.Array:
    """(M, K), (N, K) -> (M, N) squared L2 distance matrix."""
    return registry.dispatch("l2sq", backend, a, b, block_m=block_m,
                             block_n=block_n, block_k=block_k)


def fused_predict(x: jax.Array, borders: jax.Array, split_features: jax.Array,
                  split_bins: jax.Array, leaf_values: jax.Array, *,
                  backend: Backend = "auto", block_n: int | None = None,
                  block_t: int | None = None) -> jax.Array:
    """Fused binarize+index+gather -> (N, C) f32.

    Inputs need no pre-padding: N/T/F are padded here to the block
    multiples (padded trees get zero leaf values and an impossible
    split bin, so they contribute nothing).  When block_n/block_t are
    None the shapes come from the VMEM footprint model in
    `kernels.tuning` (the RVV-LMUL analog), sized to this ensemble and
    batch instead of a fixed (128, 16).
    """
    return registry.dispatch("fused_predict", backend, x, borders,
                             split_features, split_bins, leaf_values,
                             block_n=block_n, block_t=block_t)


# --------------------------------------------------------------------------
# Prepadded-model fast paths (the compiled-plan Predictor's hot loop)
# --------------------------------------------------------------------------
# These entry points take ensemble arrays that a plan builder
# (`core.predictor.Predictor.build`) has already padded to block
# multiples, so only the data side (x / bins / idx) is padded per call —
# the per-call model `jnp.pad`s the paper hoists out of the loop are gone.
# Invariants the builder guarantees for the pallas backend:
#   borders  F padded to a FEATURE_ALIGN multiple with +inf
#   splits   T padded to a block_t multiple (bins=PAD_SPLIT_BIN: go left)
#   leaves   T padded with zeros (padded trees contribute nothing)
# On the ref backend the same arrays work unpadded — ref kernels accept
# any shape — so a ref plan carries the original arrays through.

def fused_predict_prepadded(x: jax.Array, borders: jax.Array,
                            split_features: jax.Array, split_bins: jax.Array,
                            leaf_values: jax.Array, *,
                            backend: Backend = "auto",
                            block_n: int = 128,
                            block_t: int = 16) -> jax.Array:
    """Fused predict on a prepadded model -> (N, C) f32."""
    return registry.dispatch("fused_predict", backend, x, borders,
                             split_features, split_bins, leaf_values,
                             block_n=block_n, block_t=block_t,
                             prepadded=True)


def binarize_prepadded(x: jax.Array, borders: jax.Array, *,
                       backend: Backend = "auto",
                       block_n: int = 256) -> jax.Array:
    """Binarize against prepadded borders -> (N, Fp) int32.

    Keeps the padded feature columns (bins for +inf-border features are
    zero) so the downstream prepadded stages see an aligned F axis.
    """
    return registry.dispatch("binarize", backend, x, borders,
                             block_n=block_n, prepadded=True)


def binarize_u8_prepadded(x: jax.Array, borders: jax.Array, *,
                          backend: Backend = "auto",
                          block_n: int = 256) -> jax.Array:
    """Binarize against prepadded borders -> (N, Fp) uint8 (B <= 255).

    The plan's quantize entry: same aligned-F contract as
    `binarize_prepadded`, but emitting the one-byte quantized-pool
    stream."""
    return registry.dispatch("binarize", backend, x, borders,
                             dtype="uint8", block_n=block_n,
                             prepadded=True)


def leaf_index_prepadded(bins: jax.Array, split_features: jax.Array,
                         split_bins: jax.Array, *,
                         backend: Backend = "auto", block_n: int = 256,
                         block_t: int = 16) -> jax.Array:
    """Leaf indices on prepadded splits -> (N, Tp) int32 (padded trees
    land in leaf 0, which holds a zero leaf value).  Accepts int32 or
    uint8 bins (the quantized-pool scoring path)."""
    return registry.dispatch("leaf_index", backend, bins, split_features,
                             split_bins, dtype=_bins_dtype(bins),
                             block_n=block_n, block_t=block_t,
                             prepadded=True)


def leaf_gather_prepadded(idx: jax.Array, leaf_values: jax.Array, *,
                          backend: Backend = "auto", block_n: int = 128,
                          block_t: int = 16) -> jax.Array:
    """Sum prepadded leaf values at idx -> (N, C) f32."""
    return registry.dispatch("leaf_gather", backend, idx, leaf_values,
                             block_n=block_n, block_t=block_t,
                             prepadded=True)


# --------------------------------------------------------------------------
# Depth-major layout entry points (lowered-model hot loop)
# --------------------------------------------------------------------------
# These take the `DepthMajorLayout` arrays `layout.lower` produced —
# the one-hot gather matrix, bit-plane split bins and the hoisted pow2
# vector — so the kernels never rebuild iota/one-hot per call.  The
# model side is always lowered pre-padded; data is padded per call.

def leaf_index_dm_prepadded(bins: jax.Array, onehot: jax.Array,
                            split_bins_dm: jax.Array, pow2: jax.Array, *,
                            backend: Backend = "auto", block_n: int = 256,
                            block_t: int = 16) -> jax.Array:
    """Leaf indices from a depth-major lowered model -> (N, Tp) int32.
    Accepts int32 or uint8 bins (quantized-pool scoring)."""
    return registry.dispatch("leaf_index", backend, bins, onehot,
                             split_bins_dm, pow2,
                             dtype=_bins_dtype(bins),
                             layout="depth_major",
                             block_n=block_n, block_t=block_t,
                             prepadded=True)


def fused_predict_dm_prepadded(x: jax.Array, borders: jax.Array,
                               onehot: jax.Array, split_bins_dm: jax.Array,
                               pow2: jax.Array, leaf_values: jax.Array, *,
                               backend: Backend = "auto",
                               block_n: int = 128,
                               block_t: int = 16) -> jax.Array:
    """Fused predict on a depth-major lowered model -> (N, C) f32."""
    return registry.dispatch("fused_predict", backend, x, borders, onehot,
                             split_bins_dm, pow2, leaf_values,
                             layout="depth_major",
                             block_n=block_n, block_t=block_t,
                             prepadded=True)


# --------------------------------------------------------------------------
# Bitpacked layout entry points (lowered-model hot loop)
# --------------------------------------------------------------------------
# These take the `BitpackedLayout` bit-plane arrays `layout.lower`
# produced — (D, T) transposed split features/thresholds — so index
# assembly runs as integer shift/or with no one-hot anywhere.  The
# model side is always lowered pre-padded; data is padded per call.

def leaf_index_bp_prepadded(bins: jax.Array, split_features_bp: jax.Array,
                            split_bins_bp: jax.Array, *,
                            backend: Backend = "auto", block_n: int = 256,
                            block_t: int = 16) -> jax.Array:
    """Leaf indices from a bitpacked lowered model -> (N, Tp) int32.
    Accepts int32 or uint8 bins (quantized-pool scoring)."""
    return registry.dispatch("leaf_index", backend, bins, split_features_bp,
                             split_bins_bp, dtype=_bins_dtype(bins),
                             layout="bitpacked",
                             block_n=block_n, block_t=block_t,
                             prepadded=True)


def fused_predict_bp_prepadded(x: jax.Array, borders: jax.Array,
                               split_features_bp: jax.Array,
                               split_bins_bp: jax.Array,
                               leaf_values: jax.Array, *,
                               backend: Backend = "auto",
                               block_n: int = 128,
                               block_t: int = 16) -> jax.Array:
    """Fused predict on a bitpacked lowered model -> (N, C) f32."""
    return registry.dispatch("fused_predict", backend, x, borders,
                             split_features_bp, split_bins_bp, leaf_values,
                             layout="bitpacked",
                             block_n=block_n, block_t=block_t,
                             prepadded=True)
