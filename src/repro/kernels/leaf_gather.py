"""Pallas TPU kernel for leaf-value accumulation
(paper: CalculateLeafValues / CalculateLeafValuesMulti).

This is the hotspot the paper explicitly could NOT vectorize: RVV 0.7.1
gather/scatter is too slow to pay for the few arithmetic ops per element
(their Tables 2-3 show speedup 0.98-1.03x).  The TPU answer is to avoid
the gather unit entirely: `sum_t leaf_values[t, idx[n, t], :]` becomes a
one-hot matmul `onehot(idx) @ leaf_values` on the 128x128 MXU.  The
indirect addressing turns into dense systolic compute — the beyond-paper
optimization recorded in EXPERIMENTS.md SSPerf.

Grid: (N / block_n, T / block_t) with the T axis as a serial reduction;
the output tile is initialized at t-block 0 and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _leaf_gather_kernel(idx_ref, lv_ref, out_ref):
    t_blk = pl.program_id(1)
    idx = idx_ref[...]                                 # (bn, bt) int32
    lv = lv_ref[...]                                   # (bt, L, C) f32
    bn, bt = idx.shape
    _, L, C = lv.shape

    # onehot over the flattened (tree, leaf) axis -> one MXU matmul.
    leaf_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bt, L), 2)
    onehot = (leaf_iota == idx[:, :, None]).astype(jnp.float32)
    onehot = onehot.reshape(bn, bt * L)
    acc = jax.lax.dot(onehot, lv.reshape(bt * L, C),
                      preferred_element_type=jnp.float32)   # (bn, C)

    @pl.when(t_blk == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(t_blk != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block_n", "block_t", "interpret"))
def leaf_gather(idx: jax.Array, leaf_values: jax.Array, *, block_n: int = 128,
                block_t: int = 16, interpret: bool = False) -> jax.Array:
    """pred[n, c] = sum_t leaf_values[t, idx[n, t], c]  -> (N, C) float32.

    Pre-padded: N % block_n == 0, T % block_t == 0.  Padded trees must have
    all-zero leaf_values.
    """
    N, T = idx.shape
    _, L, C = leaf_values.shape
    grid = (N // block_n, T // block_t)
    return pl.pallas_call(
        _leaf_gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_t), lambda i, j: (i, j)),
            pl.BlockSpec((block_t, L, C), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, C), jnp.float32),
        interpret=interpret,
    )(idx, leaf_values)
