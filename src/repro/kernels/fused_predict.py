"""Beyond-paper Pallas kernel: fully fused GBDT prediction.

binarize -> leaf_index -> leaf_gather executed in a single VMEM-resident
pass over a sample block.  The paper's three hotspots run as separate
passes with HBM round-trips between them; since GBDT inference is
memory-bound (sub-1 FLOP/byte on the scalar path), fusing removes the
intermediate `bins` (N x F int32) and `idx` (N x T int32) HBM traffic
entirely.  Binarized features are computed once per sample block at
t-block 0 into VMEM scratch and reused for every tree block (the grid's
T axis is serial on TPU).

Grid: (N / block_n, T / block_t).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.leaf_index import _bp_compare_planes


def _fused_kernel(x_ref, borders_ref, sf_ref, sb_ref, lv_ref, out_ref,
                  bins_scratch, *, n_borders: int):
    t_blk = pl.program_id(1)

    # ---- Stage 1: binarize (once per sample block, persisted in VMEM) ----
    @pl.when(t_blk == 0)
    def _binarize():
        x = x_ref[...]                               # (bn, F)
        borders = borders_ref[...]                   # (B, F)

        def body(b, acc):
            row = jax.lax.dynamic_index_in_dim(borders, b, axis=0,
                                               keepdims=True)
            return acc + (x > row).astype(jnp.int32)

        # accumulate in int32, store in the scratch dtype (uint8 when
        # the ensemble fits 255 borders: 4x less VMEM held across every
        # tree block — the quantized-pool representation, in-kernel)
        bins_scratch[...] = jax.lax.fori_loop(
            0, n_borders, body,
            jnp.zeros(x.shape, jnp.int32)).astype(bins_scratch.dtype)

    bins = bins_scratch[...].astype(jnp.float32)     # (bn, F)
    sf = sf_ref[...]                                 # (bt, D)
    sb = sb_ref[...]                                 # (bt, D)
    lv = lv_ref[...]                                 # (bt, L, C)
    bt, D = sf.shape
    bn, F = bins.shape
    _, L, C = lv.shape

    # ---- Stage 2: leaf index (one-hot feature gather on the MXU) ----
    f_iota = jax.lax.broadcasted_iota(jnp.int32, (bt * D, F), 1)
    onehot_f = (f_iota == sf.reshape(bt * D, 1)).astype(jnp.float32)
    gathered = jax.lax.dot(onehot_f, bins.T,
                           preferred_element_type=jnp.float32)
    gathered = gathered.reshape(bt, D, bn)
    go_right = gathered >= sb[:, :, None].astype(jnp.float32)
    pow2 = (1 << jax.lax.broadcasted_iota(jnp.int32, (1, D, 1), 1)).astype(
        jnp.float32)
    idx = jnp.sum(go_right.astype(jnp.float32) * pow2, axis=1)   # (bt, bn)
    idx = idx.T.astype(jnp.int32)                                # (bn, bt)

    # ---- Stage 3: leaf accumulate (one-hot matmul on the MXU) ----
    leaf_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bt, L), 2)
    onehot_l = (leaf_iota == idx[:, :, None]).astype(jnp.float32)
    acc = jax.lax.dot(onehot_l.reshape(bn, bt * L), lv.reshape(bt * L, C),
                      preferred_element_type=jnp.float32)        # (bn, C)

    @pl.when(t_blk == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(t_blk != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_t", "interpret",
                                    "bins_scratch_dtype"))
def fused_predict(x: jax.Array, borders: jax.Array, split_features: jax.Array,
                  split_bins: jax.Array, leaf_values: jax.Array, *,
                  block_n: int = 128, block_t: int = 16,
                  interpret: bool = False,
                  bins_scratch_dtype=jnp.int32) -> jax.Array:
    """Fused GBDT predict -> (N, C) float32.

    Raw kernel entry: N and T must already be multiples of the block
    shapes and padded trees must carry zero leaf_values and
    split_bins > #bins (padded samples/features are harmless zeros).
    `kernels.ops.fused_predict` is the public wrapper that performs that
    padding and picks the block shapes from the tuner — call it, not
    this, unless you have pre-padded tensors.  `bins_scratch_dtype`
    uint8 (valid when B <= 255) quarters the VMEM the binarized block
    holds across tree blocks; values are exact either way.
    """
    N, F = x.shape
    B = borders.shape[0]
    T, D = split_features.shape
    _, L, C = leaf_values.shape
    if N % block_n or T % block_t:
        raise ValueError(
            f"fused_predict requires padded inputs: N={N} % block_n="
            f"{block_n} and T={T} % block_t={block_t} must be 0 "
            "(use kernels.ops.fused_predict for automatic padding)")
    grid = (N // block_n, T // block_t)
    return pl.pallas_call(
        functools.partial(_fused_kernel, n_borders=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((B, F), lambda i, j: (0, 0)),
            pl.BlockSpec((block_t, D), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, D), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, L, C), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n, F), bins_scratch_dtype)],
        interpret=interpret,
    )(x, borders, split_features, split_bins, leaf_values)


def _fused_dm_kernel(x_ref, borders_ref, onehot_ref, sb_ref, pow2_ref,
                     lv_ref, out_ref, bins_scratch, *, n_borders: int):
    t_blk = pl.program_id(1)

    # ---- Stage 1: binarize (identical to the soa kernel) ----
    @pl.when(t_blk == 0)
    def _binarize():
        x = x_ref[...]                               # (bn, F)
        borders = borders_ref[...]                   # (B, F)

        def body(b, acc):
            row = jax.lax.dynamic_index_in_dim(borders, b, axis=0,
                                               keepdims=True)
            return acc + (x > row).astype(jnp.int32)

        bins_scratch[...] = jax.lax.fori_loop(
            0, n_borders, body,
            jnp.zeros(x.shape, jnp.int32)).astype(bins_scratch.dtype)

    bins = bins_scratch[...].astype(jnp.float32)     # (bn, F)
    onehot = onehot_ref[...]                         # (bt, D, F) f32
    sb = sb_ref[...]                                 # (D, bt) int32
    pow2 = pow2_ref[...]                             # (D, 1) f32
    lv = lv_ref[...]                                 # (bt, L, C)
    bt, D, F = onehot.shape
    bn = bins.shape[0]
    _, L, C = lv.shape

    # ---- Stage 2: leaf index via the PRECOMPUTED one-hot ----
    # The soa kernel rebuilds iota + one-hot from split_features every
    # call; the depth-major layout hoists that to lower time, so stage 2
    # is a single MXU matmul against the lowered gather matrix.
    gathered = jax.lax.dot(onehot.reshape(bt * D, F), bins.T,
                           preferred_element_type=jnp.float32)
    gathered = gathered.reshape(bt, D, bn)
    go_right = gathered >= sb.T[:, :, None].astype(jnp.float32)
    idx = jnp.sum(go_right.astype(jnp.float32)
                  * pow2.reshape(1, D, 1), axis=1)               # (bt, bn)
    idx = idx.T.astype(jnp.int32)                                # (bn, bt)

    # ---- Stage 3: leaf accumulate (identical to the soa kernel) ----
    leaf_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bt, L), 2)
    onehot_l = (leaf_iota == idx[:, :, None]).astype(jnp.float32)
    acc = jax.lax.dot(onehot_l.reshape(bn, bt * L), lv.reshape(bt * L, C),
                      preferred_element_type=jnp.float32)        # (bn, C)

    @pl.when(t_blk == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(t_blk != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_t", "interpret",
                                    "bins_scratch_dtype"))
def fused_predict_dm(x: jax.Array, borders: jax.Array, onehot: jax.Array,
                     split_bins_dm: jax.Array, pow2: jax.Array,
                     leaf_values: jax.Array, *,
                     block_n: int = 128, block_t: int = 16,
                     interpret: bool = False,
                     bins_scratch_dtype=jnp.int32) -> jax.Array:
    """Fused GBDT predict over the depth-major lowered layout -> (N, C).

    Same contract as `fused_predict` with the model side replaced by
    the `DepthMajorLayout` arrays: `onehot` (T, D, F) f32 precomputed
    one-hot(sf), `split_bins_dm` (D, T) int32 bit planes, `pow2`
    (D, 1) f32.  N and T must be pre-padded to the block multiples.
    """
    N, F = x.shape
    B = borders.shape[0]
    T, D, _ = onehot.shape
    _, L, C = leaf_values.shape
    if N % block_n or T % block_t:
        raise ValueError(
            f"fused_predict_dm requires padded inputs: N={N} % block_n="
            f"{block_n} and T={T} % block_t={block_t} must be 0 "
            "(lowering pads the model; use the plan API)")
    grid = (N // block_n, T // block_t)
    return pl.pallas_call(
        functools.partial(_fused_dm_kernel, n_borders=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((B, F), lambda i, j: (0, 0)),
            pl.BlockSpec((block_t, D, F), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((D, block_t), lambda i, j: (0, j)),
            pl.BlockSpec((D, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_t, L, C), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n, F), bins_scratch_dtype)],
        interpret=interpret,
    )(x, borders, onehot, split_bins_dm, pow2, leaf_values)


def _fused_bp_kernel(x_ref, borders_ref, sf_ref, sb_ref, lv_ref, out_ref,
                     bins_scratch, *, n_borders: int):
    t_blk = pl.program_id(1)

    # ---- Stage 1: binarize (identical to the soa kernel) ----
    @pl.when(t_blk == 0)
    def _binarize():
        x = x_ref[...]                               # (bn, F)
        borders = borders_ref[...]                   # (B, F)

        def body(b, acc):
            row = jax.lax.dynamic_index_in_dim(borders, b, axis=0,
                                               keepdims=True)
            return acc + (x > row).astype(jnp.int32)

        bins_scratch[...] = jax.lax.fori_loop(
            0, n_borders, body,
            jnp.zeros(x.shape, jnp.int32)).astype(bins_scratch.dtype)

    bins = bins_scratch[...]                         # (bn, F) — stays integer
    sf = sf_ref[...]                                 # (D, bt) int32
    sb = sb_ref[...]                                 # (D, bt) int32
    lv = lv_ref[...]                                 # (bt, L, C)
    D, bt = sf.shape
    bn = bins.shape[0]
    _, L, C = lv.shape

    # ---- Stage 2: leaf index via bitpacked shift/or (no MXU) ----
    # Per depth the comparison is one bit per doc; 32-doc columns pack
    # into uint32 lane words and the index register accumulates bit d
    # with shift/or — integers end to end, no one-hot materialization.
    # A uint8 bins scratch (<= 255 borders) also compares unwidened:
    # thresholds narrow to uint8 with the PAD_SPLIT_BIN sentinel kept
    # as a mask (see leaf_index._bp_compare_planes), so the panel is
    # never upcast to int32.
    narrow = bins.dtype == jnp.uint8
    if narrow:
        sb_u8, live = _bp_compare_planes(sb)
    w = bn // 32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32, bt), 1)
    idx = jnp.zeros((bn, bt), jnp.int32)
    for d in range(D):                               # static unroll over depth
        cols = jnp.take(bins, sf[d], axis=1)         # (bn, bt) integer gather
        if narrow:
            go = (cols >= sb_u8[d][None, :]) & live[d][None, :]
        else:
            go = cols >= sb[d][None, :]
        bit = go.astype(jnp.uint32)
        words = jnp.sum(bit.reshape(w, 32, bt) << shifts, axis=1,
                        dtype=jnp.uint32)            # (w, bt) lane words
        plane = ((words[:, None, :] >> shifts) & jnp.uint32(1)
                 ).reshape(bn, bt).astype(jnp.int32)
        idx = idx | (plane << d)

    # ---- Stage 3: leaf accumulate (one-hot matmul, as in soa) ----
    # Gathering leaf values is the one stage where the MXU one-hot
    # earns its keep; the bitpacked win is confined to index assembly.
    leaf_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bt, L), 2)
    onehot_l = (leaf_iota == idx[:, :, None]).astype(jnp.float32)
    acc = jax.lax.dot(onehot_l.reshape(bn, bt * L), lv.reshape(bt * L, C),
                      preferred_element_type=jnp.float32)        # (bn, C)

    @pl.when(t_blk == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(t_blk != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_t", "interpret",
                                    "bins_scratch_dtype"))
def fused_predict_bp(x: jax.Array, borders: jax.Array,
                     split_features_bp: jax.Array, split_bins_bp: jax.Array,
                     leaf_values: jax.Array, *,
                     block_n: int = 128, block_t: int = 16,
                     interpret: bool = False,
                     bins_scratch_dtype=jnp.int32) -> jax.Array:
    """Fused GBDT predict over the bitpacked lowered layout -> (N, C).

    Same contract as `fused_predict` with the model side replaced by
    the `BitpackedLayout` bit-plane arrays: `split_features_bp` /
    `split_bins_bp`, both (D, T).  N and T must be pre-padded to the
    block multiples and block_n must be a multiple of 32 (whole uint32
    doc lanes).
    """
    N, F = x.shape
    B = borders.shape[0]
    D, T = split_features_bp.shape
    _, L, C = leaf_values.shape
    if N % block_n or T % block_t:
        raise ValueError(
            f"fused_predict_bp requires padded inputs: N={N} % block_n="
            f"{block_n} and T={T} % block_t={block_t} must be 0 "
            "(lowering pads the model; use the plan API)")
    if block_n % 32:
        raise ValueError(f"fused_predict_bp packs 32-doc uint32 lanes: "
                         f"block_n={block_n} must be a multiple of 32")
    grid = (N // block_n, T // block_t)
    return pl.pallas_call(
        functools.partial(_fused_bp_kernel, n_borders=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((B, F), lambda i, j: (0, 0)),
            pl.BlockSpec((D, block_t), lambda i, j: (0, j)),
            pl.BlockSpec((D, block_t), lambda i, j: (0, j)),
            pl.BlockSpec((block_t, L, C), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n, F), bins_scratch_dtype)],
        interpret=interpret,
    )(x, borders, split_features_bp.astype(jnp.int32),
      split_bins_bp.astype(jnp.int32), leaf_values)
