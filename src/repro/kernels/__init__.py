"""Pallas TPU kernels for the paper hotspots + pure-jnp oracles."""
from repro.kernels import ops, ref  # noqa: F401

__all__ = ["ops", "ref"]
