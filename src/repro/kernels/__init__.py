"""Pallas TPU kernels for the paper hotspots + pure-jnp oracles.

Importing the package registers every kernel implementation in
`repro.kernels.registry` (ops.py registers at import time), so
`from repro.kernels import registry; registry.table()` always sees the
full dispatch surface."""
from repro.kernels import ops, ref, registry  # noqa: F401

__all__ = ["ops", "ref", "registry"]
