"""Pallas TPU kernels for squared-L2 distance (paper: L2SqrDistance).

Two forms:

* `l2sq_rowwise` — paper-faithful VPU kernel: one query against many
  reference rows, fused subtract + multiply-accumulate (the RVV
  vfsub/vfmacc/vfredsum loop), tiled over (refs, feature-chunks) with the
  feature axis as a serial reduction.

* `l2sq_matrix` — beyond-paper MXU kernel for the KNN use case: the full
  pairwise matrix via ||a||^2 + ||b||^2 - 2 a.b^T, with the cross term on
  the systolic array and the (precomputed) norms added at the last
  K-block.  The paper computes distances row-by-row; a matrix engine makes
  the batched form compute-bound instead of load-bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------------------------
# Row-wise (paper-faithful) form
# --------------------------------------------------------------------------
def _l2_rowwise_kernel(q_ref, refs_ref, out_ref):
    k_blk = pl.program_id(1)
    q = q_ref[...]                    # (1, bk)
    refs = refs_ref[...]              # (bn, bk)
    d = refs - q                      # broadcast over rows (vfsub)
    partial = jnp.sum(d * d, axis=1, keepdims=True)   # (bn, 1)  (vfmacc+reduce)

    @pl.when(k_blk == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(k_blk != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def l2sq_rowwise(q: jax.Array, refs: jax.Array, *, block_n: int = 256,
                 block_k: int = 128, interpret: bool = False) -> jax.Array:
    """out[n] = ||refs[n] - q||^2  -> (N,) float32.  Pre-padded N, K."""
    N, K = refs.shape
    grid = (N // block_n, K // block_k)
    out = pl.pallas_call(
        _l2_rowwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_k), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        interpret=interpret,
    )(q.reshape(1, K), refs)
    return out[:, 0]


# --------------------------------------------------------------------------
# Matrix (beyond-paper, MXU) form
# --------------------------------------------------------------------------
def _l2_matrix_kernel(a_ref, b_ref, asq_ref, bsq_ref, out_ref, *,
                      k_blocks: int):
    k_blk = pl.program_id(2)
    a = a_ref[...]                    # (bm, bk)
    b = b_ref[...]                    # (bn, bk)
    cross = jax.lax.dot(a, b.T, preferred_element_type=jnp.float32)

    @pl.when(k_blk == 0)
    def _init():
        out_ref[...] = -2.0 * cross

    @pl.when(k_blk != 0)
    def _accum():
        out_ref[...] += -2.0 * cross

    @pl.when(k_blk == k_blocks - 1)
    def _final():
        out_ref[...] = jnp.maximum(
            out_ref[...] + asq_ref[...] + bsq_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def l2sq_matrix(a: jax.Array, b: jax.Array, *, block_m: int = 128,
                block_n: int = 128, block_k: int = 128,
                interpret: bool = False) -> jax.Array:
    """out[m, n] = ||a[m] - b[n]||^2  -> (M, N) float32.  Pre-padded M/N/K."""
    M, K = a.shape
    N, _ = b.shape
    a_sq = jnp.sum(a * a, axis=1, keepdims=True)          # (M, 1)
    b_sq = jnp.sum(b * b, axis=1, keepdims=True).T        # (1, N)
    k_blocks = K // block_k
    grid = (M // block_m, N // block_n, k_blocks)
    return pl.pallas_call(
        functools.partial(_l2_matrix_kernel, k_blocks=k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b, a_sq, b_sq)
