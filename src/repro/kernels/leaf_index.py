"""Pallas TPU kernel for oblivious-tree leaf index computation
(paper: CalcIndexesBasic).

The paper's RVV loop hoists a pre-shifted ones vector (1 << depth) out of
the loop, then per depth compares binarized features against the split
border (vmsgeu) and mask-ORs the shifted bit into the running index.

TPU adaptation: the per-(tree, depth) feature *gather* bins[n, sf[t, d]] —
the strided-access pattern RVV also struggles with — is reformulated as a
one-hot matmul on the MXU: onehot(sf) @ bins^T gathers D x block_t feature
columns for the whole sample block in one systolic pass.  The bit-OR
accumulation becomes a mask-weighted sum with the power-of-two vector
precomputed outside the loop (the paper's hoisting trick, verbatim).

Grid: (N / block_n, T / block_t); the bins panel (block_n, F) is VMEM-
resident for all trees of the block row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _leaf_index_kernel(bins_ref, sf_ref, sb_ref, out_ref):
    # bins may arrive int32 (legacy) or uint8 (quantized pool); both
    # upcast exactly to float32 for the MXU gather (bin ids <= 255 and
    # split ids < 2^30 are exact in f32).
    bins = bins_ref[...].astype(jnp.float32)          # (bn, F)
    sf = sf_ref[...]                                  # (bt, D) int32
    sb = sb_ref[...]                                  # (bt, D) int32
    bt, D = sf.shape
    bn, F = bins.shape

    # One-hot gather on the MXU: (bt*D, F) @ (F, bn) -> (bt*D, bn)
    sf_flat = sf.reshape(bt * D, 1)
    f_iota = jax.lax.broadcasted_iota(jnp.int32, (bt * D, F), 1)
    onehot = (f_iota == sf_flat).astype(jnp.float32)
    gathered = jax.lax.dot(onehot, bins.T,
                           preferred_element_type=jnp.float32)  # (bt*D, bn)
    gathered = gathered.reshape(bt, D, bn)

    go_right = gathered >= sb[:, :, None].astype(jnp.float32)   # (bt, D, bn)
    pow2 = (1 << jax.lax.broadcasted_iota(jnp.int32, (1, D, 1), 1)).astype(
        jnp.float32)
    idx = jnp.sum(go_right.astype(jnp.float32) * pow2, axis=1)  # (bt, bn)
    out_ref[...] = idx.T.astype(jnp.int32)                      # (bn, bt)


@functools.partial(jax.jit, static_argnames=("block_n", "block_t", "interpret"))
def leaf_index(bins: jax.Array, split_features: jax.Array,
               split_bins: jax.Array, *, block_n: int = 256,
               block_t: int = 16, interpret: bool = False) -> jax.Array:
    """idx[n, t] = sum_d 2^d [bins[n, sf[t,d]] >= sb[t,d]]  -> (N, T) int32.

    Pre-padded: N % block_n == 0, T % block_t == 0.  Padded trees must use
    split_bins > max bin (e.g. 2^30) so they contribute leaf 0.
    """
    N, F = bins.shape
    T, D = split_features.shape
    grid = (N // block_n, T // block_t)
    return pl.pallas_call(
        _leaf_index_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, D), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, T), jnp.int32),
        interpret=interpret,
    )(bins, split_features, split_bins)


def _leaf_index_dm_kernel(bins_ref, onehot_ref, sb_ref, pow2_ref, out_ref):
    # Depth-major lowered layout: the one-hot feature-gather matrix and
    # the pow2 vector arrive precomputed (hoisted to lower time), so the
    # kernel body is the two MXU/VPU passes and nothing else — no iota,
    # no one-hot construction, no per-call shift building.
    bins = bins_ref[...].astype(jnp.float32)          # (bn, F)
    onehot = onehot_ref[...]                          # (bt, D, F) f32
    sb = sb_ref[...]                                  # (D, bt) int32
    pow2 = pow2_ref[...]                              # (D, 1) f32
    bt, D, F = onehot.shape
    bn = bins.shape[0]

    gathered = jax.lax.dot(onehot.reshape(bt * D, F), bins.T,
                           preferred_element_type=jnp.float32)  # (bt*D, bn)
    gathered = gathered.reshape(bt, D, bn)
    go_right = gathered >= sb.T[:, :, None].astype(jnp.float32)  # (bt, D, bn)
    idx = jnp.sum(go_right.astype(jnp.float32)
                  * pow2.reshape(1, D, 1), axis=1)               # (bt, bn)
    out_ref[...] = idx.T.astype(jnp.int32)                       # (bn, bt)


@functools.partial(jax.jit, static_argnames=("block_n", "block_t",
                                             "interpret"))
def leaf_index_dm(bins: jax.Array, onehot: jax.Array, split_bins_dm: jax.Array,
                  pow2: jax.Array, *, block_n: int = 256, block_t: int = 16,
                  interpret: bool = False) -> jax.Array:
    """Depth-major `leaf_index`: gather via the precomputed one-hot
    matrix -> (N, T) int32.

    Inputs are the depth-major lowered model arrays (see
    `repro.core.layout.DepthMajorLayout`): `onehot` (T, D, F) f32,
    `split_bins_dm` (D, T) int32 bit-plane order, `pow2` (D, 1) f32.
    Pre-padded: N % block_n == 0, T % block_t == 0, padded trees carry
    split_bins > max bin.  `bins` may be int32 or uint8 (the
    quantized-pool stream) — both upcast exactly to f32.
    """
    N, F = bins.shape
    T, D, _ = onehot.shape
    if N % block_n or T % block_t:
        raise ValueError(
            f"leaf_index_dm requires padded inputs: N={N} % block_n="
            f"{block_n} and T={T} % block_t={block_t} must be 0 "
            "(lowering pads the model; use the plan API)")
    grid = (N // block_n, T // block_t)
    return pl.pallas_call(
        _leaf_index_dm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, D, F), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((D, block_t), lambda i, j: (0, j)),
            pl.BlockSpec((D, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, T), jnp.int32),
        interpret=interpret,
    )(bins, onehot, split_bins_dm, pow2)


def _bp_compare_planes(sb):
    """Narrow the (D, bt) int32 threshold planes for a uint8 compare.

    Real split thresholds on a uint8 pool are <= 255 (bin ids fit one
    byte), so the compare can run unwidened in uint8 — the paper's
    vmsgeu on the byte stream.  The PAD_SPLIT_BIN sentinel (2^30, used
    for padded trees and truncated depths) means "never go right"; it
    survives the narrowing as an explicit liveness mask, NOT by
    widening the bins panel to int32 (which would 4x the VMEM the
    panel holds — the contract checker's working-set audit pins this).
    """
    live = sb <= 255                       # (D, bt) bool: real splits
    return sb.astype(jnp.uint8), live


def _leaf_index_bp_kernel(bins_ref, sf_ref, sb_ref, out_ref):
    # Bitpacked lowered layout: integer-only pipeline, the closest TPU
    # analog of the paper's RVV loop.  Per depth d the comparison
    # bins[n, sf[d, t]] >= sb[d, t] is ONE bit per doc; a 32-doc column
    # packs into a uint32 lane word (the vmsgeu mask register) and the
    # leaf-index register accumulates bit d via shift/or.  No MXU, no
    # one-hot materialization, no float arithmetic anywhere — and for
    # uint8 pool bins the panel is never widened either: the compare
    # runs in uint8 against the narrowed threshold planes.
    bins = bins_ref[...]                              # (bn, F) i32 | u8
    sf = sf_ref[...]                                  # (D, bt) int32
    sb = sb_ref[...]                                  # (D, bt) int32
    D, bt = sf.shape
    bn = bins.shape[0]
    w = bn // 32
    narrow = bins.dtype == jnp.uint8
    if narrow:
        sb_u8, live = _bp_compare_planes(sb)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32, bt), 1)
    idx = jnp.zeros((bn, bt), jnp.int32)
    for d in range(D):                                # static unroll over depth
        cols = jnp.take(bins, sf[d], axis=1)          # (bn, bt) integer gather
        if narrow:
            go = (cols >= sb_u8[d][None, :]) & live[d][None, :]
        else:
            go = cols >= sb[d][None, :]
        bit = go.astype(jnp.uint32)
        # pack 32-doc lanes into uint32 words: bits are disjoint per
        # lane position, so the shifted sum IS the bitwise OR
        words = jnp.sum(bit.reshape(w, 32, bt) << shifts, axis=1,
                        dtype=jnp.uint32)             # (w, bt) lane words
        plane = ((words[:, None, :] >> shifts) & jnp.uint32(1)
                 ).reshape(bn, bt).astype(jnp.int32)
        idx = idx | (plane << d)
    out_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("block_n", "block_t",
                                             "interpret"))
def leaf_index_bp(bins: jax.Array, split_features_bp: jax.Array,
                  split_bins_bp: jax.Array, *, block_n: int = 256,
                  block_t: int = 16, interpret: bool = False) -> jax.Array:
    """Bitpacked `leaf_index`: integer shift/or index assembly -> (N, T) int32.

    Inputs are the bitpacked lowered model arrays (see
    `repro.core.layout.BitpackedLayout`): bit-plane transposed
    `split_features_bp` / `split_bins_bp`, both (D, T).  Pre-padded:
    N % block_n == 0 (block_n a multiple of 32 so doc lanes fill whole
    uint32 words), T % block_t == 0, padded trees carry split_bins >
    max bin (they pack bit 0 at every depth -> leaf 0).  `bins` may be
    int32 or uint8 — uint8 compares unwidened against the narrowed
    threshold planes (see `_bp_compare_planes`), int32 directly.
    """
    N, F = bins.shape
    D, T = split_features_bp.shape
    if N % block_n or T % block_t:
        raise ValueError(
            f"leaf_index_bp requires padded inputs: N={N} % block_n="
            f"{block_n} and T={T} % block_t={block_t} must be 0 "
            "(lowering pads the model; use the plan API)")
    if block_n % 32:
        raise ValueError(f"leaf_index_bp packs 32-doc uint32 lanes: "
                         f"block_n={block_n} must be a multiple of 32")
    grid = (N // block_n, T // block_t)
    return pl.pallas_call(
        _leaf_index_bp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i, j: (i, 0)),
            pl.BlockSpec((D, block_t), lambda i, j: (0, j)),
            pl.BlockSpec((D, block_t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, T), jnp.int32),
        interpret=interpret,
    )(bins, split_features_bp.astype(jnp.int32),
      split_bins_bp.astype(jnp.int32))


def leaf_index_u8(bins: jax.Array, split_features: jax.Array,
                  split_bins: jax.Array, *, block_n: int = 256,
                  block_t: int = 16, interpret: bool = False) -> jax.Array:
    """`leaf_index` over the quantized-pool bin stream: uint8 bins.

    Mirrors the paper's CalcIndexesBasic loop, which runs entirely on
    the *quantized* uint8 representation (vmsgeu compares u8 bins
    against the u8 split border) — binarization never reruns per tree.
    The kernel body is shared with the int32 variant (bins upcast to
    f32 for the one-hot MXU gather either way); this entry pins the
    dtype contract and keeps the 4x-narrower bins panel (block_n x F
    bytes instead of words) VMEM-resident per sample block.  8-bit
    loads use the (32, 128) tile on real TPUs; interpret mode has no
    such constraint.
    """
    if bins.dtype != jnp.uint8:
        raise TypeError(f"leaf_index_u8 takes uint8 bins, got {bins.dtype} "
                        "(use leaf_index for int32)")
    return leaf_index(bins, split_features, split_bins, block_n=block_n,
                      block_t=block_t, interpret=interpret)
