"""Kernel block-shape tuning support.

RVV 0.7.1 exposes LMUL (m1/m2/m4/m8) register grouping; the paper notes
picking the best mode "requires experiments".  The TPU analog is the
Pallas BlockSpec shape: it sets the VMEM working set and the MXU/VPU
tile utilization.  This module provides the VMEM footprint model used to
pre-filter candidate block shapes (anything over the budget would spill)
and the candidate grids the benchmark sweeps.

On real TPU hardware `sweep()` would time each candidate; on CPU the
interpret-mode result is correctness-only, so the selector falls back to
the analytic footprint/alignment score.
"""
from __future__ import annotations

import dataclasses

VMEM_BUDGET = 96 * 1024 * 1024   # bytes; leave headroom of v5e's 128 MiB
LANE = 128                        # VPU lane width / MXU tile edge
SUBLANE = 8


def _align_score(*dims: int) -> float:
    """Fraction of hardware tile actually used (penalizes ragged tiles)."""
    score = 1.0
    for d in dims[:-1]:
        score *= min(1.0, d / (SUBLANE * ((d + SUBLANE - 1) // SUBLANE)))
    d = dims[-1]
    score *= min(1.0, d / (LANE * ((d + LANE - 1) // LANE)))
    return score


def binarize_footprint(block_n: int, block_f: int, n_borders: int, *,
                       bins_bytes: int = 4) -> int:
    """`bins_bytes=1` models the uint8 bin stream (quantized pool /
    u8 fused scratch): the output panel shrinks 4x.  The compare-add
    loop accumulates in int32 regardless of the stored dtype, so the
    (block_n, block_f) accumulator is always counted at 4 bytes (the
    static analyzer's live-buffer estimate checks this model against
    the traced kernel)."""
    x = block_n * block_f * 4
    borders = n_borders * block_f * 4
    acc = block_n * block_f * 4
    out = block_n * block_f * bins_bytes
    return x + borders + acc + out


def leaf_index_footprint(block_n: int, block_t: int, F: int, D: int, *,
                         bins_bytes: int = 4,
                         gather: str = "mxu") -> int:
    """`gather` names the index-assembly pipeline the kernel runs:

      mxu       one-hot matmul gather — the kernel holds an f32 working
                copy of the bins panel for the systolic pass (exact for
                bin ids <= 255), plus the one-hot and gathered panels
      bitplane  integer shift/or assembly (the bitpacked layout): no
                one-hot, no f32 upcast — the working set past the
                resident bins panel is the per-depth (block_n, block_t)
                column/mask/plane panels and the index register
    """
    bins = block_n * F * bins_bytes
    out = block_n * block_t * 4
    if gather == "bitplane":
        depth_panels = block_n * block_t * (bins_bytes + 4 + 4)
        idx = block_n * block_t * 4
        return bins + depth_panels + idx + out
    upcast = block_n * F * 4
    onehot = block_t * D * F * 4
    gathered = block_t * D * block_n * 4
    return bins + upcast + onehot + gathered + out


def leaf_gather_footprint(block_n: int, block_t: int, L: int, C: int) -> int:
    idx = block_n * block_t * 4
    lv = block_t * L * C * 4
    onehot = block_n * block_t * L * 4
    out = block_n * C * 4
    return idx + lv + onehot + out


def fused_footprint(block_n: int, block_t: int, F: int, D: int, L: int,
                    C: int, n_borders: int, *, bins_bytes: int = 4,
                    gather: str = "mxu") -> int:
    """`bins_bytes=1` models the u8 bins scratch the fused kernel uses
    when the ensemble fits 255 borders (ops.py picks it automatically);
    `gather="bitplane"` models the bitpacked fused kernel's integer
    stage-2 (see `leaf_index_footprint`)."""
    return (binarize_footprint(block_n, F, n_borders,
                               bins_bytes=bins_bytes)
            + leaf_index_footprint(block_n, block_t, F, D,
                                   bins_bytes=bins_bytes, gather=gather)
            + leaf_gather_footprint(block_n, block_t, L, C))


@dataclasses.dataclass
class Candidate:
    block_n: int
    block_t: int
    footprint: int
    score: float


def _pad_utilization(n: int, block: int) -> float:
    """Fraction of padded work that is real when n is rounded up to a
    multiple of block (1.0 when block divides n or n unknown)."""
    padded = block * ((n + block - 1) // block) if n > 0 else block
    return n / padded if n > 0 else 1.0


def candidates_fused(F: int, D: int, L: int, C: int, n_borders: int,
                     budget: int = VMEM_BUDGET, *,
                     n_rows: int | None = None,
                     n_trees: int | None = None) -> list[Candidate]:
    """Candidate (block_n, block_t) grid, best first.

    When the workload shape (n_rows, n_trees) is known — the serving path
    always knows it — candidates that force heavy zero-padding are
    penalized by the fraction of padded work that is real, so a 150-row
    bucket is not handed a 1024-row block.
    """
    out = []
    for bn in (64, 128, 256, 512, 1024):
        for bt in (8, 16, 32, 64):
            fp = fused_footprint(bn, bt, F, D, L, C, n_borders)
            if fp > budget:
                continue
            # prefer larger tiles (fewer grid steps) once aligned
            score = _align_score(bn, LANE) * min(1.0, fp / budget + 0.2) \
                * (bn * bt) ** 0.25
            if n_rows is not None:
                score *= _pad_utilization(n_rows, bn)
            if n_trees is not None:
                score *= _pad_utilization(n_trees, bt)
            out.append(Candidate(bn, bt, fp, score))
    return sorted(out, key=lambda c: -c.score)


def best_fused_blocks(F: int, D: int, L: int, C: int,
                      n_borders: int, *,
                      n_rows: int | None = None,
                      n_trees: int | None = None) -> tuple[int, int]:
    cands = candidates_fused(F, D, L, C, n_borders,
                             n_rows=n_rows, n_trees=n_trees)
    if not cands:
        return 128, 16
    return cands[0].block_n, cands[0].block_t


# --------------------------------------------------------------------------
# Training histogram block planning (see repro.kernels.histogram)
# --------------------------------------------------------------------------
def hist_footprint(block_f: int, block_n: int, n_leaves: int,
                   n_bins: int, n_stats: int, *,
                   bins_bytes: int = 1) -> int:
    """VMEM working set of one histogram grid step.

    The (block_f, block_n, L*B) one-hot selector panel dominates — the
    training twin of the (N, L) gather one-hot `best_fused_blocks`
    budgets — plus the bins tile (`bins_bytes=1` for uint8 pool bins),
    the (block_n, n_stats) gradient/hessian tile and the
    (block_f, L*B, n_stats) accumulator."""
    S = n_leaves * n_bins
    return (block_f * block_n * S * 4          # one-hot selector (f32)
            + block_f * block_n * bins_bytes   # bins tile
            + block_n * n_stats * 4            # g/h stats tile
            + block_f * S * n_stats * 4)       # accumulator


@dataclasses.dataclass
class HistCandidate:
    block_f: int
    block_n: int
    footprint: int
    score: float


def candidates_hist(F: int, n_leaves: int, n_bins: int, n_stats: int,
                    budget: int = VMEM_BUDGET, *,
                    n_rows: int | None = None,
                    bins_bytes: int = 1) -> list[HistCandidate]:
    """Candidate (block_f, block_n) grid for the histogram kernel, best
    first.  Scored like `candidates_fused`: prefer lane-aligned sample
    blocks and larger tiles once aligned, penalize candidates whose
    padding (features to block_f, rows to block_n) is mostly zeros."""
    out = []
    for bf in (1, 2, 4, 8, 16, 32):
        for bn in (128, 256, 512, 1024):
            fp = hist_footprint(bf, bn, n_leaves, n_bins, n_stats,
                                bins_bytes=bins_bytes)
            if fp > budget:
                continue
            score = _align_score(bn, LANE) * min(1.0, fp / budget + 0.2) \
                * (bf * bn) ** 0.25
            if n_rows is not None:
                score *= _pad_utilization(n_rows, bn)
            score *= _pad_utilization(F, bf)
            out.append(HistCandidate(bf, bn, fp, score))
    return sorted(out, key=lambda c: -c.score)


def best_hist_blocks(F: int, n_leaves: int, n_bins: int, n_stats: int, *,
                     n_rows: int | None = None,
                     bins_bytes: int = 1) -> tuple[int, int]:
    cands = candidates_hist(F, n_leaves, n_bins, n_stats,
                            n_rows=n_rows, bins_bytes=bins_bytes)
    if not cands:
        return 1, 128
    return cands[0].block_f, cands[0].block_n


# --------------------------------------------------------------------------
# Bulk-scoring chunk planning (see repro.scoring.scorer)
# --------------------------------------------------------------------------
# Working-set budget per in-flight scoring chunk.  The binding
# constraint on CPU (the measured backend in this container) is not
# host RAM but the cache footprint of the staged kernels' per-chunk
# intermediates — the (N, F, B) binarize comparison panel and the
# (N, T, L) gather one-hot.  Chunks past the budget fall off a cache
# cliff (measured: the float path's us/row triples from N=2048 to
# N=4096 on a 100-tree covertype model); chunks far below it waste
# dispatch overhead.  32 MiB lands the planner on the measured sweet
# spot for paper-scale models while keeping a depth-2 prefetch
# pipeline comfortably in memory.
CHUNK_BUDGET_BYTES = 32 * 1024 * 1024
MIN_CHUNK_ROWS = 256
MAX_CHUNK_ROWS = 1 << 17          # dispatch overhead is long amortized


def chunk_row_bytes(n_features: int, n_outputs: int, *,
                    n_borders: int = 0, n_trees: int = 0,
                    n_leaves: int = 0) -> int:
    """Per-row working set of one scoring chunk.

    Always counted: the float32 copy sliced from the source, its uint8
    bins (the quantized pool), and the float32 output panel.  When the
    model dims are known the staged-kernel intermediates dominate and
    are added: the (F, B) binarize comparison panel and the (T, L)
    leaf-gather one-hot, both float32 per row."""
    base = 4 * n_features + n_features + 4 * max(n_outputs, 2)
    base += 4 * n_features * n_borders       # binarize comparisons
    base += 4 * n_trees * n_leaves           # gather one-hot
    return base


def best_chunk_rows(n_features: int, n_outputs: int, *,
                    n_borders: int = 0, n_trees: int = 0,
                    n_leaves: int = 0,
                    budget_bytes: int = CHUNK_BUDGET_BYTES,
                    n_rows: int | None = None) -> int:
    """Pick the bulk scorer's fixed chunk shape, the way
    `best_fused_blocks` picks block shapes: largest power-of-two row
    count whose per-chunk working set fits the budget (pow2 so the
    tail bucket ladder and the kernel block shapes divide it evenly),
    clamped to [MIN_CHUNK_ROWS, MAX_CHUNK_ROWS].  A known small
    `n_rows` caps the chunk at the first pow2 that covers the whole
    dataset — no point compiling a shape 60x the data."""
    per_row = chunk_row_bytes(n_features, n_outputs, n_borders=n_borders,
                              n_trees=n_trees, n_leaves=n_leaves)
    rows = MIN_CHUNK_ROWS
    while rows * 2 <= MAX_CHUNK_ROWS and rows * 2 * per_row <= budget_bytes:
        rows *= 2
    if n_rows is not None and n_rows > 0:
        cover = MIN_CHUNK_ROWS
        while cover < n_rows:
            cover *= 2
        rows = min(rows, cover)
    return rows


# --------------------------------------------------------------------------
# Physical-layout selection (see repro.core.layout)
# --------------------------------------------------------------------------
# depth_grouped pays per-group kernel dispatches to shrink leaf tables;
# only worth it once the shallow trees save a real fraction of the
# padded-to-Dmax table (and more than one group exists).
GROUPED_MIN_SAVINGS = 0.30
# depth_major trades a (T, D, F) f32 one-hot gather matrix for never
# rebuilding iota/one-hot in the leaf_index hot loop; past this size the
# matrix stops being a free win (HBM traffic per tree block grows).
DEPTH_MAJOR_MAX_ONEHOT_BYTES = 8 * 1024 * 1024


def layout_costs(true_depths, n_outputs: int, n_features: int
                 ) -> dict[str, int]:
    """Leaf-table / lowered-array byte costs per layout for an ensemble
    with the given per-tree true depths (the inputs `best_layout` ranks
    on; exposed for the bench and docs)."""
    import numpy as np
    d = np.asarray(true_depths, np.int64)
    dmax = int(d.max()) if d.size else 1
    soa_leaf = int(d.size) * (1 << dmax) * n_outputs * 4
    grouped_leaf = int(((1 << np.maximum(d, 1)) * n_outputs * 4).sum())
    onehot = int(d.size) * dmax * n_features * 4
    # bitpacked shares depth_grouped's leaf tables; its extra state is
    # two (d, T_d) integer bit planes per group — int32 worst case
    plane = int((2 * np.maximum(d, 1) * 4).sum())
    return {"soa_leaf_bytes": soa_leaf,
            "depth_grouped_leaf_bytes": grouped_leaf,
            "depth_major_onehot_bytes": onehot,
            "bitpacked_leaf_bytes": grouped_leaf,
            "bitpacked_plane_bytes": plane}


def best_layout(true_depths, n_outputs: int, n_features: int, *,
                backend: str = "ref") -> str:
    """Pick a physical layout from the ensemble's shape, the same way
    `best_fused_blocks` picks block shapes: from the depth histogram,
    tree count, the leaf-table bytes each layout would carry, and the
    kernel family that will consume it.

      bitpacked      mixed depths with grouped savings whose one-hot /
                     f32 working set (the (T, Dmax, F) gather panel an
                     MXU-family index kernel would stream) blows the
                     VMEM budget — the integer bit-plane pipeline
                     carries no one-hot at all, so its working set is
                     the grouped leaf tables plus two thin planes
      depth_grouped  when true depths mix and the per-depth leaf tables
                     save >= GROUPED_MIN_SAVINGS of the soa table
                     (less index+gather work on any backend)
      depth_major    pallas-family kernels on (near-)uniform depths
                     when the precomputed one-hot gather matrix stays
                     small enough — it removes the per-call iota /
                     one-hot build from the kernel body; the jnp
                     reference gathers cheaper than it matmuls, so ref
                     stays on soa
      soa            everything else (and the safe fallback: tracer
                     ensembles never reach here — the plan resolver
                     pins them to soa)
    """
    import numpy as np
    d = np.asarray(true_depths, np.int64)
    if d.size == 0:
        return "soa"
    costs = layout_costs(d, n_outputs, n_features)
    if len(set(d.tolist())) > 1:
        savings = 1.0 - (costs["depth_grouped_leaf_bytes"]
                         / max(costs["soa_leaf_bytes"], 1))
        if savings >= GROUPED_MIN_SAVINGS:
            if costs["depth_major_onehot_bytes"] > VMEM_BUDGET:
                return "bitpacked"
            return "depth_grouped"
    if backend.startswith("pallas") and \
            costs["depth_major_onehot_bytes"] <= DEPTH_MAJOR_MAX_ONEHOT_BYTES:
        return "depth_major"
    return "soa"


# --------------------------------------------------------------------------
# Mesh shard-axis selection (see Predictor.sharded / docs/distributed.md)
# --------------------------------------------------------------------------
# Tree-sharding exists for giant ensembles (the 1k-10k tree regime);
# below this the psum combine and the reassociated float sum buy
# nothing a row shard doesn't already give exactly.
TREE_SHARD_MIN_TREES = 1024
# Row-sharding replicates the whole lowered model on every shard; past
# this many replicated bytes the model, not the batch, is the memory
# problem and the tree split pays for its psum.
TREE_REPLICATION_BUDGET_BYTES = 64 * 1024 * 1024


def shard_count(mesh) -> int:
    """Total shards a mesh (or plain int) fans out to."""
    if isinstance(mesh, int):
        return max(mesh, 1)
    out = 1
    for size in dict(mesh.shape).values():
        out *= int(size)
    return max(out, 1)


def best_shard_axis(n_rows: int, n_trees: int, mesh, *,
                    n_outputs: int = 1,
                    leaf_table_bytes: int = 0) -> str:
    """Pick row- vs tree-sharding for a K-way mesh, the same way
    `best_layout` / `best_chunk_rows` pick from shape arithmetic.

    The per-shard traversal work is symmetric — ceil(N/K) x T rows-wise
    vs N x ceil(T/K) trees-wise — so the bulk product never decides.
    What does:

      rows   exact parity (same addend order per row), no combine;
             hidden cost is K-fold replication of the lowered model
      trees  a psum of the (N, C) partial sums, a reassociated float
             tree sum (~1e-6, not bit-for-bit), and the model split
             K ways instead of replicated

    So: rows unless the ensemble is in the giant-tree regime
    (`TREE_SHARD_MIN_TREES`) AND either the replicated leaf tables
    blow `TREE_REPLICATION_BUDGET_BYTES` or the batch is too ragged to
    row-shard efficiently (padding utilization below the tree axis's —
    the N < K serving-batch case).  `mesh` may be a Mesh/AbstractMesh
    or a plain shard count.
    """
    k = shard_count(mesh)
    if k <= 1:
        return "rows"
    if n_trees < TREE_SHARD_MIN_TREES or n_trees < k:
        return "rows"
    if leaf_table_bytes * (k - 1) > TREE_REPLICATION_BUDGET_BYTES:
        return "trees"
    if _pad_utilization(max(n_rows, 1), k) < _pad_utilization(n_trees, k):
        return "trees"
    return "rows"
