"""Quantized-first GBDT training: boosting over a `QuantizedPool`.

The training twin of the PR-5 scoring subsystem, closing ROADMAP item 3.
The seed trainer (`core.boosting._fit_scan`) binarizes its own float
matrix and scatters histograms through `jax.ops.segment_sum`, bypassing
the kernel registry entirely.  This module trains on the same uint8
representation everything else consumes:

  * ingest   a `QuantizedPool` directly, or any `scoring.sources`
             RowSource streamed through `quantize_pool_chunked` — float
             rows live O(chunk) like `BulkScorer`, only one byte per
             (sample, feature) is retained
  * grow     per level, gradient/hessian histograms go through the
             registered `histogram` op (ref segment-sum oracle or the
             Pallas one-hot-matmul kernel; uint8 pool bins route to the
             widening-free `pallas_u8` variant).  Gradients and hessians
             are concatenated on the stats axis so both histograms cost
             ONE accumulation pass, and level d sizes its histogram to
             the 2^d leaves that exist instead of the full 2^depth
  * serve    the fitted `ObliviousEnsemble` goes straight through
             `Predictor.build`, and the trainer's reported training-time
             predictions are that plan's own `raw(pool)` — so the CLI's
             train->serve parity check is exact by construction

Per-tree math is the seed's, bit-for-bit per channel: same split gains,
same Newton leaf values, same RNG stream (`key, sub, sub2` per
iteration), same loss-after-update history semantics.  The boosting
loop itself runs in Python (one jitted call per stage) so iterations
can be checkpointed and resumed mid-run: `TrainState` carries the
ensemble-so-far, the accumulated raw predictions and the RNG key, and a
killed run restored from its last checkpoint finishes with a
bit-identical ensemble.

Compiled-shape contract: one trace per (stage, level) — histogram
dispatch counts stay <= depth across any number of fits on same-shaped
data, and training on a pool performs ZERO binarize dispatches
(`history["dispatch_delta"]` records the proof).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as losses_lib
from repro.core import predictor as predictor_mod
from repro.core import quantize
from repro.core.boosting import (NEG_INF, BoostingParams, _gain_term,
                                 _ordered_update)
from repro.core.trees import ObliviousEnsemble
from repro.kernels import ops, registry
from repro.kernels import tuning as _tuning
from repro.obs.trace import get_tracer
from repro.serving.metrics import PercentileReservoir
from repro.training.checkpoint import CheckpointManager

_TRACER = get_tracer()


# --------------------------------------------------------------------------
# Observability
# --------------------------------------------------------------------------
class TrainingMetrics:
    """Per-iteration training observability.

    Mirrors `serving.metrics.ServerMetrics`: stage timings flow through
    the shared `PercentileReservoir`, throughput is `rows_per_s` — the
    same unit ServerMetrics and ScoringMetrics report — so training and
    serving dashboards share one vocabulary.  `rows_trained` counts
    sample-rows per boosting iteration (N rows x T iterations).
    """

    MAX_SAMPLES = 8192

    def __init__(self, name: str = "gbdt"):
        self.name = name
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self.iterations = 0
        self.rows_trained = 0
        self.quantize_s = 0.0
        self.n_chunks = 0
        self.chunk_rows = 0
        self.hist_dispatches = 0
        self.train_loss: list[float] = []
        self._iter = PercentileReservoir(self.MAX_SAMPLES)
        self._hist = PercentileReservoir(self.MAX_SAMPLES, seed=1)
        self._split = PercentileReservoir(self.MAX_SAMPLES, seed=2)
        self._leaf = PercentileReservoir(self.MAX_SAMPLES, seed=3)
        self._busy = {"hist": 0.0, "split": 0.0, "leaf": 0.0, "iter": 0.0}

    def note_quantize(self, seconds: float, n_chunks: int,
                      chunk_rows: int) -> None:
        with self._lock:
            self.quantize_s += seconds
            self.n_chunks += n_chunks
            self.chunk_rows = chunk_rows

    def note_iteration(self, n_rows: int, hist_s: float, split_s: float,
                       leaf_s: float, iter_s: float,
                       loss_value: float) -> None:
        with self._lock:
            self.iterations += 1
            self.rows_trained += n_rows
            self.train_loss.append(float(loss_value))
            self._iter.add(iter_s)
            self._hist.add(hist_s)
            self._split.add(split_s)
            self._leaf.add(leaf_s)
            self._busy["hist"] += hist_s
            self._busy["split"] += split_s
            self._busy["leaf"] += leaf_s
            self._busy["iter"] += iter_s

    def note_hist_dispatches(self, n: int) -> None:
        with self._lock:
            self.hist_dispatches += n

    def snapshot(self) -> dict[str, Any]:
        """One flat dict, same shape discipline as ServerMetrics'."""
        with self._lock:
            dt = max(time.perf_counter() - self._t0, 1e-9)
            busy = max(self._busy["iter"], 1e-9)

            def p(res: PercentileReservoir, q: float) -> float:
                return res.percentile(q) * 1e3 if res.seen else 0.0

            return {
                "model": self.name,
                "iterations": self.iterations,
                "rows_trained": self.rows_trained,
                "rows_per_s": self.rows_trained / dt,
                "iter_p50_ms": p(self._iter, 50),
                "iter_p99_ms": p(self._iter, 99),
                "hist_p50_ms": p(self._hist, 50),
                "split_p50_ms": p(self._split, 50),
                "leaf_p50_ms": p(self._leaf, 50),
                "hist_frac": self._busy["hist"] / busy,
                "split_frac": self._busy["split"] / busy,
                "leaf_frac": self._busy["leaf"] / busy,
                "first_train_loss": (self.train_loss[0]
                                     if self.train_loss else float("nan")),
                "final_train_loss": (self.train_loss[-1]
                                     if self.train_loss else float("nan")),
                "quantize_s": self.quantize_s,
                "n_chunks": self.n_chunks,
                "chunk_rows": self.chunk_rows,
                "hist_dispatches": self.hist_dispatches,
            }


# --------------------------------------------------------------------------
# Checkpointable boosting state
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TrainState:
    """Everything a resumed run needs to finish bit-identically.

    The RNG `key` is the CARRIED key (already split `iteration` times),
    and `raw` the accumulated train-time predictions — restoring both
    replays the remaining iterations on exactly the seed stream and
    residuals the killed run would have used.
    """

    iteration: int
    key: np.ndarray                # (2,) uint32 carried PRNG key
    split_features: np.ndarray     # (k, D) int32
    split_bins: np.ndarray         # (k, D) int32
    leaf_values: np.ndarray        # (k, L, C) float32
    raw: np.ndarray                # (N, C) float32
    train_loss: np.ndarray         # (k,) float32

    def tree(self) -> dict[str, np.ndarray]:
        return {
            "iteration": np.asarray(self.iteration, np.int64),
            "key": np.asarray(self.key),
            "split_features": np.asarray(self.split_features, np.int32),
            "split_bins": np.asarray(self.split_bins, np.int32),
            "leaf_values": np.asarray(self.leaf_values, np.float32),
            "raw": np.asarray(self.raw, np.float32),
            "train_loss": np.asarray(self.train_loss, np.float32),
        }

    @classmethod
    def from_tree(cls, tree: dict[str, np.ndarray]) -> "TrainState":
        return cls(iteration=int(tree["iteration"]),
                   key=np.asarray(tree["key"]),
                   split_features=np.asarray(tree["split_features"]),
                   split_bins=np.asarray(tree["split_bins"]),
                   leaf_values=np.asarray(tree["leaf_values"]),
                   raw=np.asarray(tree["raw"]),
                   train_loss=np.asarray(tree["train_loss"]))


# --------------------------------------------------------------------------
# Jitted per-stage helpers (module level: the compile cache is shared
# across trainer instances, so fitting twice compiles nothing new)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("loss",))
def _grad_stack(raw, y, *, loss):
    """(N, C) g and (N, C) h concatenated -> (N, 2C): one histogram
    pass accumulates both."""
    g, h = loss.grad_hess(raw, y)
    return jnp.concatenate([g, h], axis=1)


@partial(jax.jit, static_argnames=("n_bins", "n_leaves", "backend"))
def _hist_level(bins_t, leaf, gh, *, n_bins, n_leaves, backend):
    return ops.histogram(bins_t, leaf, gh, n_bins=n_bins,
                         n_leaves=n_leaves, backend=backend)


@partial(jax.jit, static_argnames=("n_bins", "d", "l2"))
def _split_level(hist, valid, bins_t, leaf, *, n_bins, d, l2):
    """Pick the level's oblivious split from the (F, 2^d * n_bins, 2C)
    histogram and refine leaf ids.  Reproduces the seed trainer's gain
    math bit-for-bit per channel — the only difference is that the leaf
    axis holds the 2^d leaves that exist at level d instead of the full
    2^depth (absent leaves contribute exact zeros to every gain sum)."""
    F, S, C2 = hist.shape
    n_leaves = S // n_bins
    C = C2 // 2
    h4 = hist.reshape(F, n_leaves, n_bins, C2)
    incl = jnp.cumsum(h4, axis=2)
    total = incl[:, :, -1:, :]
    # left of border b = bins < b -> inclusive cumsum shifted by one
    left = jnp.pad(incl[:, :, :-1, :], ((0, 0), (0, 0), (1, 0), (0, 0)))
    right = total - left
    gain = (_gain_term(left[..., :C], left[..., C:], l2)
            + _gain_term(right[..., :C], right[..., C:], l2)
            ).sum(axis=(1, 3))                             # (F, n_bins)
    # a split must put hessian mass on both sides; degenerate splits
    # (constant features) are never selected
    nonempty = (left[..., C:].sum(axis=(1, 3)) > 0) \
        & (right[..., C:].sum(axis=(1, 3)) > 0)
    gain = jnp.where(valid & nonempty, gain, NEG_INF)
    flat = jnp.argmax(gain.reshape(-1))
    f_star = (flat // n_bins).astype(jnp.int32)
    b_star = (flat % n_bins).astype(jnp.int32)
    go_right = (bins_t[f_star].astype(jnp.int32) >= b_star).astype(jnp.int32)
    return f_star, b_star, leaf | (go_right << d)


@partial(jax.jit, static_argnames=("loss", "n_leaves", "lr", "l2"))
def _finish_plain(raw, y, gh, leaf, *, loss, n_leaves, lr, l2):
    C = gh.shape[1] // 2
    s = jax.ops.segment_sum(gh, leaf, num_segments=n_leaves)   # (L, 2C)
    w = -lr * s[:, :C] / (s[:, C:] + l2)                       # (L, C)
    raw = raw + w[leaf]
    return raw, w, loss.value(raw, y)


@partial(jax.jit, static_argnames=("loss", "n_leaves", "lr", "l2"))
def _finish_ordered(raw, y, gh, leaf, key, *, loss, n_leaves, lr, l2):
    C = gh.shape[1] // 2
    g, h = gh[:, :C], gh[:, C:]
    s = jax.ops.segment_sum(gh, leaf, num_segments=n_leaves)
    w = -lr * s[:, :C] / (s[:, C:] + l2)
    raw = raw + _ordered_update(leaf, g, h, key, lr, l2, n_leaves)
    return raw, w, loss.value(raw, y)


@partial(jax.jit, static_argnames=("n_features", "keep"))
def _feat_mask(key, *, n_features, keep):
    perm = jax.random.permutation(key, n_features)
    return jnp.zeros((n_features,), bool).at[perm[:keep]].set(True)


# --------------------------------------------------------------------------
# Trainer
# --------------------------------------------------------------------------
class GBDTTrainer:
    """Quantized-first boosting: `fit_pool` / `fit_source` / `fit_bins`.

    One trainer instance owns one `TrainingMetrics`; the jit caches are
    module-level, so instances are cheap.  `backend` follows the kernel
    registry's legacy shim values ("auto" / "ref" / "pallas").
    """

    def __init__(self, loss: losses_lib.Loss, params: BoostingParams, *,
                 backend: str = "auto", name: str = "gbdt"):
        self.loss = loss
        self.params = params
        self.backend = backend
        self.metrics = TrainingMetrics(name)
        self.pool_: Optional[quantize.QuantizedPool] = None
        self.plan_: Optional[predictor_mod.Predictor] = None

    # -- entry points ------------------------------------------------------
    def fit_pool(self, pool: quantize.QuantizedPool, y, *, borders,
                 n_borders=None,
                 checkpoint: Optional[CheckpointManager] = None,
                 checkpoint_every: int = 0,
                 resume_from: Optional[int] = None
                 ) -> tuple[ObliviousEnsemble, dict]:
        """Train on an existing uint8 pool: ZERO binarize dispatches."""
        fp = quantize.borders_fingerprint(borders)
        if pool.fingerprint != fp:
            raise ValueError(
                f"pool was quantized under a different schema: pool "
                f"fingerprint {pool.fingerprint} != borders {fp}")
        self.pool_ = pool
        return self._fit_bins(pool.bins, y, borders=borders,
                              n_borders=n_borders, pool=pool,
                              checkpoint=checkpoint,
                              checkpoint_every=checkpoint_every,
                              resume_from=resume_from)

    def fit_bins(self, bins, y, *, borders, n_borders=None,
                 checkpoint: Optional[CheckpointManager] = None,
                 checkpoint_every: int = 0,
                 resume_from: Optional[int] = None
                 ) -> tuple[ObliviousEnsemble, dict]:
        """Train on a raw (N, F) int32/uint8 bins matrix — the escape
        hatch for > 255 borders, where no uint8 pool can exist."""
        return self._fit_bins(jnp.asarray(bins), y, borders=borders,
                              n_borders=n_borders, pool=None,
                              checkpoint=checkpoint,
                              checkpoint_every=checkpoint_every,
                              resume_from=resume_from)

    def fit_source(self, source, y, *, max_bins: Optional[int] = None,
                   chunk_rows: int = 0, sample_rows: int = 65536,
                   checkpoint: Optional[CheckpointManager] = None,
                   checkpoint_every: int = 0,
                   resume_from: Optional[int] = None
                   ) -> tuple[ObliviousEnsemble, dict]:
        """Out-of-core ingest: stream a `RowSource` chunk-by-chunk
        through `quantize_pool_chunked`, then boost on the pool.

        Float rows exist only one chunk at a time (the `BulkScorer`
        memory contract); the retained representation is one byte per
        (sample, feature).  Two streaming passes: border computation
        (reservoir sample) and binarization."""
        from repro.scoring import sources as sources_lib

        if max_bins is None:
            max_bins = self.params.max_bins
        if chunk_rows <= 0:
            chunk_rows = _tuning.best_chunk_rows(source.n_features, 1)
        t0 = time.perf_counter()
        borders, n_borders = quantize.compute_borders_chunked(
            sources_lib.iter_chunks(source, chunk_rows), max_bins,
            sample_rows=sample_rows)
        pool = quantize.quantize_pool_chunked(
            sources_lib.iter_chunks(source, chunk_rows), borders,
            backend=self.backend)
        n_chunks = -(-source.n_rows // chunk_rows)
        self.metrics.note_quantize(time.perf_counter() - t0, n_chunks,
                                   chunk_rows)
        ens, history = self.fit_pool(pool, y, borders=borders,
                                     n_borders=n_borders,
                                     checkpoint=checkpoint,
                                     checkpoint_every=checkpoint_every,
                                     resume_from=resume_from)
        history["chunk_rows"] = chunk_rows
        history["n_chunks"] = n_chunks
        return ens, history

    # -- core loop ---------------------------------------------------------
    def _fit_bins(self, bins, y, *, borders, n_borders, pool,
                  checkpoint, checkpoint_every, resume_from):
        p = self.params
        loss = self.loss
        N, F = bins.shape
        yj = jnp.asarray(y)
        raw0 = loss.init_raw(yj)
        C = raw0.shape[1]
        depth, L = p.depth, 1 << p.depth
        borders = jnp.asarray(borders)
        n_bins = int(borders.shape[0]) + 1
        if n_borders is None:
            n_borders = jnp.asarray(
                np.isfinite(np.asarray(borders)).sum(0).astype(np.int32))
        bins_t = jnp.asarray(bins).T
        b_iota = jnp.arange(n_bins, dtype=jnp.int32)
        # valid split borders: 1 <= b <= n_borders[f]
        base_valid = (b_iota[None, :] >= 1) \
            & (b_iota[None, :] <= jnp.asarray(n_borders)[:, None])

        stats0 = registry.call_stats()

        # resume: restore the carried key / raw / ensemble-so-far
        sf_rows: list[np.ndarray] = []
        sb_rows: list[np.ndarray] = []
        lv_rows: list[np.ndarray] = []
        loss_vals: list[float] = []
        start = 0
        key = jax.random.PRNGKey(p.seed)
        raw = raw0
        if checkpoint is not None and resume_from is not None:
            step = None if resume_from < 0 else resume_from
            state = TrainState.from_tree(checkpoint.restore(step))
            if state.raw.shape != (N, C):
                raise ValueError(
                    f"checkpoint raw shape {state.raw.shape} does not "
                    f"match this dataset ({(N, C)})")
            if state.iteration > p.n_trees:
                raise ValueError(
                    f"checkpoint is at iteration {state.iteration} > "
                    f"n_trees {p.n_trees}")
            start = state.iteration
            key = jnp.asarray(state.key)
            raw = jnp.asarray(state.raw)
            sf_rows = list(state.split_features)
            sb_rows = list(state.split_bins)
            lv_rows = list(state.leaf_values)
            loss_vals = [float(v) for v in state.train_loss]

        keep = max(1, int(F * p.rsm))
        for it in range(start, p.n_trees):
            t_iter = time.perf_counter()
            key, sub, sub2 = jax.random.split(key, 3)
            gh = _grad_stack(raw, yj, loss=loss)
            if p.rsm < 1.0:
                valid = base_valid & _feat_mask(sub, n_features=F,
                                                keep=keep)[:, None]
            else:
                valid = base_valid
            leaf = jnp.zeros((N,), jnp.int32)
            sf_d: list = []
            sb_d: list = []
            hist_s = split_s = 0.0
            for d in range(depth):
                t0 = time.perf_counter()
                hist = _hist_level(bins_t, leaf, gh, n_bins=n_bins,
                                   n_leaves=1 << d, backend=self.backend)
                hist.block_until_ready()
                t1 = time.perf_counter()
                hist_s += t1 - t0
                f_star, b_star, leaf = _split_level(
                    hist, valid, bins_t, leaf, n_bins=n_bins, d=d,
                    l2=p.l2_reg)
                leaf.block_until_ready()
                t_end = time.perf_counter()
                split_s += t_end - t1
                if _TRACER.enabled:
                    # the level clocks above are the span: record the
                    # already-measured region (block_until_ready fenced)
                    _TRACER.complete(
                        "train/level", "train",
                        start_ns=int(t0 * 1e9),
                        duration_ns=int((t_end - t0) * 1e9),
                        iteration=it, level=d, leaves=1 << d,
                        hist_ms=(t1 - t0) * 1e3,
                        split_ms=(t_end - t1) * 1e3)
                sf_d.append(f_star)
                sb_d.append(b_star)
            t2 = time.perf_counter()
            if p.ordered:
                raw, w, val = _finish_ordered(
                    raw, yj, gh, leaf, sub2, loss=loss, n_leaves=L,
                    lr=p.learning_rate, l2=p.l2_reg)
            else:
                raw, w, val = _finish_plain(
                    raw, yj, gh, leaf, loss=loss, n_leaves=L,
                    lr=p.learning_rate, l2=p.l2_reg)
            raw.block_until_ready()
            t3 = time.perf_counter()
            sf_rows.append(np.asarray(jnp.stack(sf_d), np.int32)
                           if sf_d else np.zeros((0,), np.int32))
            sb_rows.append(np.asarray(jnp.stack(sb_d), np.int32)
                           if sb_d else np.zeros((0,), np.int32))
            lv_rows.append(np.asarray(w, np.float32))
            loss_vals.append(float(val))
            self.metrics.note_iteration(N, hist_s, split_s, t3 - t2,
                                        t3 - t_iter, loss_vals[-1])
            if _TRACER.enabled:
                _TRACER.complete(
                    "train/iteration", "train",
                    start_ns=int(t_iter * 1e9),
                    duration_ns=int((t3 - t_iter) * 1e9),
                    iteration=it, rows=N,
                    hist_ms=hist_s * 1e3, split_ms=split_s * 1e3,
                    leaf_ms=(t3 - t2) * 1e3, loss=loss_vals[-1])
            done = it + 1
            if checkpoint is not None and checkpoint_every > 0 and (
                    done % checkpoint_every == 0 or done == p.n_trees):
                checkpoint.save(done, TrainState(
                    iteration=done, key=np.asarray(key),
                    split_features=np.stack(sf_rows),
                    split_bins=np.stack(sb_rows),
                    leaf_values=np.stack(lv_rows),
                    raw=np.asarray(raw),
                    train_loss=np.asarray(loss_vals, np.float32)).tree())
        if checkpoint is not None:
            checkpoint.wait()

        T = len(sf_rows)
        sfs = (jnp.asarray(np.stack(sf_rows), jnp.int32) if T
               else jnp.zeros((0, depth), jnp.int32))
        sbs = (jnp.asarray(np.stack(sb_rows), jnp.int32) if T
               else jnp.zeros((0, depth), jnp.int32))
        lvs = (jnp.asarray(np.stack(lv_rows), jnp.float32) if T
               else jnp.zeros((0, L, C), jnp.float32))
        ensemble = ObliviousEnsemble(
            split_features=sfs, split_bins=sbs, leaf_values=lvs,
            borders=borders, n_borders=jnp.asarray(n_borders),
            base_score=raw0[0].astype(jnp.float32))

        # Closed train->serve loop: the reported training-time
        # predictions ARE a serving plan's output on the training pool,
        # so `Predictor.build` round-trips to EXACT parity (same
        # lowering, same jitted program, same inputs).  The int32
        # escape hatch (> 255 borders, no pool) evaluates through the
        # same staged ops instead.
        if pool is not None:
            self.plan_ = predictor_mod.Predictor.build(
                ensemble, strategy="staged", layout="soa",
                backend=self.backend)
            final_raw = self.plan_.raw(pool)
        else:
            idx = ops.leaf_index(jnp.asarray(bins), sfs, sbs,
                                 backend=self.backend)
            final_raw = raw0[:1] + ops.leaf_gather(idx, lvs,
                                                   backend=self.backend)

        delta = {op: n - stats0.get(op, 0)
                 for op, n in registry.call_stats().items()
                 if n != stats0.get(op, 0)}
        self.metrics.note_hist_dispatches(delta.get("histogram", 0))
        history = {
            "train_loss": np.asarray(loss_vals, np.float32),
            "final_metric": float(loss.metric(raw, yj)),
            "final_raw": np.asarray(final_raw, np.float32),
            # float-association drift between the accumulated training
            # raw and the served re-score (systematic under ordered
            # boosting, where stored leaf values deliberately differ
            # from the ordered update)
            "serve_drift": float(np.max(np.abs(
                np.asarray(final_raw) - np.asarray(raw)))) if T else 0.0,
            "dispatch_delta": delta,
            "metrics": self.metrics.snapshot(),
        }
        return ensemble, history
