"""Optimizers: AdamW, Adafactor, SGD — self-contained (no optax).

Interface:  opt = adamw(lr=...);  state = opt.init(params)
            updates, state = opt.update(grads, state, params)
            params = tree_map(lambda p, u: p + u, params, updates)

Adafactor exists because 1T-param models (kimi-k2) cannot afford Adam's
2x fp32 moments on a 512-chip pod: the second moment is factored into
row/col statistics (O(n+m) per matrix instead of O(nm)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, count):
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def sched(count):
        count = count.astype(jnp.float32)
        warm = peak * count / max(warmup, 1)
        frac = jnp.clip((count - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5
                      * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(count < warmup, warm, cos)
    return sched


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    kind: str
    global_norm: Callable = global_norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
def adamw(lr: Schedule = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros(), "v": zeros(),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9)) \
            if clip_norm else 1.0
        lr_t = _lr_at(lr, count)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** count.astype(jnp.float32))
            vhat = v2 / (1 - b2 ** count.astype(jnp.float32))
            step = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                            + weight_decay * p.astype(jnp.float32))
            return step, m2, v2

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init=init, update=update, kind="adamw")


# --------------------------------------------------------------------------
# Adafactor (factored second moment, no fp32 master copies)
# --------------------------------------------------------------------------
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(lr: Schedule = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def vr(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                    else jnp.zeros(p.shape, jnp.float32))

        def vc(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p.shape) else jnp.zeros((0,), jnp.float32))

        return {"vr": jax.tree_util.tree_map(vr, params),
                "vc": jax.tree_util.tree_map(vc, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay
        lr_t = _lr_at(lr, count)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(g.shape):
                vr2 = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc2 = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr2[..., None] * vc2[..., None, :]
                    / jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True)
                                  [..., None], eps))
            else:
                vr2 = beta * vr + (1 - beta) * g2
                vc2 = vc
                denom = jnp.sqrt(vr2)
            u = g / jnp.maximum(denom, eps)
            # RMS clipping (Adafactor's update clipping)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            step = -lr_t * (u + weight_decay * p.astype(jnp.float32))
            return step, vr2, vc2

        out = jax.tree_util.tree_map(upd, grads, state["vr"], state["vc"],
                                     params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"vr": pick(1), "vc": pick(2), "count": count}

    return Optimizer(init=init, update=update, kind="adafactor")


def sgd(lr: Schedule = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        lr_t = _lr_at(lr, count)

        def upd(g, m):
            m2 = momentum * m + g.astype(jnp.float32)
            return -lr_t * m2, m2

        out = jax.tree_util.tree_map(upd, grads, state["m"])
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "count": count}

    return Optimizer(init=init, update=update, kind="sgd")


def make(cfg, total_steps: int = 10000, peak_lr: float = 3e-4) -> Optimizer:
    sched = warmup_cosine(peak_lr, min(1000, total_steps // 10), total_steps)
    if cfg.optimizer == "adafactor":
        return adafactor(lr=sched)
    return adamw(lr=sched, weight_decay=0.1)
