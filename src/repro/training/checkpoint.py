"""Fault-tolerant checkpointing.

Design (DESIGN.md SS5):
  * atomic two-phase commit: write into `step_N.tmp/`, fsync, os.replace
    to `step_N/` — a crash mid-save never corrupts the latest checkpoint
  * mesh-agnostic layout: leaves are stored as full logical numpy arrays
    + a manifest of the pytree structure, so a checkpoint written on a
    16x16 mesh restores onto 8x8 (elastic scaling) or a single host
  * async save: the host copy happens on the caller thread (cheap), the
    serialization + rename on a background thread
  * retention: keep_last prunes old steps, latest() enables auto-resume
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, *, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        self.wait()                      # one in-flight save at a time
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}

        def work():
            try:
                tmp = self.dir / f"step_{step:09d}.tmp"
                final = self.dir / f"step_{step:09d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir()
                np.savez(tmp / "leaves.npz", **host)
                manifest = {"step": step,
                            "keys": sorted(host.keys()),
                            "shapes": {k: list(v.shape)
                                       for k, v in host.items()},
                            "dtypes": {k: str(v.dtype)
                                       for k, v in host.items()}}
                with open(tmp / "manifest.json", "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._prune()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_pending()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- load --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> Any:
        if step is None:
            step = self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:09d}"
        with np.load(path / "leaves.npz") as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(flat)

    def restore_sharded(self, mesh, spec_tree, step: Optional[int] = None):
        """Elastic restore: place the logical checkpoint onto any mesh."""
        from repro.distributed import sharding as shd
        tree = self.restore(step)
        return shd.shard_tree(tree, mesh, spec_tree)
