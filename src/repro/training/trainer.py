"""Fault-tolerant training loop.

Responsibilities:
  * jit the train step with explicit in/out shardings for the active mesh
  * checkpoint/restart: periodic atomic saves, auto-resume from latest
    (params + optimizer state + data position), survive injected failures
  * straggler mitigation: per-step wall-time EMA; steps slower than
    `straggler_factor` x EMA are logged and counted — at multi-host scale
    this signal drives the (host-level) work re-queue; here it also
    feeds the bounded prefetch queue so one slow component cannot stall
    the pipeline silently
  * elastic scaling: checkpoints are mesh-agnostic; `Trainer.restore`
    re-shards onto whatever mesh the trainer was built with
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import steps as steps_lib
from repro.models import transformer as tf
from repro.training import optimizer as opt_lib
from repro.training.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    peak_lr: float = 3e-4
    straggler_factor: float = 3.0
    keep_ckpts: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh: Mesh, ckpt_dir: str,
                 tcfg: TrainerConfig = TrainerConfig(), *,
                 max_positions: int = 0, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.tcfg = tcfg
        self.optimizer = opt_lib.make(cfg, tcfg.total_steps, tcfg.peak_lr)
        self.ckpt = CheckpointManager(ckpt_dir, keep_last=tcfg.keep_ckpts)
        self.max_positions = max_positions

        self.p_specs = shd.param_specs(cfg, mesh,
                                       max_positions=max_positions)
        self.o_specs = shd.opt_state_specs(self.p_specs,
                                           self.optimizer.kind)
        step_fn = steps_lib.make_train_step(cfg, self.optimizer)
        self._jit_step = jax.jit(
            step_fn,
            in_shardings=(shd.named(mesh, self.p_specs),
                          shd.named(mesh, self.o_specs), None),
            out_shardings=(shd.named(mesh, self.p_specs),
                           shd.named(mesh, self.o_specs), None),
            donate_argnums=(0, 1))
        self._seed = seed
        self.step = 0
        self.params = None
        self.opt_state = None
        # telemetry
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []
        self._ema: Optional[float] = None

    # -- state -------------------------------------------------------------
    def initialize(self):
        key = jax.random.PRNGKey(self._seed)
        with self.mesh:
            params = tf.init_params(self.cfg, key,
                                    max_positions=self.max_positions)
            opt_state = self.optimizer.init(params)
        self.params = shd.shard_tree(params, self.mesh, self.p_specs)
        self.opt_state = shd.shard_tree(opt_state, self.mesh, self.o_specs)
        self.step = 0

    def restore(self) -> bool:
        """Auto-resume from the latest checkpoint. True if restored."""
        latest = self.ckpt.latest()
        if latest is None:
            return False
        state = self.ckpt.restore(latest)
        self.params = shd.shard_tree(state["params"], self.mesh,
                                     self.p_specs)
        self.opt_state = shd.shard_tree(state["opt_state"], self.mesh,
                                        self.o_specs)
        self.step = int(state["meta"]["step"][()])
        return True

    def init_or_restore(self):
        if not self.restore():
            self.initialize()

    def save(self, blocking: bool = False):
        self.ckpt.save(self.step, {
            "params": self.params,
            "opt_state": self.opt_state,
            "meta": {"step": np.asarray(self.step)},
        }, blocking=blocking)

    # -- loop --------------------------------------------------------------
    def train(self, batches: Iterator[dict], *, num_steps: int | None = None,
              fail_at: Optional[int] = None) -> list[dict]:
        """Run steps; `fail_at` injects a simulated crash (tests)."""
        assert self.params is not None, "call init_or_restore() first"
        num_steps = num_steps or self.tcfg.total_steps
        history = []
        it = iter(batches)
        # replay data position on resume (deterministic sources index by
        # step; stream sources skip consumed batches)
        for _ in range(self.step):
            next(it, None)

        while self.step < num_steps:
            batch = next(it, None)
            if batch is None:
                break
            t0 = time.perf_counter()
            with self.mesh:
                self.params, self.opt_state, metrics = self._jit_step(
                    self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            # the first step includes compile time: exclude it from the
            # straggler EMA or it poisons the baseline
            if len(self.step_times) >= 2:
                if self._ema is None:
                    self._ema = dt
                if dt > self.tcfg.straggler_factor * self._ema \
                        and len(self.step_times) > 3:
                    self.straggler_steps.append(self.step)
                self._ema = 0.9 * self._ema + 0.1 * dt

            self.step += 1
            metrics["step"] = self.step
            history.append(metrics)
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
            if fail_at is not None and self.step >= fail_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {self.step}")
        self.save(blocking=True)
        return history
