"""Training substrate: optimizers, fault-tolerant trainer, checkpointing."""
from repro.training import checkpoint, gbdt, optimizer, trainer  # noqa: F401
