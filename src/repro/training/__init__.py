"""Training substrate: optimizers, fault-tolerant trainer, checkpointing."""
from repro.training import checkpoint, optimizer, trainer  # noqa: F401
