"""Static contract checking for the kernel registry.

Abstractly traces every (op × impl × layout × bin-dtype) capability
claim in `repro.kernels.registry` with `jax.make_jaxpr` — nothing is
executed or compiled — and lints the jaxprs for the contracts the
paper's vectorization depends on: uint8 widening discipline, the
bitpacked integer pipeline, VMEM working sets vs the tuning footprint
models, plan-entry transfer/retrace hygiene, and registry capability
consistency.  `python -m repro.launch.analyze` is the CLI;
docs/analysis.md documents the rules.
"""
from repro.analysis.checker import run_check
from repro.analysis.matrix import Cell, enumerate_cells
from repro.analysis.report import (ContractReport, Finding, RULES,
                                   default_report_path,
                                   parse_suppressions)

__all__ = ["run_check", "Cell", "enumerate_cells", "ContractReport",
           "Finding", "RULES", "default_report_path",
           "parse_suppressions"]
