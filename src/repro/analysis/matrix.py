"""Capability-matrix enumeration and abstract tracing.

Cells are enumerated from `registry.table()` exactly the way
tests/test_differential.py builds its parametrization — one cell per
(op, impl, layout, bin-dtype) claim — so a new registration (or a new
layout/dtype claim on an existing one) is covered by the contract
checker with zero new code here.

Each cell maps to one or more *call variants*: concrete ShapeDtypeStruct
argument lists for the registered fn at canonical dims, traced with
`jax.make_jaxpr` (never executed, never compiled).  Layout-independent
ops (binarize, l2sq, histogram) produce identical avals across layouts,
so the module-level trace cache collapses them; the checker's
cells/traces counters make the collapse visible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.analysis import jaxpr_tools

# Canonical dims.  Small on purpose: make_jaxpr cost is shape-blind,
# and the lint rules are dtype/structure properties, not size ones.
N, F, B, T, D, L, C = 64, 7, 9, 6, 4, 16, 2
TP, FP = 16, 128      # padded tree/feature dims the lowered layouts carry
B_WIDE = 300          # >255 borders: forces the int32 bins scratch path


@dataclasses.dataclass(frozen=True)
class Cell:
    """One capability claim: op × impl × layout × bin-dtype."""
    op: str
    impl: str
    layout: str
    dtype: str

    @property
    def key(self) -> str:
        return f"{self.op}:{self.impl}"

    def __str__(self) -> str:
        return f"{self.key}[{self.layout}/{self.dtype}]"


def enumerate_cells(*, ops_filter=None, impls_filter=None) -> list[Cell]:
    """Every capability-table cell, optionally filtered.  Filters take
    op names / "op:impl" keys respectively."""
    out = []
    for row in registry.table():
        if ops_filter is not None and row["op"] not in ops_filter:
            continue
        if impls_filter is not None \
                and f"{row['op']}:{row['impl']}" not in impls_filter:
            continue
        for lay in row["layouts"].split("/"):
            for dt in row["dtypes"].split("/"):
                out.append(Cell(row["op"], row["impl"], lay, dt))
    return out


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def cell_variants(cell: Cell) -> list[tuple[tuple, dict]]:
    """(args, static kwargs) call variants to trace for one cell.

    Shapes mirror the differential harness's call conventions: soa ops
    take raw (T, D) arrays (the registered wrappers pad), depth-major
    ops take the pre-lowered padded (TP, D, FP) arrays, bitpacked ops
    take the (D, TP) transposed planes.
    """
    f32, i32 = jnp.float32, jnp.int32
    bt = jnp.dtype(cell.dtype) if cell.dtype in ("uint8", "int32") else i32

    if cell.op == "binarize":
        return [((_sds((N, F), f32), _sds((B, F), f32)), {})]

    if cell.op == "l2sq":
        refs = _sds((16, 5), f32)
        return [((_sds((8, 5), f32), refs), {}),    # matrix
                ((_sds((5,), f32), refs), {})]      # rowwise

    if cell.op == "leaf_index":
        if cell.layout in ("soa", "depth_grouped"):
            return [((_sds((N, F), bt), _sds((T, D), i32),
                      _sds((T, D), i32)), {})]
        if cell.layout == "depth_major":
            return [((_sds((N, FP), bt), _sds((TP, D, FP), f32),
                      _sds((D, TP), i32), _sds((D, 1), f32)), {})]
        # bitpacked: the ref path consumes the lowering's planes, which
        # are narrowed to uint8 for u8 pools — trace what production
        # feeds it, or the widening lint would flag the promotion jnp
        # inserts for a mixed uint8-vs-int32 compare that never runs.
        plane = (jnp.uint8 if (cell.dtype == "uint8"
                               and cell.impl.startswith("ref")) else i32)
        return [((_sds((N, F), bt), _sds((D, TP), plane),
                  _sds((D, TP), plane)), {})]

    if cell.op == "leaf_gather":
        return [((_sds((N, T), i32), _sds((T, L, C), f32)), {})]

    if cell.op == "histogram":
        return [((_sds((F, N), bt), _sds((N,), i32), _sds((N, C), f32)),
                 {"n_bins": B + 1, "n_leaves": 4})]

    assert cell.op == "fused_predict", cell.op
    # dtype here claims the bins-scratch dtype the kernel may pick:
    # uint8 needs <=255 borders, int32 cells trace the >255 path.
    nb = B if cell.dtype == "uint8" else B_WIDE
    if cell.layout in ("soa", "depth_grouped"):
        return [((_sds((N, F), f32), _sds((nb, F), f32),
                  _sds((T, D), i32), _sds((T, D), i32),
                  _sds((T, L, C), f32)), {})]
    if cell.layout == "depth_major":
        return [((_sds((N, FP), f32), _sds((nb, FP), f32),
                  _sds((TP, D, FP), f32), _sds((D, TP), i32),
                  _sds((D, 1), f32), _sds((TP, L, C), f32)), {})]
    # bitpacked
    return [((_sds((N, F), f32), _sds((nb, F), f32),
              _sds((D, TP), i32), _sds((D, TP), i32),
              _sds((TP, L, C), f32)), {})]


# --------------------------------------------------------------------------
# Trace cache
# --------------------------------------------------------------------------
_TRACE_CACHE: dict[tuple, Any] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def _trace_key(cell: Cell, args, kwargs) -> tuple:
    sig = tuple((tuple(a.shape), str(a.dtype)) for a in args)
    return (cell.key, sig, tuple(sorted(kwargs.items())))


def trace_cell(cell: Cell) -> list[Any]:
    """ClosedJaxprs for every call variant of the cell, through the
    module-level cache.  Raises whatever the trace raises — the checker
    turns that into a capability finding."""
    impl = registry.get(cell.op, cell.impl)
    out = []
    for args, kwargs in cell_variants(cell):
        key = _trace_key(cell, args, kwargs)
        if key in _TRACE_CACHE:
            _CACHE_STATS["hits"] += 1
        else:
            _TRACE_CACHE[key] = jaxpr_tools.trace_abstract(
                impl.fn, *args, **kwargs)
            _CACHE_STATS["misses"] += 1
        out.append(_TRACE_CACHE[key])
    return out


def cache_stats() -> dict[str, int]:
    return dict(_CACHE_STATS)


def reset_cache() -> None:
    _TRACE_CACHE.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0


# --------------------------------------------------------------------------
# Canonical ensemble (plan lints + layout-cost audit)
# --------------------------------------------------------------------------
def canonical_ensemble(*, n_features: int = FP, n_trees: int = 64,
                       n_borders: int = B, n_outputs: int = C,
                       depth: int = D, seed: int = 17):
    """Mixed-true-depth ensemble at lowering-friendly dims (features
    already lane-aligned, trees a block multiple) so the layout-cost
    audit compares model vs actual bytes without padding noise."""
    from repro.core import trees
    from repro.core.trees import ObliviousEnsemble

    rng = np.random.default_rng(seed)
    borders = np.sort(rng.normal(size=(n_borders, n_features)), 0) \
        .astype(np.float32)
    sf = rng.integers(0, n_features, (n_trees, depth)).astype(np.int32)
    sb = rng.integers(1, n_borders + 1, (n_trees, depth)).astype(np.int32)
    lv = rng.normal(size=(n_trees, 1 << depth, n_outputs)) \
        .astype(np.float32)
    ens = ObliviousEnsemble(jnp.asarray(sf), jnp.asarray(sb),
                            jnp.asarray(lv), jnp.asarray(borders),
                            jnp.full((n_features,), n_borders, jnp.int32))
    true_depths = rng.integers(1, depth + 1, n_trees)
    true_depths[0] = depth          # keep dmax = depth
    return trees.truncate_tree_depths(ens, true_depths), true_depths
