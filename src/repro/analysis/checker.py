"""The contract checker: run every pass over the capability matrix.

`run_check()` is the one entry point (`repro.launch.analyze` is its
CLI).  Flow:

  1. enumerate cells from `registry.table()` and abstract-trace each
     (matrix.trace_cell; module-level cache collapses layout-identical
     calls);
  2. lint every trace (widening, int-pipeline, VMEM audit);
  3. capability negatives: `resolve` must reject or re-route every
     (layout, dtype) an implementation does NOT claim;
  4. plan walk: `Predictor.trace_entries` + transfer/retrace lints;
  4b. shard-parity: the sharded entry points abstract-traced over an
     `AbstractMesh` per layout, linted for gathering collectives;
  5. tuning consistency: chunk planner and layout-cost model audits;
  6. apply declared suppressions, flag unused ones, derive the
     per-impl `verified` verdict map the registry table displays.

Filters (`ops_filter`, `impls_filter`, `include_plan`,
`include_tuning`) narrow a run for tests; unused-suppression detection
only runs on unfiltered matrices (a narrowed run cannot know a
suppression is stale).
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.kernels import registry
from repro.analysis import matrix, passes
from repro.analysis.report import ContractReport, Finding, \
    parse_suppressions


def _trace_cell_findings(cell: matrix.Cell) -> tuple[list[Finding], int]:
    """All per-cell findings + pallas kernels audited."""
    try:
        traces = matrix.trace_cell(cell)
    except Exception as e:  # declared combo must trace — this is the claim
        return [Finding(rule="capability", op=cell.op, impl=cell.impl,
                        layout=cell.layout, dtype=cell.dtype,
                        message=f"declared combo failed to trace: "
                                f"{type(e).__name__}: {e}")], 0
    findings: list[Finding] = []
    kernels = 0
    for closed in traces:
        findings += passes.widening_lint(cell, closed)
        findings += passes.integer_pipeline_lint(cell, closed)
        vmem, n = passes.vmem_audit(cell, closed)
        findings += vmem
        kernels += n
    return findings, kernels


def _capability_negatives(rows: list[dict]) -> list[Finding]:
    """Every (layout, dtype) an impl does NOT claim must be rejected by
    `resolve` — or routed to a sibling that does claim it.  The
    universe per op is what its impls collectively claim (plus the
    other ops' layouts: an impl must also reject layouts its op has
    never heard of)."""
    out: list[Finding] = []
    all_rows = registry.table()
    universe_lay = {l for r in all_rows for l in r["layouts"].split("/")}
    universe_dt = {d for r in all_rows for d in r["dtypes"].split("/")}
    for row in rows:
        op, name = row["op"], row["impl"]
        claimed_lay = set(row["layouts"].split("/"))
        claimed_dt = set(row["dtypes"].split("/"))
        for lay in sorted(universe_lay - claimed_lay):
            try:
                resolved = registry.resolve(op, name, layout=lay)
            except (ValueError, KeyError):
                continue
            if resolved == name:
                out.append(Finding(
                    rule="capability", op=op, impl=name, layout=lay,
                    message=f"resolve accepted undeclared layout "
                            f"{lay!r} without re-routing"))
        for dt in sorted(universe_dt - claimed_dt):
            try:
                resolved = registry.resolve(op, name, dtype=dt)
            except (ValueError, KeyError):
                continue
            if resolved == name:
                out.append(Finding(
                    rule="capability", op=op, impl=name, dtype=dt,
                    message=f"resolve accepted undeclared dtype "
                            f"{dt!r} without re-routing"))
    return out


def _plan_findings(batch_sizes: Sequence[int]) -> list[Finding]:
    """Walk a canonical plan's entries per strategy (staged exercises
    the kernel pipeline entry-by-entry, fused the single-kernel path)
    and lint each abstract trace.  Also asserts the walk itself kept
    the no-compile contract: stats()['traces'] must stay empty."""
    from repro.core.predictor import Predictor

    ens, _ = matrix.canonical_ensemble()
    out: list[Finding] = []
    for strategy in ("staged", "fused"):
        plan = Predictor.build(ens, strategy=strategy)
        entries = plan.trace_entries(batch_sizes=batch_sizes)
        for label, closed in entries.items():
            for f in passes.entry_findings(f"{strategy}:{label}", closed):
                out.append(f)
        stats = plan.stats
        if stats["total_traces"]:
            out.append(Finding(
                rule="trace-error", op="plan", impl=strategy,
                message=f"trace_entries compiled {stats['traces']} — "
                        "the plan walk must stay abstract"))
    return out


def _apply_suppressions(findings: list[Finding],
                        rows: list[dict],
                        check_unused: bool) -> list[Finding]:
    """Mark findings covered by declared suppressions; append
    unused-suppression findings for stale declarations."""
    declared = {}
    for row in rows:
        if row["suppressions"]:
            declared[(row["op"], row["impl"])] = parse_suppressions(
                row["suppressions"].split(" ; "))
    used: set[tuple] = set()
    for f in findings:
        rules = declared.get((f.op, f.impl))
        if rules is not None and f.rule in rules:
            f.suppressed = True
            used.add((f.op, f.impl, f.rule))
    if check_unused:
        for (op, name), rules in sorted(declared.items()):
            for rule, reason in sorted(rules.items()):
                if (op, name, rule) not in used:
                    findings.append(Finding(
                        rule="unused-suppression", op=op, impl=name,
                        message=f"declared suppression {rule!r} "
                                f"({reason or 'no reason'}) matched no "
                                "finding — remove it"))
    return findings


def run_check(*, ops_filter: Optional[Sequence[str]] = None,
              impls_filter: Optional[Sequence[str]] = None,
              include_plan: bool = True,
              include_shard: bool = True,
              include_tuning: bool = True,
              check_unused: Optional[bool] = None,
              batch_sizes: Sequence[int] = (8,)) -> ContractReport:
    """Run the full contract check; see the module docstring."""
    ops_filter = set(ops_filter) if ops_filter is not None else None
    impls_filter = set(impls_filter) if impls_filter is not None else None
    filtered = ops_filter is not None or impls_filter is not None
    if check_unused is None:
        check_unused = not filtered

    rows = [r for r in registry.table()
            if (ops_filter is None or r["op"] in ops_filter)
            and (impls_filter is None
                 or f"{r['op']}:{r['impl']}" in impls_filter)]

    before = matrix.cache_stats()
    cells = matrix.enumerate_cells(ops_filter=ops_filter,
                                   impls_filter=impls_filter)
    findings: list[Finding] = []
    kernels = 0
    for cell in cells:
        cell_findings, n = _trace_cell_findings(cell)
        findings += cell_findings
        kernels += n

    findings += _capability_negatives(rows)
    if include_plan:
        findings += _plan_findings(batch_sizes)
    if include_shard:
        findings += passes.shard_parity_findings(batch_sizes)
    if include_tuning:
        findings += passes.chunk_model_findings()
        findings += passes.layout_cost_findings()

    findings = _apply_suppressions(findings, rows, check_unused)

    verified: dict[str, str] = {}
    for row in rows:
        key = f"{row['op']}:{row['impl']}"
        mine = [f for f in findings if (f.op, f.impl)
                == (row["op"], row["impl"])]
        if any(not f.suppressed for f in mine):
            verified[key] = "FAIL"
        elif mine:
            verified[key] = f"ok ({len(mine)} suppressed)"
        else:
            verified[key] = "ok"

    after = matrix.cache_stats()
    return ContractReport(
        findings=findings,
        cells=len(cells),
        traces=after["misses"] - before["misses"],
        trace_cache_hits=after["hits"] - before["hits"],
        kernels=kernels,
        verified=verified)
