"""Jaxpr walking utilities shared by the lint passes.

Everything here works on `jax.make_jaxpr` output — pure abstract
traces, nothing is compiled or executed.  Two structural facts the
walkers rely on (pinned by tests/test_analysis.py so a jax upgrade
that changes them fails loudly):

  * higher-order eqns (pjit, scan, while, cond, pallas_call) carry
    their body as a Jaxpr/ClosedJaxpr somewhere in `eqn.params` —
    possibly nested inside tuples/lists — so generic recursion over
    params values finds every sub-jaxpr without a per-primitive table;
  * `pallas_call` body invars have MemRef avals whose `.inner_aval`
    holds the real ShapedArray; their shapes are the BLOCK shapes the
    grid spec carved out, which is exactly what a VMEM audit needs.
"""
from __future__ import annotations

from typing import Any, Iterator, Optional

import jax
import numpy as np
from jax._src import core as jax_core


# --------------------------------------------------------------------------
# Avals
# --------------------------------------------------------------------------
def unwrap_aval(aval: Any) -> Any:
    """MemRef avals (pallas kernel refs) wrap the payload ShapedArray
    in `.inner_aval`; everything else passes through."""
    return getattr(aval, "inner_aval", aval)


def aval_bytes(aval: Any) -> int:
    """Buffer size in bytes, 0 for avals without shape/dtype (tokens)."""
    aval = unwrap_aval(aval)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def aval_short(aval: Any) -> str:
    aval = unwrap_aval(aval)
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    return f"{np.dtype(dtype).name if dtype is not None else '?'}" \
           f"[{','.join(str(d) for d in shape)}]"


# --------------------------------------------------------------------------
# Sub-jaxpr recursion
# --------------------------------------------------------------------------
def _params_jaxprs(value: Any) -> Iterator[jax_core.Jaxpr]:
    """Yield every Jaxpr reachable from one params value."""
    if isinstance(value, jax_core.Jaxpr):
        yield value
    elif isinstance(value, jax_core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, (tuple, list)):
        for item in value:
            yield from _params_jaxprs(item)


def eqn_subjaxprs(eqn: jax_core.JaxprEqn) -> list[jax_core.Jaxpr]:
    """Sub-jaxprs carried by one equation (pjit/scan/while/cond bodies,
    pallas_call kernel bodies, ...)."""
    out: list[jax_core.Jaxpr] = []
    for value in eqn.params.values():
        out.extend(_params_jaxprs(value))
    return out


def iter_jaxprs(jaxpr: jax_core.Jaxpr) -> Iterator[jax_core.Jaxpr]:
    """The jaxpr and every nested sub-jaxpr, outermost first."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for sub in eqn_subjaxprs(eqn):
            yield from iter_jaxprs(sub)


def find_pallas_calls(
        jaxpr: jax_core.Jaxpr) -> list[jax_core.JaxprEqn]:
    """Every pallas_call equation anywhere in the trace."""
    return [eqn for j in iter_jaxprs(jaxpr) for eqn in j.eqns
            if eqn.primitive.name == "pallas_call"]


def pallas_kernel_jaxpr(eqn: jax_core.JaxprEqn) -> jax_core.Jaxpr:
    """The kernel-body jaxpr of a pallas_call eqn (invars are refs with
    BLOCK-shaped inner avals)."""
    body = eqn.params.get("jaxpr")
    if isinstance(body, jax_core.ClosedJaxpr):
        body = body.jaxpr
    if not isinstance(body, jax_core.Jaxpr):
        raise TypeError("pallas_call eqn carries no kernel jaxpr "
                        f"(params keys: {sorted(eqn.params)})")
    return body


def pallas_ref_avals(eqn: jax_core.JaxprEqn) -> list[Any]:
    """Unwrapped (ShapedArray) avals of the kernel body's refs, in
    invar order — inputs, then outputs, then scratch."""
    return [unwrap_aval(v.aval) for v in pallas_kernel_jaxpr(eqn).invars]


# --------------------------------------------------------------------------
# Dataflow within one (sub)jaxpr scope
# --------------------------------------------------------------------------
def consumers_map(
        jaxpr: jax_core.Jaxpr
) -> dict[jax_core.Var, list[jax_core.JaxprEqn]]:
    """var -> equations (in this scope only) that read it."""
    out: dict[jax_core.Var, list[jax_core.JaxprEqn]] = {}
    for eqn in jaxpr.eqns:
        for invar in eqn.invars:
            if isinstance(invar, jax_core.Var):
                out.setdefault(invar, []).append(eqn)
    return out


# Ops that merely move/reshape data: a value flowing through them keeps
# its identity for the terminal-consumer walk.
LAYOUT_PRESERVING = frozenset({
    "transpose", "reshape", "broadcast_in_dim", "squeeze", "slice",
    "rev", "copy", "dynamic_slice",
})

# Call-like primitives whose body invars map 1:1 onto the eqn invars,
# so the walk can descend (jnp helpers like `take`/`einsum` wrap their
# gather/dot in a named pjit — a widened panel must be followed inside
# or the lint would stop at the wrapper).  Loop/branch primitives
# (scan, while, cond) interleave carries/consts and stay boundaries.
_CALL_PRIMS = frozenset({"pjit", "closed_call", "core_call",
                         "custom_jvp_call", "custom_vjp_call"})


def _call_body(eqn: jax_core.JaxprEqn) -> Optional[jax_core.Jaxpr]:
    for key in ("jaxpr", "call_jaxpr"):
        j = eqn.params.get(key)
        if isinstance(j, jax_core.ClosedJaxpr):
            return j.jaxpr
        if isinstance(j, jax_core.Jaxpr):
            return j
    return None


def terminal_consumers(
        jaxpr: jax_core.Jaxpr,
        start: jax_core.Var,
        consumers: Optional[dict] = None,
) -> list[tuple[jax_core.JaxprEqn, jax_core.Var]]:
    """(eqn, var) pairs that *use* (not merely move) the value in
    `start` — `var` is the alias of `start` the eqn actually reads, so
    callers can check which operand position it feeds.

    Follows outputs of LAYOUT_PRESERVING eqns transitively, and
    descends into call-like sub-jaxprs (pjit etc.) by operand
    position.  Loop/branch eqns (scan, while, pallas_call) are
    boundaries: returned as terminals for the caller to classify.  A
    value that escapes via a scope's outvars is simply not reported
    (the enclosing scope sees the producing eqn)."""
    cmaps: dict[int, dict] = {
        id(jaxpr): consumers if consumers is not None
        else consumers_map(jaxpr)}

    def cmap(scope):
        m = cmaps.get(id(scope))
        if m is None:
            m = consumers_map(scope)
            cmaps[id(scope)] = m
        return m

    out: list[tuple[jax_core.JaxprEqn, jax_core.Var]] = []
    seen: set[tuple[int, int]] = set()
    stack = [(jaxpr, start)]
    while stack:
        scope, var = stack.pop()
        for eqn in cmap(scope).get(var, ()):
            if (id(eqn), id(var)) in seen:
                continue
            seen.add((id(eqn), id(var)))
            subs = eqn_subjaxprs(eqn)
            name = eqn.primitive.name
            if not subs and name in LAYOUT_PRESERVING:
                stack.extend((scope, v) for v in eqn.outvars
                             if isinstance(v, jax_core.Var))
            elif subs and name in _CALL_PRIMS:
                body = _call_body(eqn)
                if body is not None \
                        and len(body.invars) == len(eqn.invars):
                    stack.extend((body, body.invars[pos])
                                 for pos, iv in enumerate(eqn.invars)
                                 if iv is var)
                else:  # unexpected arity: keep it visible as terminal
                    out.append((eqn, var))
            else:
                out.append((eqn, var))
    return out


# --------------------------------------------------------------------------
# Liveness / peak-working-set estimate
# --------------------------------------------------------------------------
# Primitives whose output is never a fresh buffer: a pallas `get`
# reads a VMEM-resident ref block (the ref itself is counted by the
# audit), and XLA fuses/rematerializes iota and broadcasts into their
# consumers rather than materializing them.
_UNCHARGED = frozenset({"get", "iota", "broadcast_in_dim"})


def peak_live_bytes(jaxpr: jax_core.Jaxpr,
                    include_invars: bool = True) -> int:
    """Upper-bound estimate of the scope's peak live buffer bytes.

    Walks eqns in order; an eqn's outputs are allocated when it runs,
    its inputs are released after their last use — for a leaf eqn the
    dying inputs release *before* the output allocates (XLA donates
    elementwise operands in place), for an eqn carrying sub-jaxprs
    (scan/pjit bodies stay live while the body runs) they release
    after.  Sub-jaxpr scopes contribute their own peak as a transient
    on top of the enclosing live set.  Pessimistic for XLA fusion
    (which may never materialize intermediates) but honest as a "what
    could be resident at once" bound, which is what the VMEM audit
    compares against the tuning footprint models.  Ref loads (`get`),
    iota/broadcast values and dead outputs (`swap`'s discarded old
    value) are not charged."""
    last_use: dict[jax_core.Var, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if isinstance(v, jax_core.Var):
                last_use[v] = i
    n_eqns = len(jaxpr.eqns)
    for v in jaxpr.outvars:
        if isinstance(v, jax_core.Var):
            last_use[v] = n_eqns  # escapes: live to the end

    def out_bytes(v, eqn) -> int:
        if eqn.primitive.name in _UNCHARGED or v not in last_use:
            return 0
        return aval_bytes(v.aval)

    alloc_by: dict[jax_core.Var, jax_core.JaxprEqn] = {}

    def release(v) -> int:
        src = alloc_by.get(v)
        if src is not None:
            return out_bytes(v, src)
        return aval_bytes(v.aval) if include_invars else 0

    live = 0
    if include_invars:
        roots = list(jaxpr.invars) + list(jaxpr.constvars)
        live += sum(aval_bytes(v.aval) for v in roots)
    peak = live
    for i, eqn in enumerate(jaxpr.eqns):
        subs = eqn_subjaxprs(eqn)
        dying = [v for v in eqn.invars
                 if isinstance(v, jax_core.Var) and last_use.get(v) == i]
        if not subs:
            live -= sum(release(v) for v in dying)
        for v in eqn.outvars:
            if isinstance(v, jax_core.Var):
                alloc_by[v] = eqn
                live += out_bytes(v, eqn)
        transient = 0
        for sub in subs:
            # Sub-scope invars alias buffers already counted live here,
            # so only its *interior* growth is a transient.
            transient = max(transient,
                            peak_live_bytes(sub, include_invars=False))
        peak = max(peak, live + transient)
        if subs:
            live -= sum(release(v) for v in dying)
    return peak


# --------------------------------------------------------------------------
# Tracing
# --------------------------------------------------------------------------
def trace_abstract(fn: Any, *avals: Any, **kwargs: Any):
    """`jax.make_jaxpr` over ShapeDtypeStructs: trace without running.

    Returns the ClosedJaxpr.  kwargs are static (baked into the trace),
    matching how the registry impls take n_bins/n_leaves etc."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*avals)
