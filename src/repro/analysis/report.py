"""Findings, suppressions and the contract-report artifact.

The contract checker (`repro.analysis.checker`) reduces every lint pass
to a flat list of `Finding`s.  A finding is addressed to the registry
implementation it was raised against, so declared suppressions — the
`suppressions=("rule: reason", ...)` metadata on `registry.register` —
can be matched mechanically: a finding whose rule appears in its impl's
suppression list is demoted to *suppressed* (reported, never fatal),
and a suppression that matches no finding at all is itself a finding
(`unused-suppression`), so stale exceptions cannot linger.

The JSON artifact (results/analysis/contract-report.json) is committed
like the results/perf trajectories: deterministic (no timestamps), so a
diff shows exactly which claims changed verdict.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Iterable, Optional

# Rule catalog — docs/analysis.md documents each in prose.
RULES: dict[str, str] = {
    "widening": "uint8 bins/comparison panel promoted to a wider dtype "
                "outside the sanctioned dot_general/gather contract",
    "int-pipeline": "bitpacked leaf-index pipeline converted an integer "
                    "value to float before the leaf gather",
    "vmem-model": "traced kernel working set exceeds its kernels.tuning "
                  "footprint model (the block tuner would mis-plan)",
    "vmem-budget": "traced kernel working set exceeds VMEM_BUDGET",
    "capability": "registry capability claim diverges from behavior "
                  "(declared combo fails to trace, or an undeclared "
                  "combo is not rejected by resolve)",
    "transfer": "plan entry stages a host<->device transfer or a large "
                "non-donated buffer",
    "shard-parity": "sharded plan entry gathers the row-sharded panel "
                    "back onto one shard (an all_gather/all_to_all in "
                    "the jaxpr breaks the weak-scaling contract)",
    "retrace": "plan entry admits avals (weak types, x64 leaks) that "
               "would retrace beyond the compile contract",
    "chunk-model": "best_chunk_rows plans a chunk whose working set "
                   "breaks CHUNK_BUDGET_BYTES or the pow2/clamp contract",
    "layout-cost": "layout_costs diverges from the bytes actually "
                   "lowered (the layout selector would mis-rank)",
    "unused-suppression": "declared suppression matched no finding",
    "trace-error": "internal: a lint pass itself failed on a trace",
}


@dataclasses.dataclass
class Finding:
    """One rule violation, addressed to a registry implementation
    (`op:impl` — plan-level findings use op="plan", impl=entry name)."""
    rule: str
    op: str
    impl: str
    layout: str = ""
    dtype: str = ""
    message: str = ""
    suppressed: bool = False

    @property
    def cell(self) -> str:
        tail = "/".join(p for p in (self.layout, self.dtype) if p)
        return f"{self.op}:{self.impl}" + (f" [{tail}]" if tail else "")

    def format(self) -> str:
        mark = "suppressed" if self.suppressed else "FAIL"
        return f"{mark:10s} {self.rule:18s} {self.cell}: {self.message}"

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "Finding":
        return cls(**d)


def parse_suppressions(entries: Iterable[str]) -> dict[str, str]:
    """("rule: reason", ...) -> {rule: reason}.  A bare "rule" (no
    colon) suppresses with an empty reason; unknown rule names raise —
    a typo in a suppression must not silently disable nothing."""
    out: dict[str, str] = {}
    for entry in entries:
        rule, _, reason = entry.partition(":")
        rule = rule.strip()
        if rule not in RULES:
            raise ValueError(f"unknown suppression rule {rule!r} in "
                             f"{entry!r}; known: {sorted(RULES)}")
        out[rule] = reason.strip()
    return out


def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3]


def default_report_path() -> pathlib.Path:
    return _repo_root() / "results" / "analysis" / "contract-report.json"


@dataclasses.dataclass
class ContractReport:
    """The checker's full output: findings + coverage counters + the
    per-impl verdict map the registry's `verified` column displays."""
    findings: list[Finding]
    cells: int = 0                 # capability-matrix cells enumerated
    traces: int = 0                # unique abstract traces linted
    trace_cache_hits: int = 0      # cells served from the trace cache
    kernels: int = 0               # pallas kernel bodies audited
    verified: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def unsuppressed(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> list[Finding]:
        return [f for f in self.findings if f.suppressed]

    @property
    def ok(self) -> bool:
        return not self.unsuppressed

    def to_json(self) -> dict[str, Any]:
        return {
            "schema": 1,
            "cells": self.cells,
            "traces": self.traces,
            "trace_cache_hits": self.trace_cache_hits,
            "kernels": self.kernels,
            "unsuppressed_count": len(self.unsuppressed),
            "suppressed_count": len(self.suppressed),
            "verified": dict(sorted(self.verified.items())),
            "findings": [f.to_json() for f in self.findings],
        }

    def save(self, path: Optional[pathlib.Path] = None) -> pathlib.Path:
        path = pathlib.Path(path) if path else default_report_path()
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2,
                                   sort_keys=False) + "\n",
                        encoding="utf-8")
        return path

    @classmethod
    def load(cls, path: Optional[pathlib.Path] = None) -> "ContractReport":
        path = pathlib.Path(path) if path else default_report_path()
        d = json.loads(path.read_text(encoding="utf-8"))
        return cls(findings=[Finding.from_json(f) for f in d["findings"]],
                   cells=d.get("cells", 0), traces=d.get("traces", 0),
                   trace_cache_hits=d.get("trace_cache_hits", 0),
                   kernels=d.get("kernels", 0),
                   verified=dict(d.get("verified", {})))

    def format(self, verbose: bool = False) -> str:
        lines = [
            f"contract check: {self.cells} cells, {self.traces} traces "
            f"({self.trace_cache_hits} cache hits), "
            f"{self.kernels} pallas kernels audited",
            f"findings: {len(self.unsuppressed)} unsuppressed, "
            f"{len(self.suppressed)} suppressed",
        ]
        shown = self.findings if verbose else self.unsuppressed
        lines += ["  " + f.format() for f in shown]
        if not verbose and self.suppressed:
            lines.append(f"  ({len(self.suppressed)} suppressed findings "
                         "hidden; -v shows them)")
        fails = sorted(k for k, v in self.verified.items() if v == "FAIL")
        if fails:
            lines.append("failing impls: " + ", ".join(fails))
        lines.append("RESULT: " + ("OK" if self.ok else "FAIL"))
        return "\n".join(lines)
