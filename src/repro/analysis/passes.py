"""The lint passes: rules applied to abstract traces.

Every pass takes a `matrix.Cell` (or a plan-entry name) plus a
ClosedJaxpr from `jax.make_jaxpr` and returns `report.Finding`s.
Nothing here executes traced code.

The paper's core observation is that the C++ compiler performs *no
automatic vectorization* of the CatBoost scalar loop — the win had to
be engineered by hand and can silently rot.  These passes are the
JAX-side analog of that discipline: the uint8 bin stream, the integer
bit-plane pipeline and the VMEM working set are engineered contracts,
and XLA will happily trace a widened/promoted version that still
returns correct values while quietly quadrupling the panel the kernel
streams.  A lint at the jaxpr level catches the rot before a benchmark
has to.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np
from jax._src import core as jax_core

from repro.analysis import jaxpr_tools as jt
from repro.analysis.matrix import Cell
from repro.analysis.report import Finding

# Sinks allowed to consume a widened uint8 panel: the MXU contract.
# dot_general — the one-hot gather matmul requires f32 operands (exact
# for bin ids <= 255); gather — but only as the *index* operand: a
# gather indexed by a widened value never materializes a widened panel
# per element, while gathering FROM a widened data panel means that
# panel is resident wide (the operand-position check below).
SANCTIONED_SINKS = frozenset({"dot_general", "gather"})


def _sanctioned(eqn: Any, var: Any) -> bool:
    name = eqn.primitive.name
    if name == "dot_general":
        return True
    if name == "gather":
        # invars[0] is the data operand; widened data panels are the
        # violation, widened indices are fine
        return var is not eqn.invars[0]
    return False

# Working-set estimate vs footprint model tolerance.  The estimate is
# a pessimistic liveness bound (XLA may fuse intermediates away); the
# models deliberately count only the structural panels.  1.5x absorbs
# bookkeeping values (iota, masks) without absorbing a dtype widening,
# which is >= 2x on the dominant panel by construction.
VMEM_SLACK = 1.5

# Plan-entry buffers above this that are not donated get flagged: at
# serving batch sizes nothing legitimate is this large except the
# input panel itself, which the plan donates.
LARGE_BUFFER_BYTES = 64 * 1024 * 1024


def _finding(cell: Cell, rule: str, msg: str) -> Finding:
    return Finding(rule=rule, op=cell.op, impl=cell.impl,
                   layout=cell.layout, dtype=cell.dtype, message=msg)


# --------------------------------------------------------------------------
# Pass 1a: uint8 widening discipline
# --------------------------------------------------------------------------
def widening_lint(cell: Cell, closed: Any) -> list[Finding]:
    """Flag uint8 panels promoted to wide dtypes outside the MXU/gather
    contract.

    For every `convert_element_type` whose operand is uint8 and whose
    target itemsize exceeds 1, the widened value's terminal consumers
    (through transpose/reshape/... moves) must all be sanctioned sinks.
    Any other consumer — a compare, an add, a store — means a widened
    panel is live element-wise, which is exactly the PR-7 histogram bug
    (uint8 pool bins promoted to an int32 segment-id panel) and the
    4x-VMEM failure mode the uint8 stream exists to avoid.

    The walk follows the widened value into call-like sub-jaxprs (jnp
    wraps `take`/`einsum` bodies in named pjits); loop/branch eqns and
    values escaping through scope outvars are boundaries, not
    violations — each (sub)jaxpr is also linted as its own scope.
    """
    if cell.dtype != "uint8":
        return []
    out: list[Finding] = []
    for jaxpr in jt.iter_jaxprs(closed.jaxpr):
        consumers = jt.consumers_map(jaxpr)
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            operand = eqn.invars[0]
            src = jt.unwrap_aval(getattr(operand, "aval", None))
            dst = eqn.params.get("new_dtype")
            if src is None or dst is None:
                continue
            if np.dtype(src.dtype) != np.dtype(np.uint8):
                continue
            if np.dtype(dst).itemsize <= 1:
                continue
            outvar = eqn.outvars[0]
            bad = [(t, v) for t, v in jt.terminal_consumers(
                       jaxpr, outvar, consumers)
                   if not jt.eqn_subjaxprs(t) and not _sanctioned(t, v)]
            if bad:
                sinks = sorted({t.primitive.name for t, _ in bad})
                out.append(_finding(
                    cell, "widening",
                    f"uint8 {jt.aval_short(src)} widened to "
                    f"{np.dtype(dst).name} and consumed by "
                    f"{'/'.join(sinks)} (sanctioned sinks: "
                    f"{'/'.join(sorted(SANCTIONED_SINKS))})"))
    return out


# --------------------------------------------------------------------------
# Pass 1b: bitpacked integer-pipeline discipline
# --------------------------------------------------------------------------
def integer_pipeline_lint(cell: Cell, closed: Any) -> list[Finding]:
    """The bitpacked layout's reason to exist is an index pipeline with
    no float excursion (the paper's vmsgeu/bit-plane loop): flag any
    integer->float conversion in a bitpacked leaf_index/fused trace.
    bool->float is allowed — that is the leaf-gather one-hot being
    built from a comparison mask, downstream of index assembly."""
    if cell.layout != "bitpacked" \
            or cell.op not in ("leaf_index", "fused_predict"):
        return []
    out: list[Finding] = []
    for jaxpr in jt.iter_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            src = jt.unwrap_aval(getattr(eqn.invars[0], "aval", None))
            dst = eqn.params.get("new_dtype")
            if src is None or dst is None:
                continue
            sd, dd = np.dtype(src.dtype), np.dtype(dst)
            if sd.kind in "iu" and dd.kind == "f":
                out.append(_finding(
                    cell, "int-pipeline",
                    f"{sd.name} {jt.aval_short(src)} converted to "
                    f"{dd.name} inside the bitpacked pipeline"))
    return out


# --------------------------------------------------------------------------
# Pass 2: VMEM working-set audit
# --------------------------------------------------------------------------
def _model_bytes(cell: Cell, refs: list[Any]) -> Optional[int]:
    """The kernels.tuning footprint model for this kernel, with dims
    recovered from the kernel body's BLOCK-shaped ref avals.  None for
    kernels without a model (l2sq) — those get the budget check only."""
    from repro.kernels import tuning

    def ib(a):  # itemsize
        return np.dtype(a.dtype).itemsize

    if cell.op == "binarize":
        x, borders, out = refs
        bn, bf = x.shape
        return tuning.binarize_footprint(bn, bf, borders.shape[0],
                                         bins_bytes=ib(out))
    if cell.op == "leaf_index":
        if cell.layout == "depth_major":
            bins, onehot, _sb, _pow2, out = refs
            bt, d, f = onehot.shape
            return tuning.leaf_index_footprint(bins.shape[0], bt, f, d,
                                               bins_bytes=ib(bins))
        bins, sf, _sb, out = refs
        if cell.layout == "bitpacked":
            d, bt = sf.shape
            return tuning.leaf_index_footprint(
                bins.shape[0], bt, bins.shape[1], d,
                bins_bytes=ib(bins), gather="bitplane")
        bt, d = sf.shape
        return tuning.leaf_index_footprint(bins.shape[0], bt,
                                           bins.shape[1], d,
                                           bins_bytes=ib(bins))
    if cell.op == "leaf_gather":
        idx, lv, _out = refs
        bt, l, c = lv.shape
        return tuning.leaf_gather_footprint(idx.shape[0], bt, l, c)
    if cell.op == "fused_predict":
        if cell.layout == "depth_major":
            x, borders, onehot, _sb, _pow2, lv, _out, scratch = refs
            bt, d, f = onehot.shape
        else:
            x, borders, sf, _sb, lv, _out, scratch = refs
            if cell.layout == "bitpacked":
                d, bt = sf.shape
            else:
                bt, d = sf.shape
            f = x.shape[1]
        gather = "bitplane" if cell.layout == "bitpacked" else "mxu"
        _, l, c = lv.shape
        return tuning.fused_footprint(x.shape[0], bt, f, d, l, c,
                                      borders.shape[0],
                                      bins_bytes=ib(scratch),
                                      gather=gather)
    if cell.op == "histogram":
        bins, _leaf, g, out = refs
        bf, bn = bins.shape
        s = out.shape[1]                   # n_leaves * n_bins, fused dim
        return tuning.hist_footprint(bf, bn, 1, s, g.shape[1],
                                     bins_bytes=ib(bins))
    return None  # l2sq: no footprint model — budget check only


def vmem_audit(cell: Cell, closed: Any) -> tuple[list[Finding], int]:
    """Per-pallas-kernel working-set estimate (resident ref blocks +
    peak live interior values) vs the VMEM budget and the op's tuning
    footprint model.  Returns (findings, kernels_audited)."""
    from repro.kernels import tuning

    out: list[Finding] = []
    calls = jt.find_pallas_calls(closed.jaxpr)
    for eqn in calls:
        refs = jt.pallas_ref_avals(eqn)
        body = jt.pallas_kernel_jaxpr(eqn)
        est = sum(jt.aval_bytes(a) for a in refs) \
            + jt.peak_live_bytes(body, include_invars=False)
        if est > tuning.VMEM_BUDGET:
            out.append(_finding(
                cell, "vmem-budget",
                f"estimated working set {est} B exceeds VMEM_BUDGET "
                f"{tuning.VMEM_BUDGET} B"))
        try:
            model = _model_bytes(cell, refs)
        except (ValueError, IndexError) as e:
            out.append(_finding(
                cell, "trace-error",
                f"footprint-model dim recovery failed on refs "
                f"{[jt.aval_short(a) for a in refs]}: {e}"))
            continue
        if model is not None and est > VMEM_SLACK * model:
            out.append(_finding(
                cell, "vmem-model",
                f"estimated working set {est} B is "
                f"{est / model:.2f}x the tuning footprint model "
                f"({model} B; slack {VMEM_SLACK}x) — the block tuner "
                "would mis-plan this kernel"))
    return out, len(calls)


# --------------------------------------------------------------------------
# Pass 3: plan-entry transfer/retrace lints
# --------------------------------------------------------------------------
def entry_findings(name: str, closed: Any) -> list[Finding]:
    """Lint one Predictor plan entry's abstract trace.

    transfer: explicit `device_put` staging inside a jitted entry, or a
    large buffer entering a pjit region with donation disabled.
    retrace: weakly-typed or x64 avals at the entry boundary — shapes
    the ≤2-shapes compile contract does not cover, so every call with a
    fresh Python scalar would silently retrace."""
    cell = Cell("plan", name, "", "")
    out: list[Finding] = []
    for aval in list(closed.in_avals) + [v.aval for v in
                                         closed.jaxpr.constvars]:
        if getattr(aval, "weak_type", False):
            out.append(_finding(
                cell, "retrace",
                f"weakly-typed boundary aval {jt.aval_short(aval)} — "
                "each distinct Python scalar retraces"))
        dt = getattr(jt.unwrap_aval(aval), "dtype", None)
        if dt is not None and np.dtype(dt).itemsize == 8:
            out.append(_finding(
                cell, "retrace",
                f"x64 boundary aval {jt.aval_short(aval)} leaks into "
                "the plan (the serve path pins float32/int32)"))
    for jaxpr in jt.iter_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "device_put":
                out.append(_finding(
                    cell, "transfer",
                    "device_put staged inside the traced entry — "
                    "host->device transfer on every call"))
            elif eqn.primitive.name == "pjit":
                donated = eqn.params.get("donated_invars", ())
                for v, don in zip(eqn.invars, donated):
                    nbytes = jt.aval_bytes(getattr(v, "aval", None))
                    if nbytes > LARGE_BUFFER_BYTES and not don:
                        out.append(_finding(
                            cell, "transfer",
                            f"{nbytes} B buffer enters jitted region "
                            "without donation — doubles peak residency"))
    return out


# --------------------------------------------------------------------------
# Pass 3b: sharded-entry collective discipline (shard-parity)
# --------------------------------------------------------------------------
# Collectives that re-materialize a sharded value on every shard.  The
# row-sharded predict path owes its weak scaling to each shard touching
# only its own (N/K, F) panel; an all_gather in the jaxpr means some
# operation pulled the full panel back — O(N) bytes and O(N) work per
# shard, i.e. no scaling at all.  `psum` is *expected* (tree-sharded
# leaf-sum reduction) and reduces, never gathers, so it is not listed.
GATHERING_COLLECTIVES = frozenset({
    "all_gather", "all_gather_invariant", "all_to_all", "pgather"})


def sharded_entry_findings(name: str, closed: Any) -> list[Finding]:
    """Lint one sharded plan entry's abstract trace: no gathering
    collective may appear anywhere in it (sub-jaxprs included — the
    shard_map body is a sub-jaxpr of the traced entry)."""
    cell = Cell("plan", name, "", "")
    out: list[Finding] = []
    for jaxpr in jt.iter_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in GATHERING_COLLECTIVES:
                out.append(_finding(
                    cell, "shard-parity",
                    f"{eqn.primitive.name} of "
                    f"{jt.aval_short(getattr(eqn.invars[0], 'aval', None))}"
                    " inside a row-sharded entry — the bins panel must "
                    "stay shard-local"))
    return out


def shard_parity_findings(batch_sizes: Any = (8,)) -> list[Finding]:
    """Abstract-trace the sharded entry points of one plan per layout
    over a device-free `AbstractMesh` and lint each trace for gathering
    collectives.  Also re-asserts the no-compile contract: the walk
    must not tick the plans' trace counters (an AbstractMesh cannot be
    compiled against, so a tick means a sharded entry escaped the
    abstract path)."""
    from repro.compat import abstract_mesh
    from repro.core.predictor import Predictor
    from repro.analysis.matrix import canonical_ensemble

    mesh = abstract_mesh((4,), ("data",))
    sizes = [n for n in batch_sizes if n % 4 == 0] or [8]
    ens, _ = canonical_ensemble()
    out: list[Finding] = []
    for lay in ("soa", "depth_major", "depth_grouped", "bitpacked"):
        plan = Predictor.build(ens, strategy="staged", layout=lay)
        entries = plan.trace_entries(
            batch_sizes=sizes, mesh=mesh,
            entries=("sharded_raw", "sharded_raw_pool"))
        for label, closed in entries.items():
            out += sharded_entry_findings(f"{lay}:{label}", closed)
        if plan.stats["total_traces"]:
            out.append(Finding(
                rule="trace-error", op="plan", impl=f"{lay}:sharded",
                message="sharded trace walk compiled — it must stay "
                        "abstract (AbstractMesh)"))
    return out


# --------------------------------------------------------------------------
# Pass 4: tuning-model consistency (chunk planner, layout selector)
# --------------------------------------------------------------------------
def chunk_model_findings() -> list[Finding]:
    """`best_chunk_rows` must honor its own documented contract for
    representative model shapes: pow2 rows in [MIN, MAX], working set
    within budget unless pinned at the MIN floor, small datasets capped
    at the first covering pow2."""
    from repro.kernels import tuning

    cell = Cell("tuning", "best_chunk_rows", "", "")
    out: list[Finding] = []
    shapes = [  # (n_features, n_outputs, kwargs)
        (10, 1, {}),
        (54, 7, dict(n_borders=254, n_trees=100, n_leaves=64)),
        (784, 10, dict(n_borders=255, n_trees=500, n_leaves=64)),
        (2000, 1, dict(n_borders=255, n_trees=1000, n_leaves=64)),
    ]
    for f, c, kw in shapes:
        rows = tuning.best_chunk_rows(f, c, **kw)
        per_row = tuning.chunk_row_bytes(f, c, **kw)
        desc = f"F={f} C={c} {kw or ''}".strip()
        if rows & (rows - 1) or not (tuning.MIN_CHUNK_ROWS <= rows
                                     <= tuning.MAX_CHUNK_ROWS):
            out.append(_finding(
                cell, "chunk-model",
                f"{desc}: rows={rows} not a pow2 in "
                f"[{tuning.MIN_CHUNK_ROWS}, {tuning.MAX_CHUNK_ROWS}]"))
        elif rows * per_row > tuning.CHUNK_BUDGET_BYTES \
                and rows > tuning.MIN_CHUNK_ROWS:
            out.append(_finding(
                cell, "chunk-model",
                f"{desc}: rows={rows} x {per_row} B/row = "
                f"{rows * per_row} B exceeds CHUNK_BUDGET_BYTES "
                f"{tuning.CHUNK_BUDGET_BYTES} above the MIN floor"))
        capped = tuning.best_chunk_rows(f, c, n_rows=1000, **kw)
        cover = tuning.MIN_CHUNK_ROWS
        while cover < 1000:
            cover *= 2
        if capped > max(cover, tuning.MIN_CHUNK_ROWS):
            out.append(_finding(
                cell, "chunk-model",
                f"{desc}: n_rows=1000 cap ignored (rows={capped})"))
    return out


def layout_cost_findings() -> list[Finding]:
    """`tuning.layout_costs` (what `best_layout` ranks on) vs the bytes
    each layout actually lowers for a canonical mixed-depth ensemble at
    lane-aligned dims.  Loose bounds: the model is pre-padding, the
    lowering pads groups/trees to block multiples and may narrow
    bitpacked planes to uint8 — a model off by more than 4x either way
    would mis-rank layouts."""
    from repro.core import layout as layout_mod
    from repro.kernels import tuning
    from repro.analysis.matrix import canonical_ensemble

    cell = Cell("tuning", "layout_costs", "", "")
    ens, true_depths = canonical_ensemble()
    costs = tuning.layout_costs(true_depths, ens.n_outputs,
                                ens.n_features)
    lowered = {lay: layout_mod.lower(ens, lay, backend="ref")
               for lay in ("soa", "depth_grouped", "depth_major",
                           "bitpacked")}
    actual = {
        "soa_leaf_bytes": lowered["soa"].leaf_table_bytes(),
        "depth_grouped_leaf_bytes":
            lowered["depth_grouped"].leaf_table_bytes(),
        "depth_major_onehot_bytes": lowered["depth_major"].onehot_bytes(),
        "bitpacked_leaf_bytes": lowered["bitpacked"].leaf_table_bytes(),
        "bitpacked_plane_bytes": lowered["bitpacked"].plane_bytes(),
    }
    out: list[Finding] = []
    for key, model in costs.items():
        got = actual[key]
        if not (model / 4 <= got <= model * 4 + 65536):
            out.append(_finding(
                cell, "layout-cost",
                f"{key}: model {model} B vs lowered {got} B — "
                "outside the 4x mis-rank bound"))
    return out
