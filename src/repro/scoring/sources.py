"""Row sources: how the bulk scorer reads datasets it cannot hold.

A `RowSource` is anything with ``n_rows``, ``n_features`` and
``read(start, stop) -> (stop-start, F) float32`` — random access by row
range, so the scorer can cut fixed-shape chunks and resume from any
chunk index without replaying the stream.  The contract is deliberately
a duck protocol, not a base class: a production loader (parquet shards,
a feature store scan) only has to answer range reads.

Peak host memory for every source here is O(read span), never
O(dataset): `NpyMemmapSource` pages rows in through the OS,
`SyntheticSource` tiles a small base dataset virtually to arbitrary row
counts (the out-of-core test rig — a 100M-row sweep costs the memory of
the base dataset plus one chunk).
"""
from __future__ import annotations

import pathlib
from typing import Iterator, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class RowSource(Protocol):
    """Range-readable float feature matrix (see module docstring)."""

    @property
    def n_rows(self) -> int: ...

    @property
    def n_features(self) -> int: ...

    def read(self, start: int, stop: int) -> np.ndarray: ...


def _check_span(source, start: int, stop: int) -> None:
    if not 0 <= start <= stop <= source.n_rows:
        raise ValueError(f"row span [{start}, {stop}) outside "
                         f"[0, {source.n_rows})")


def iter_chunks(source: RowSource, chunk_rows: int, *,
                start_row: int = 0) -> Iterator[np.ndarray]:
    """Plain chunk iterator over a source — the adapter the chunked
    quantize helpers (`quantize_pool_chunked`, `compute_borders_chunked`)
    consume."""
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    for s in range(start_row, source.n_rows, chunk_rows):
        yield source.read(s, min(s + chunk_rows, source.n_rows))


class ArraySource:
    """In-memory (or caller-managed memmap) feature matrix."""

    def __init__(self, x: np.ndarray):
        x = np.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"ArraySource needs a (N, F) matrix, got "
                             f"shape {x.shape}")
        self._x = x

    @property
    def n_rows(self) -> int:
        return int(self._x.shape[0])

    @property
    def n_features(self) -> int:
        return int(self._x.shape[1])

    def read(self, start: int, stop: int) -> np.ndarray:
        _check_span(self, start, stop)
        return np.asarray(self._x[start:stop], np.float32)

    def __repr__(self) -> str:
        return f"<ArraySource {self.n_rows}x{self.n_features}>"


class NpyMemmapSource:
    """A ``.npy`` feature matrix paged in by the OS, never fully loaded.

    The on-disk dtype is served as float32 per chunk (`read` copies the
    requested span only).  Pair with `repro.scoring.sinks.NpySink` for a
    disk-to-disk rescore whose host footprint is O(chunk).
    """

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._x = np.load(self.path, mmap_mode="r")
        if self._x.ndim != 2:
            raise ValueError(f"{self.path}: expected a (N, F) matrix, got "
                             f"shape {self._x.shape}")

    @property
    def n_rows(self) -> int:
        return int(self._x.shape[0])

    @property
    def n_features(self) -> int:
        return int(self._x.shape[1])

    def read(self, start: int, stop: int) -> np.ndarray:
        _check_span(self, start, stop)
        # np.asarray on a memmap slice copies exactly the span read
        return np.asarray(self._x[start:stop], np.float32)

    def __repr__(self) -> str:
        return (f"<NpyMemmapSource {self.path.name} "
                f"{self.n_rows}x{self.n_features}>")


class SyntheticSource:
    """A `repro.data.synthetic` dataset served as a scoring source,
    virtually tiled to out-of-core row counts.

    ``repeat=k`` serves the base split k times over (row i maps to base
    row ``i % base_rows``), so ``SyntheticSource("covertype", scale=0.1,
    repeat=20)`` is a ~280k-row sweep that costs the memory of the
    14k-row base — the rig the scoring benchmark and the paper's
    ApplyModelMulti-style dataset sweeps run on.  ``split`` picks which
    side of the train/test cut to serve ("test", "train" or "all").
    """

    def __init__(self, name: str, *, scale: float = 1.0,
                 seed: int | None = None, split: str = "test",
                 repeat: int = 1):
        from repro.data import synthetic

        if repeat < 1:
            raise ValueError(f"repeat must be >= 1, got {repeat}")
        if split not in ("train", "test", "all"):
            raise ValueError(f"split must be train|test|all, got {split!r}")
        ds = synthetic.load(name, scale=scale, seed=seed)
        if split == "train":
            base = ds.x_train
        elif split == "test":
            base = ds.x_test
        else:
            base = np.concatenate([ds.x_train, ds.x_test], axis=0)
        if base.shape[0] == 0:
            raise ValueError(f"{name} at scale={scale} has no {split} rows")
        self.name = name
        self.dataset = ds
        self.repeat = repeat
        self._base = np.asarray(base, np.float32)

    @property
    def base_rows(self) -> int:
        return int(self._base.shape[0])

    @property
    def n_rows(self) -> int:
        return self.base_rows * self.repeat

    @property
    def n_features(self) -> int:
        return int(self._base.shape[1])

    def read(self, start: int, stop: int) -> np.ndarray:
        _check_span(self, start, stop)
        if stop - start == 0:
            return np.zeros((0, self.n_features), np.float32)
        idx = np.arange(start, stop) % self.base_rows
        return self._base[idx]

    def __repr__(self) -> str:
        return (f"<SyntheticSource {self.name} {self.n_rows}x"
                f"{self.n_features} (base {self.base_rows}, "
                f"repeat {self.repeat})>")
