"""Bulk scoring subsystem: streaming out-of-core dataset apply.

The offline counterpart of `repro.serving` — apply one or many compiled
`Predictor` plans to datasets that never fit in one batch (or in
memory), at device speed and O(chunk) host memory.  See
docs/scoring.md for the architecture and the memory/compile contracts.

    from repro.scoring import (BulkScorer, ScoreConfig,
                               NpyMemmapSource, NpySink)

    result = BulkScorer(plan, ScoreConfig(output="raw")).score(
        NpyMemmapSource("features.npy"), NpySink("scores.npy"))
"""
from repro.scoring import scorer, sinks, sources  # noqa: F401
from repro.scoring.scorer import (BulkScorer, ChunkSpan,  # noqa: F401
                                  ScoreConfig, ScoreResult,
                                  ScoringMetrics, plan_chunks)
from repro.scoring.sinks import (ArraySink, NpySink,  # noqa: F401
                                 ScoreSink, StatsSink, TopKSink)
from repro.scoring.sources import (ArraySource, NpyMemmapSource,  # noqa: F401
                                   RowSource, SyntheticSource,
                                   iter_chunks)
