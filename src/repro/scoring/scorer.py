"""Streaming out-of-core bulk scoring: apply compiled Predictor plans
to arbitrarily large datasets at device speed.

This is the offline half of the serving stack — the paper's headline
workload (`ApplyModelMulti` sweeping whole datasets through a prepared
ensemble) as a production "nightly rescore" subsystem.  The online path
(PRs 1-4) binds throughput to request traffic; `BulkScorer` binds it to
the hardware:

  * **one fixed chunk shape** — the planner picks a single power-of-two
    chunk (`kernels.tuning.best_chunk_rows`, host-memory-budgeted) and
    bucket-pads the tail chunk via `QuantizedPool.pad_rows`, so the
    whole run traces at most 2 XLA shapes no matter the dataset size;
  * **O(chunk) host memory** — rows are range-read from a `RowSource`
    and quantized per chunk (`quantize_pool` on the chunk, never the
    dataset), scores stream row-addressed into a `ScoreSink`; nothing
    dataset-sized is ever resident;
  * **pipelined quantization** — a `data.pipeline.Prefetcher` worker
    reads + binarizes chunk k+1 while the main thread's jax dispatch
    scores chunk k (device compute is async; the host sync point is the
    sink write);
  * **multi-model fan-out** — K plans score every chunk; plans sharing
    a quantization schema (`borders_fingerprint`) share one pool per
    chunk, the offline analogue of `ModelRegistry.predict_multi`;
  * **resume by chunk index** — chunk boundaries are a pure function of
    (n_rows, chunk_rows), so an interrupted run restarts at
    ``resume_from=k`` and row-addressed sinks (`NpySink(resume=True)`)
    keep the rows already scored.

    cfg    = ScoreConfig(output="proba")
    scorer = BulkScorer(plan, cfg)           # or {"name": plan, ...}
    result = scorer.score(NpyMemmapSource("x.npy"), NpySink("y.npy"))
    result.metrics["rows_per_s"]             # comparable to ServerMetrics
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Mapping, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.predictor import (Predictor, classify_from_raw,
                                  proba_from_raw)
from repro.core.quantize import MAX_BINS
from repro.data.pipeline import Prefetcher
from repro.kernels import tuning
from repro.obs.trace import get_tracer
from repro.scoring.sinks import ArraySink, ScoreSink
from repro.scoring.sources import RowSource
from repro.serving.batching import bucket_for, pad_rows, pow2_buckets
from repro.serving.metrics import PercentileReservoir

_OUTPUTS = ("raw", "proba", "classify")

_TRACER = get_tracer()


@dataclasses.dataclass(frozen=True)
class ScoreConfig:
    """Bulk-scoring configuration.

      chunk_rows      fixed chunk shape; 0 = auto from
                      `tuning.best_chunk_rows` (host-budgeted pow2)
      output          which plan entry scores: raw | proba | classify
                      (classify lands in sinks as an (N, 1) panel)
      prefetch_depth  chunks in flight ahead of the scorer (the
                      Prefetcher queue bound); 0 = synchronous, no
                      worker thread
      prequantize     binarize each chunk on the prefetch worker and
                      score uint8 pools (binarize leaves the critical
                      path entirely); plans whose borders exceed the
                      uint8 cap fall back to the float path per schema
      chunk_budget_bytes   host bytes one in-flight chunk may cost
                      (feeds the auto chunk planner)
    """
    chunk_rows: int = 0
    output: str = "proba"
    prefetch_depth: int = 2
    prequantize: bool = True
    chunk_budget_bytes: int = tuning.CHUNK_BUDGET_BYTES

    def __post_init__(self):
        if self.output not in _OUTPUTS:
            raise ValueError(f"output must be one of {_OUTPUTS}, "
                             f"got {self.output!r}")
        if not isinstance(self.chunk_rows, int) or self.chunk_rows < 0:
            raise ValueError(f"chunk_rows must be an int >= 0, "
                             f"got {self.chunk_rows!r}")
        if not isinstance(self.prefetch_depth, int) \
                or self.prefetch_depth < 0:
            raise ValueError(f"prefetch_depth must be an int >= 0, "
                             f"got {self.prefetch_depth!r}")
        if self.chunk_budget_bytes < 1:
            raise ValueError("chunk_budget_bytes must be positive")


class ScoringMetrics:
    """Offline counterpart of `serving.metrics.ServerMetrics`: rows/s,
    the quantize-vs-score wall split, chunk count, XLA compiles, and
    per-chunk latency percentiles through the same
    `PercentileReservoir` — so online and offline dashboards report
    comparable units (`rows_per_s` appears in both snapshots)."""

    def __init__(self, name: str = "bulk"):
        self.name = name
        self._lock = threading.Lock()
        self.rows = 0
        self.padded_rows = 0
        self.chunks = 0
        self.quantize_s = 0.0
        self.score_s = 0.0
        self.wall_s = 0.0
        self.compiles = 0
        self.resumed_from = 0
        self._chunk_lat = PercentileReservoir()
        self._t0: Optional[float] = None
        # interval-rate markers: state of the previous snapshot() call
        self._prev_t = time.perf_counter()
        self._prev_rows = 0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> None:
        if self._t0 is not None:
            self.wall_s += time.perf_counter() - self._t0
            self._t0 = None

    def reset(self) -> None:
        """Zero all counters and restart the rate clocks (the name
        survives).  A running interval (`start()` without `stop()`)
        restarts from now."""
        with self._lock:
            self.rows = self.padded_rows = self.chunks = 0
            self.quantize_s = self.score_s = self.wall_s = 0.0
            self.compiles = self.resumed_from = 0
            self._chunk_lat = PercentileReservoir()
            now = time.perf_counter()
            if self._t0 is not None:
                self._t0 = now
            self._prev_t = now
            self._prev_rows = 0

    def note_quantize(self, seconds: float) -> None:
        """Called from the prefetch worker thread."""
        with self._lock:
            self.quantize_s += seconds

    def note_chunk(self, n_valid: int, n_padded: int,
                   score_seconds: float) -> None:
        with self._lock:
            self.chunks += 1
            self.rows += n_valid
            self.padded_rows += n_padded - n_valid
            self.score_s += score_seconds
            self._chunk_lat.add(score_seconds)

    def _locked_snapshot(self, advance_interval: bool) -> dict[str, Any]:
        """Build the snapshot dict; caller holds self._lock.

        `wall_s` includes the in-progress interval when called between
        `start()` and `stop()`, so a mid-run snapshot's `rows_per_s` is
        live, not the value frozen at the last `stop()`."""
        now = time.perf_counter()
        wall = self.wall_s + (now - self._t0
                              if self._t0 is not None else 0.0)
        idt = max(now - self._prev_t, 1e-9)
        busy = self.quantize_s + self.score_s
        pad_total = self.rows + self.padded_rows
        snap = {
            "name": self.name,
            "rows": self.rows,
            "chunks": self.chunks,
            "compiles": self.compiles,
            "resumed_from": self.resumed_from,
            "wall_s": wall,
            "rows_per_s": self.rows / wall if wall else 0.0,
            "interval_rows_per_s": (self.rows - self._prev_rows) / idt,
            "quantize_s": self.quantize_s,
            "score_s": self.score_s,
            # note quantize overlaps score on the worker thread, so
            # the fractions describe where the work went, not wall
            "quantize_frac": self.quantize_s / busy if busy else 0.0,
            "chunk_p50_ms": self._chunk_lat.percentile(50) * 1e3,
            "chunk_p99_ms": self._chunk_lat.percentile(99) * 1e3,
            "pad_overhead": (self.padded_rows / pad_total
                             if pad_total else 0.0),
        }
        if advance_interval:
            self._prev_t = now
            self._prev_rows = self.rows
        return snap

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return self._locked_snapshot(advance_interval=True)

    @staticmethod
    def merge(parts: list["ScoringMetrics"]) -> dict[str, Any]:
        """One fleet view over per-shard/per-worker bulk metrics.

        Mirrors `ServerMetrics.merge`: counts, compile totals and the
        throughput rates sum (K workers at X rows/s really move K*X
        fleet rows/s), wall is the slowest part (shards run
        concurrently), and chunk-latency percentiles come from the
        merged reservoirs, not averaged per-shard percentiles."""
        if not parts:
            raise ValueError("ScoringMetrics.merge needs at least one "
                             "part")
        # one locked pass per part: snapshot fields and the reservoir
        # come from the same instant (and the non-advancing read leaves
        # each part's interval window to its own poller)
        snaps = []
        lat = PercentileReservoir()
        pad_rows = rows = 0
        for p in parts:
            with p._lock:
                snaps.append(p._locked_snapshot(advance_interval=False))
                lat.merge(p._chunk_lat)
                pad_rows += p.padded_rows
                rows += p.rows
        busy = (sum(s["quantize_s"] for s in snaps)
                + sum(s["score_s"] for s in snaps))
        pad_total = rows + pad_rows
        return {
            "name": snaps[0]["name"],
            "parts": len(parts),
            "rows": rows,
            "chunks": sum(s["chunks"] for s in snaps),
            "compiles": sum(s["compiles"] for s in snaps),
            "resumed_from": min(s["resumed_from"] for s in snaps),
            "wall_s": max(s["wall_s"] for s in snaps),
            "rows_per_s": sum(s["rows_per_s"] for s in snaps),
            "interval_rows_per_s": sum(s["interval_rows_per_s"]
                                       for s in snaps),
            "quantize_s": sum(s["quantize_s"] for s in snaps),
            "score_s": sum(s["score_s"] for s in snaps),
            "quantize_frac": (sum(s["quantize_s"] for s in snaps) / busy
                              if busy else 0.0),
            "chunk_p50_ms": lat.percentile(50) * 1e3,
            "chunk_p99_ms": lat.percentile(99) * 1e3,
            "pad_overhead": (pad_rows / pad_total if pad_total else 0.0),
        }

    def __repr__(self) -> str:
        s = self.snapshot()
        return (f"<ScoringMetrics {s['name']}: {s['rows']} rows in "
                f"{s['chunks']} chunks, {s['rows_per_s']:.0f} rows/s, "
                f"quantize {s['quantize_frac']:.0%} of busy time>")


@dataclasses.dataclass(frozen=True)
class ChunkSpan:
    """One planned chunk: rows [start, stop) padded up to `padded`."""
    index: int
    start: int
    stop: int
    padded: int

    @property
    def n_valid(self) -> int:
        return self.stop - self.start


def plan_chunks(n_rows: int, chunk_rows: int) -> tuple[ChunkSpan, ...]:
    """Cut n_rows into fixed `chunk_rows` spans; the tail span is
    padded to the smallest power-of-two bucket holding it (so a run is
    at most 2 distinct padded shapes: the chunk and one tail bucket)."""
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    ladder = pow2_buckets(chunk_rows, min_bucket=min(16, chunk_rows))
    spans = []
    for i, start in enumerate(range(0, n_rows, chunk_rows)):
        stop = min(start + chunk_rows, n_rows)
        n = stop - start
        padded = chunk_rows if n == chunk_rows else bucket_for(n, ladder)
        spans.append(ChunkSpan(i, start, stop, padded))
    return tuple(spans)


@dataclasses.dataclass(frozen=True)
class ScoreResult:
    """What a bulk run produced: per-model sink results, the metrics
    snapshot, and the compile-contract evidence (`chunk_shapes` is the
    set of padded shapes the jitted entries saw — always <= 2)."""
    outputs: dict[str, Any]
    metrics: dict[str, Any]
    chunk_rows: int
    chunk_shapes: tuple[int, ...]
    n_rows: int

    @property
    def output(self) -> Any:
        """Single-model convenience accessor."""
        if len(self.outputs) != 1:
            raise ValueError(f"run scored {sorted(self.outputs)}; pick "
                             "one from .outputs")
        return next(iter(self.outputs.values()))


@dataclasses.dataclass
class _SchemaGroup:
    """Plans sharing one quantization schema: quantize once per chunk.

    `rep` is the group's representative plan — its jitted `quantize`
    entry binarizes every full chunk (one XLA shape for the whole run;
    pools are schema-wide shareable, so any group member works).  The
    tail chunk goes through the eager `quantize_pool` + `pad_rows`
    instead, keeping the jitted quantize cache at exactly one shape.
    """
    fingerprint: str
    borders: Any
    backend: str
    use_pool: bool
    rep: Predictor
    names: list[str]


class BulkScorer:
    """Apply one or many compiled `Predictor` plans to a `RowSource`,
    streaming scores into `ScoreSink`s (see module docstring).

    Pass a single plan or a ``{name: Predictor}`` mapping; all plans
    must agree on feature count (they read the same source).  The
    scorer is stateless across runs — `score` may be called repeatedly
    (the plans' jit caches persist, so later runs skip compilation).
    """

    def __init__(self, plans: Predictor | Mapping[str, Predictor],
                 config: Optional[ScoreConfig] = None, *,
                 mesh=None, **config_kw: Any):
        if config is None:
            config = ScoreConfig(**config_kw)
        elif config_kw:
            raise TypeError("pass either a ScoreConfig or config kwargs, "
                            f"not both: {sorted(config_kw)}")
        self.config = config
        # mesh mode: every chunk's rows shard across the mesh through
        # the plan's `sharded()` pool/float entries (full registry
        # dispatch per shard, exact row-shard parity).  The streaming
        # contracts hold unchanged: the chunk planner still fixes <= 2
        # padded shapes, host memory stays O(chunk), and the Prefetcher
        # still binarizes chunk k+1 while chunk k's shards score —
        # prequantized chunks quantize once on the worker and shard
        # their uint8 bins; the float fallback binarizes shard-locally
        # inside the mesh body.
        self.mesh = mesh
        if isinstance(plans, Predictor):
            plans = {"model": plans}
        self.plans = dict(plans)
        if not self.plans:
            raise ValueError("BulkScorer needs at least one plan")
        for name, plan in self.plans.items():
            if not isinstance(plan, Predictor):
                raise TypeError(f"plans[{name!r}] is {type(plan).__name__},"
                                " not a Predictor (build one with "
                                "Predictor.build)")
        feats = {p.ensemble.n_features for p in self.plans.values()}
        if len(feats) > 1:
            raise ValueError(f"plans disagree on feature count {feats}; "
                             "one source feeds them all")
        self.n_features = feats.pop()
        # quantize once per schema fingerprint, score every plan in the
        # group from that pool (the predict_multi pattern, offline)
        self._groups: dict[str, _SchemaGroup] = {}
        for name, plan in self.plans.items():
            fp = plan.schema_fingerprint
            g = self._groups.get(fp)
            if g is None:
                can_pool = (config.prequantize and
                            plan.ensemble.borders.shape[0] <= MAX_BINS - 1)
                g = _SchemaGroup(fp, plan.ensemble.borders,
                                 plan.config.backend, can_pool, plan, [])
                self._groups[fp] = g
            g.names.append(name)
        self._group_of = {name: g for g in self._groups.values()
                          for name in g.names}

    # -- planning ----------------------------------------------------------
    def resolve_chunk_rows(self, n_rows: int) -> int:
        if self.config.chunk_rows:
            return self.config.chunk_rows
        ensembles = [p.ensemble for p in self.plans.values()]
        return tuning.best_chunk_rows(
            self.n_features,
            max(e.n_outputs for e in ensembles),
            n_borders=max(int(e.borders.shape[0]) for e in ensembles),
            n_trees=max(e.n_trees for e in ensembles),
            n_leaves=max(int(e.leaf_values.shape[1]) for e in ensembles),
            budget_bytes=self.config.chunk_budget_bytes, n_rows=n_rows)

    def _output_width(self, plan: Predictor) -> int:
        c = plan.ensemble.n_outputs
        if self.config.output == "raw":
            return c
        if self.config.output == "proba":
            return max(c, 2)
        return 1                                    # classify

    # -- the run -----------------------------------------------------------
    def _prepare(self, metrics: ScoringMetrics, chunk_rows: int):
        """Build the prefetch transform: pad the chunk to its planned
        shape and binarize it once per schema group.  Runs on the
        Prefetcher worker thread — chunk k+1 quantizes while the main
        thread's dispatch scores chunk k."""
        def prepare(item):
            span, x = item
            t0 = time.perf_counter()
            # this span lands on the Prefetcher worker's thread id, so
            # the exported timeline shows chunk k+1's quantize riding
            # under chunk k's bulk/score on the main-thread track
            with _TRACER.span("bulk/quantize", "bulk", chunk=span.index,
                              rows=span.n_valid, padded=span.padded):
                payload: dict[str, Any] = {}
                need_float = any(not g.use_pool
                                 for g in self._groups.values())
                if need_float:
                    payload["__float__"] = jnp.asarray(
                        pad_rows(x, span.padded), jnp.float32)
                for fp, g in self._groups.items():
                    if g.use_pool:
                        # every chunk — the tail too — binarizes through
                        # the representative plan's jitted quantize entry
                        # at the one full-chunk shape (a zero-padded float
                        # row bins to 0, exactly what pool padding yields)
                        pool = g.rep.quantize(
                            x if span.n_valid == chunk_rows
                            else pad_rows(x, chunk_rows))
                        if span.padded != chunk_rows:
                            # tail: slice the valid rows back out and
                            # bucket-pad the pool to the planned tail shape
                            pool = pool.slice_rows(0, span.n_valid) \
                                       .pad_rows(span.padded)
                        # force the binarize to finish HERE, on the worker
                        # thread: jax dispatch is async, and an unfinished
                        # pool would push the quantize work onto the main
                        # thread's sync point, killing the overlap
                        pool.bins.block_until_ready()
                        payload[fp] = pool
            metrics.note_quantize(time.perf_counter() - t0)
            return span, payload
        return prepare

    def _score_entry(self, plan: Predictor, x) -> np.ndarray:
        out = self.config.output
        if self.mesh is not None:
            raw = plan.sharded(self.mesh)(x)
            if out == "raw":
                return raw
            if out == "proba":
                return proba_from_raw(raw, plan.ensemble.n_outputs)
            return classify_from_raw(raw, plan.ensemble.n_outputs)
        if out == "raw":
            return plan.raw(x)
        if out == "proba":
            return plan.proba(x)
        return plan.classify(x)

    def score(self, source: RowSource, sinks=None, *,
              resume_from: int = 0) -> ScoreResult:
        """Stream the whole source through every plan.

        `sinks` is a ``{name: ScoreSink}`` mapping, a single sink (for
        single-plan scorers), or None (fresh `ArraySink` per plan —
        the whole output in host memory; pass `NpySink`s to stay
        out-of-core).  ``resume_from=k`` skips chunks < k: chunk
        boundaries depend only on (n_rows, chunk_rows), so a resumed
        run lands its rows at identical positions — pair with
        row-addressed sinks (`NpySink(resume=True)`); the streaming
        reducer sinks fold only the remaining rows.
        """
        if source.n_features != self.n_features:
            raise ValueError(f"source has {source.n_features} features, "
                             f"plans expect {self.n_features}")
        n_rows = source.n_rows
        chunk_rows = self.resolve_chunk_rows(n_rows)
        spans = plan_chunks(n_rows, chunk_rows)
        if not 0 <= resume_from <= len(spans):
            raise ValueError(f"resume_from={resume_from} outside "
                             f"[0, {len(spans)}] for {len(spans)} chunks "
                             f"of {chunk_rows} rows")
        todo = spans[resume_from:]

        sinks = self._normalize_sinks(sinks)
        for name, plan in self.plans.items():
            sinks[name].open(n_rows, self._output_width(plan))

        metrics = ScoringMetrics()
        metrics.resumed_from = resume_from
        traces0 = sum(p.stats["total_traces"] for p in self.plans.values())
        metrics.start()

        def read_spans():
            for span in todo:
                yield span, source.read(span.start, span.stop)

        prepare = self._prepare(metrics, chunk_rows)
        if self.config.prefetch_depth > 0 and len(todo) > 1:
            stream = Prefetcher(read_spans(),
                                depth=self.config.prefetch_depth,
                                transform=prepare)
        else:
            stream = map(prepare, read_spans())
        def drain(entry):
            span, outs, t0 = entry
            with _TRACER.span("bulk/sink", "bulk", chunk=span.index,
                              rows=span.n_valid):
                for name, ys in outs.items():
                    ys = np.asarray(ys, np.float32)   # host sync point
                    if ys.ndim == 1:                  # classify: (N,) ids
                        ys = ys[:, None]
                    sinks[name].write(span.start, ys[:span.n_valid])
            metrics.note_chunk(span.n_valid, span.padded,
                               time.perf_counter() - t0)

        # lag-1 sync: dispatch chunk k+1's entries before forcing chunk
        # k's device->host copy, so jax's async dispatch keeps the
        # device busy while python writes sinks (pending is bounded at
        # 2 chunks — the O(chunk) memory contract includes it)
        pending: list = []
        try:
            for span, payload in stream:
                t0 = time.perf_counter()
                outs = {}
                # covers dispatch only (jax is async): device compute
                # overlaps the next iteration; the sync cost is under
                # the chunk's bulk/sink span
                with _TRACER.span("bulk/score", "bulk", chunk=span.index,
                                  rows=span.n_valid, padded=span.padded,
                                  models=len(self.plans)):
                    for name, plan in self.plans.items():
                        g = self._group_of[name]
                        x_in = payload[g.fingerprint if g.use_pool
                                       else "__float__"]
                        outs[name] = self._score_entry(plan, x_in)
                pending.append((span, outs, t0))
                if len(pending) > 1:
                    drain(pending.pop(0))
            while pending:
                drain(pending.pop(0))
        finally:
            if isinstance(stream, Prefetcher):
                stream.close()
        metrics.stop()
        metrics.compiles = sum(p.stats["total_traces"]
                               for p in self.plans.values()) - traces0

        outputs = {name: sinks[name].close() for name in self.plans}
        return ScoreResult(outputs=outputs, metrics=metrics.snapshot(),
                           chunk_rows=chunk_rows,
                           chunk_shapes=tuple(sorted(
                               {s.padded for s in todo})),
                           n_rows=n_rows)

    def _normalize_sinks(self, sinks) -> dict[str, ScoreSink]:
        if sinks is None:
            return {name: ArraySink() for name in self.plans}
        if isinstance(sinks, Mapping):
            missing = set(self.plans) - set(sinks)
            if missing:
                raise ValueError(f"no sink for plans {sorted(missing)}")
            return {name: sinks[name] for name in self.plans}
        if len(self.plans) != 1:
            raise ValueError("a single bare sink needs a single plan; "
                             f"got plans {sorted(self.plans)}")
        return {next(iter(self.plans)): sinks}
