"""Score sinks: where the bulk scorer streams its output.

A `ScoreSink` receives row-addressed score panels and never requires
the whole output in memory at once:

    sink.open(n_rows, n_cols)       # called once, total output shape
    sink.write(start, scores)       # (n, n_cols) float32 rows at `start`
    result = sink.close()           # sink-specific result value

``write`` is row-addressed (not append-only) so a resumed run
(`BulkScorer.score(..., resume_from=k)`) can drop its chunks into the
same positions — `ArraySink` and `NpySink` are idempotent per row range
and safe to resume into; the streaming reducers (`StatsSink`,
`TopKSink`) fold rows as they pass and must see every chunk exactly
once, so resume into a *fresh* reducer only scores the remaining rows.

`NpySink` is the out-of-core output: a ``.npy`` memmap the OS pages
out, so a dataset-sized score matrix costs O(chunk) host memory — the
mirror image of `sources.NpyMemmapSource`.
"""
from __future__ import annotations

import pathlib
from typing import Any, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class ScoreSink(Protocol):
    """Row-addressed streaming score consumer (see module docstring)."""

    def open(self, n_rows: int, n_cols: int) -> None: ...

    def write(self, start: int, scores: np.ndarray) -> None: ...

    def close(self) -> Any: ...


class _SinkBase:
    """Shared open/write bookkeeping: shape checks + rows_written."""

    def __init__(self):
        self.n_rows = self.n_cols = -1
        self.rows_written = 0

    def open(self, n_rows: int, n_cols: int) -> None:
        if n_rows < 0 or n_cols < 1:
            raise ValueError(f"bad sink shape ({n_rows}, {n_cols})")
        self.n_rows, self.n_cols = n_rows, n_cols
        self.rows_written = 0

    def _check_write(self, start: int, scores: np.ndarray) -> np.ndarray:
        if self.n_rows < 0:
            raise ValueError("sink.write before sink.open")
        scores = np.asarray(scores, np.float32)
        if scores.ndim != 2 or scores.shape[1] != self.n_cols:
            raise ValueError(f"scores shape {scores.shape} does not match "
                             f"sink width {self.n_cols}")
        if not 0 <= start <= start + scores.shape[0] <= self.n_rows:
            raise ValueError(f"write span [{start}, "
                             f"{start + scores.shape[0]}) outside "
                             f"[0, {self.n_rows})")
        self.rows_written += scores.shape[0]
        return scores


class ArraySink(_SinkBase):
    """Scores into one in-memory float32 array; `close` returns it."""

    def __init__(self):
        super().__init__()
        self.scores: np.ndarray | None = None

    def open(self, n_rows: int, n_cols: int) -> None:
        super().open(n_rows, n_cols)
        self.scores = np.zeros((n_rows, n_cols), np.float32)

    def write(self, start: int, scores: np.ndarray) -> None:
        scores = self._check_write(start, scores)
        self.scores[start:start + scores.shape[0]] = scores

    def close(self) -> np.ndarray:
        return self.scores


class NpySink(_SinkBase):
    """Scores into a ``.npy`` memmap on disk; `close` flushes and
    returns the path.

    ``resume=True`` reopens an existing file in place (shape must
    match) instead of truncating it — the resume-by-chunk-index
    contract: rows written by the interrupted run survive, the resumed
    run fills in the rest.
    """

    def __init__(self, path: str | pathlib.Path, *, resume: bool = False):
        super().__init__()
        self.path = pathlib.Path(path)
        self.resume = resume
        self._mm: np.memmap | None = None

    def open(self, n_rows: int, n_cols: int) -> None:
        super().open(n_rows, n_cols)
        if self.resume and self.path.exists():
            mm = np.lib.format.open_memmap(self.path, mode="r+")
            if mm.shape != (n_rows, n_cols) or mm.dtype != np.float32:
                raise ValueError(
                    f"{self.path}: existing file is {mm.dtype}"
                    f"{mm.shape}, cannot resume a float32"
                    f"{(n_rows, n_cols)} run into it")
            self._mm = mm
        else:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._mm = np.lib.format.open_memmap(
                self.path, mode="w+", dtype=np.float32,
                shape=(n_rows, n_cols))

    def write(self, start: int, scores: np.ndarray) -> None:
        scores = self._check_write(start, scores)
        self._mm[start:start + scores.shape[0]] = scores

    def close(self) -> pathlib.Path:
        if self._mm is not None:
            self._mm.flush()
            self._mm = None
        return self.path


class StatsSink(_SinkBase):
    """Streaming per-column moments: count / mean / std / min / max.

    Chan's parallel-variance merge per chunk, so the reduction is
    one pass, O(n_cols) state, and independent of chunk order — the
    score-distribution monitor for a nightly rescore (drift alarms
    compare these against the previous run's).  `close` returns the
    stats dict.
    """

    def __init__(self):
        super().__init__()
        self._count = 0
        self._mean: np.ndarray | None = None
        self._m2: np.ndarray | None = None
        self._min: np.ndarray | None = None
        self._max: np.ndarray | None = None

    def open(self, n_rows: int, n_cols: int) -> None:
        super().open(n_rows, n_cols)
        self._count = 0
        self._mean = np.zeros(n_cols, np.float64)
        self._m2 = np.zeros(n_cols, np.float64)
        self._min = np.full(n_cols, np.inf, np.float64)
        self._max = np.full(n_cols, -np.inf, np.float64)

    def write(self, start: int, scores: np.ndarray) -> None:
        scores = self._check_write(start, scores)
        n = scores.shape[0]
        if n == 0:
            return
        s = scores.astype(np.float64)
        mean_b = s.mean(axis=0)
        m2_b = ((s - mean_b) ** 2).sum(axis=0)
        if self._count == 0:
            self._mean, self._m2 = mean_b, m2_b
        else:
            delta = mean_b - self._mean
            tot = self._count + n
            self._mean = self._mean + delta * (n / tot)
            self._m2 = self._m2 + m2_b + delta ** 2 * (self._count * n / tot)
        self._count += n
        np.minimum(self._min, s.min(axis=0), out=self._min)
        np.maximum(self._max, s.max(axis=0), out=self._max)

    def close(self) -> dict[str, Any]:
        var = (self._m2 / self._count if self._count
               else np.zeros_like(self._m2))
        return {
            "count": self._count,
            "mean": np.asarray(self._mean),
            "std": np.sqrt(var),
            "min": np.asarray(self._min),
            "max": np.asarray(self._max),
        }


class TopKSink(_SinkBase):
    """Streaming top-k rows by one score column.

    Keeps the k best (row index, full score row) seen so far by merging
    each chunk against the running top set — O(k + chunk) per write, so
    "give me the 100 highest-risk customers of 50M" never ranks the
    full output.  `close` returns ``{"indices", "scores"}`` sorted
    best-first.  ``largest=False`` flips to bottom-k.
    """

    def __init__(self, k: int, *, column: int = 0, largest: bool = True):
        super().__init__()
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = k
        self.column = column
        self.largest = largest
        self._idx: np.ndarray | None = None
        self._rows: np.ndarray | None = None

    def open(self, n_rows: int, n_cols: int) -> None:
        super().open(n_rows, n_cols)
        if not -n_cols <= self.column < n_cols:
            raise ValueError(f"column {self.column} outside the "
                             f"{n_cols}-wide score row")
        self._idx = np.zeros(0, np.int64)
        self._rows = np.zeros((0, n_cols), np.float32)

    def write(self, start: int, scores: np.ndarray) -> None:
        scores = self._check_write(start, scores)
        if scores.shape[0] == 0:
            return
        idx = np.concatenate([
            self._idx, np.arange(start, start + scores.shape[0])])
        rows = np.concatenate([self._rows, scores], axis=0)
        key = rows[:, self.column]
        if not self.largest:
            key = -key
        keep = np.argsort(-key, kind="stable")[:self.k]
        self._idx, self._rows = idx[keep], rows[keep]

    def close(self) -> dict[str, np.ndarray]:
        return {"indices": self._idx, "scores": self._rows}
