"""Compiled-plan GBDT evaluation: prepare the model once, predict many.

The paper's speedups come from hoisting everything that does not depend
on the incoming batch out of the hot loop: CatBoost's evaluator prepares
quantized borders and a blocked tree layout once, then runs a tight
vectorized loop per batch.  The kwarg-threaded `core.predict.raw_predict`
path re-did that preparation on every call — re-resolving `auto`
strategy/backend, re-running the block tuner, and re-padding the *model*
arrays (borders, splits, leaf values) to block multiples inside each
predict.  This module is the prepare-once counterpart:

  config = PredictConfig(strategy="fused", backend="pallas")
  plan   = Predictor.build(ensemble, config)   # resolve + pad ONCE
  plan.raw(x)       # (N, C) raw scores — jitted, cached per batch shape
  plan.proba(x)     # class probabilities
  plan.classify(x)  # argmax / threshold
  plan.sharded(mesh)(x)   # mesh-distributed raw scores

Quantized-first evaluation (the paper's actual data flow — its
evaluators binarize once and run CalcIndexes over uint8 bins, never
re-touching float features):

  pool = plan.quantize(x)      # binarize ONCE -> uint8 QuantizedPool
  plan.raw(pool)               # skips binarize entirely
  plan.proba(pool); plan.classify(pool)

A pool is schema-stamped (`quantize.borders_fingerprint`): scoring it
through a plan quantized with different borders raises `ValueError`
instead of silently indexing the wrong bin space.  Models sharing a
schema share pools — the multi-model registry serving win.

`Predictor.build` resolves `auto` choices to concrete ones (backend via
the kernel registry's platform default, fused block shapes from
`kernels.tuning`), pads the model arrays to block multiples exactly
once, and caches jitted entry points; with bucketed serving batches the
number of XLA compiles is bounded by (entry points x batch buckets).
The kwarg API in `core.predict` remains as a thin one-shot shim over
this class.

`from_catboost_json` ingests CatBoost's exported oblivious-tree JSON
(`model.save_model(f, format="json")`): per-feature borders, split
feature/border per depth, flat leaf values — the real-model workload the
paper benchmarks.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import threading
from typing import Any, Callable, Literal, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import layout as layout_mod
from repro.core.layout import LoweredEnsemble, STAGED_TREE_ALIGN
from repro.core.quantize import (QuantizedPool, borders_fingerprint,
                                 MAX_BINS)
from repro.core.trees import ObliviousEnsemble
from repro.kernels import ops
from repro.kernels import registry
from repro.kernels import tuning
from repro.kernels.ops import PAD_SPLIT_BIN
from repro.obs.trace import get_tracer

_TRACER = get_tracer()

Strategy = Literal["auto", "staged", "fused"]
Backend = str   # "auto" or a kernel-registry backend family

_STRATEGIES = ("auto", "staged", "fused")


@dataclasses.dataclass(frozen=True)
class PredictConfig:
    """Validated prediction-plan configuration.

    `auto` fields are placeholders resolved at plan-build time by
    `resolve()`; a built `Predictor` only ever holds concrete values, so
    nothing downstream re-queries the platform or the tuner per call.

      strategy   staged (paper three-pass) | fused (single Pallas pass)
      backend    a kernel-registry backend: pallas (real kernels;
                 interpret on CPU) | ref (pure jnp) — validated against
                 `kernels.registry.known_backends()`.  Note a third
                 registered family would pass validation but currently
                 gets the ref (unpadded) model layout: `layout.lower`
                 only knows how to pre-pad for the pallas kernels'
                 block contracts
      layout     physical model layout the plan lowers to (see
                 `repro.core.layout`): soa | depth_major |
                 depth_grouped | bitpacked; auto picks from the
                 ensemble's depth histogram / leaf-table bytes via
                 `kernels.tuning.best_layout`
      tree_block staged-path tree blocking (CalcTreesBlockedImpl); 0 = off
                 (soa layout only — an auto layout resolves to soa when
                 tree blocking is requested)
      block_n/t  fused-kernel Pallas block shapes; None = autotuned
    """
    strategy: Strategy = "auto"
    backend: Backend = "auto"
    layout: str = "auto"
    tree_block: int = 0
    block_n: Optional[int] = None
    block_t: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in _STRATEGIES:
            raise ValueError(f"strategy must be one of {_STRATEGIES}, "
                             f"got {self.strategy!r}")
        backends = ("auto",) + registry.known_backends()
        if self.backend not in backends:
            raise ValueError(f"backend must be one of {backends}, "
                             f"got {self.backend!r}")
        layouts = ("auto",) + layout_mod.LAYOUT_NAMES
        if self.layout not in layouts:
            raise ValueError(f"layout must be one of {layouts}, "
                             f"got {self.layout!r}")
        if not isinstance(self.tree_block, int) or self.tree_block < 0:
            raise ValueError(f"tree_block must be an int >= 0, "
                             f"got {self.tree_block!r}")
        if self.tree_block and self.layout not in ("auto", "soa"):
            raise ValueError(
                f"tree_block is a soa-layout feature (the depth layouts "
                f"block by structure instead); got tree_block="
                f"{self.tree_block} with layout={self.layout!r}")
        for name in ("block_n", "block_t"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v < 1):
                raise ValueError(f"{name} must be a positive int or None, "
                                 f"got {v!r}")

    @property
    def is_resolved(self) -> bool:
        return (self.strategy != "auto" and self.backend != "auto"
                and self.layout != "auto"
                and (self.strategy != "fused"
                     or (self.block_n is not None
                         and self.block_t is not None)))

    def resolve(self, ensemble: ObliviousEnsemble, *,
                n_rows: Optional[int] = None) -> "PredictConfig":
        """Concretize every `auto` choice for one ensemble.

        The `auto` backend resolves through the kernel registry
        (`registry.default_backend()`, reading the once-per-process
        platform); fused block shapes come from the VMEM footprint
        model in `kernels.tuning`, sized to this ensemble (and
        `n_rows`, the expected batch size, when known); the `auto`
        layout comes from `tuning.best_layout` on the ensemble's depth
        histogram (tracer ensembles — per-shard plans built inside
        shard_map — pin to soa: grouping needs to read split_bins).
        """
        strategy, backend = self.strategy, self.backend
        if strategy == "auto":
            strategy = "fused" if ops.default_platform() == "tpu" \
                else "staged"
        if backend == "auto":
            backend = registry.default_backend()
        layout = self.layout
        if layout == "auto":
            if self.tree_block or not layout_mod.is_concrete(ensemble):
                layout = "soa"
            else:
                layout = tuning.best_layout(ensemble.true_depths,
                                            ensemble.n_outputs,
                                            ensemble.n_features,
                                            backend=backend)
        block_n, block_t = self.block_n, self.block_t
        if strategy == "fused" and (block_n is None or block_t is None):
            tn, tt = tuning.best_fused_blocks(
                ensemble.n_features, ensemble.depth,
                ensemble.leaf_values.shape[1], ensemble.n_outputs,
                ensemble.borders.shape[0], n_rows=n_rows,
                n_trees=ensemble.n_trees)
            block_n = block_n or tn
            block_t = block_t or tt
        return dataclasses.replace(self, strategy=strategy, backend=backend,
                                   layout=layout, block_n=block_n,
                                   block_t=block_t)


def proba_from_raw(raw: jax.Array, n_outputs: int) -> jax.Array:
    """Raw scores -> class probabilities: two-column sigmoid for binary
    models, softmax otherwise.  The single definition every predict
    surface (plan entries, kwarg shims, mesh serving) shares."""
    if n_outputs == 1:
        p = jax.nn.sigmoid(raw[:, 0])
        return jnp.stack([1.0 - p, p], axis=1)
    return jax.nn.softmax(raw, axis=-1)


def classify_from_raw(raw: jax.Array, n_outputs: int) -> jax.Array:
    """Raw scores -> int32 class ids: zero threshold for binary models,
    argmax otherwise (single definition, like `proba_from_raw`)."""
    if n_outputs == 1:
        return (raw[:, 0] > 0.0).astype(jnp.int32)
    return jnp.argmax(raw, axis=-1).astype(jnp.int32)


def _lower_model(ensemble: ObliviousEnsemble, cfg: PredictConfig
                 ) -> tuple[LoweredEnsemble, int, float]:
    """The one-time model lowering `Predictor.build` hoists.

    Returns the lowered model, the number of model pad ops spent, and
    the wall-clock lowering seconds (surfaced in `Predictor.stats` so
    serving dashboards can see what one-time cost shipped).
    """
    import time
    t_align = cfg.block_t if cfg.strategy == "fused" else STAGED_TREE_ALIGN
    tree_block = cfg.tree_block if cfg.strategy == "staged" else 0
    t0 = time.perf_counter()
    lowered = layout_mod.lower(ensemble, cfg.layout, backend=cfg.backend,
                               t_align=t_align, tree_block=tree_block)
    return lowered, lowered.n_model_pads, time.perf_counter() - t0


class Predictor:
    """A compiled prediction plan for one ensemble.

    Construct with `Predictor.build(...)` (or `from_catboost_json`).
    The plan owns:
      * a fully resolved `PredictConfig` (no `auto` left)
      * the model lowered ONCE into its physical layout (see
        `repro.core.layout`): arrays reordered / precomputed / padded
        to block multiples at build time
      * jitted `raw` / `proba` / `classify` entry points whose compile
        cache is keyed by batch shape — with bucketed serving batches,
        compiles are bounded by (entries used x buckets)
    The plan is immutable: if the underlying ensemble changes, build a
    new `Predictor` (see `serving.engine.ModelRegistry.register`).
    """

    def __init__(self, ensemble: ObliviousEnsemble, config: PredictConfig,
                 lowered: Optional[LoweredEnsemble], *,
                 on_trace: Optional[Callable[[], None]] = None,
                 build_model_pads: int = 0,
                 lower_time_s: float = 0.0):
        if not config.is_resolved:
            raise ValueError("Predictor requires a resolved PredictConfig; "
                             "use Predictor.build()")
        self.ensemble = ensemble
        self.config = config
        self._lowered = lowered
        self._on_trace = on_trace
        self._build_model_pads = build_model_pads
        self._lower_time_s = lower_time_s
        self._lock = threading.Lock()
        self._traces: dict[str, int] = {}
        self._entry_shapes: set[tuple] = set()
        self._sharded_cache: dict[tuple, Callable] = {}
        # Schema fingerprint: which QuantizedPools this plan may score.
        # Computed lazily — the per-shard plans `sharded()` builds inside
        # shard_map hold tracer borders, which cannot be hashed (and
        # never score pools).
        self._schema_fingerprint: Optional[str] = None
        # Abstract (make_jaxpr) traces per (entry, shape, dtype, schema
        # fingerprint) — the contract checker walks every plan entry,
        # and walking must never compile (the jitted entries each tick
        # an XLA compile) nor re-trace an entry it already walked.
        self._abstract_traces: dict[tuple, Any] = {}
        self._abstract_trace_misses = 0
        self._entries = {
            "raw": self._make_entry("raw", self._raw_impl),
            "proba": self._make_entry("proba", self._proba_impl),
            "classify": self._make_entry("classify", self._classify_impl),
            # quantized-pool entries: same surface, bins in, no binarize
            "raw_pool": self._make_entry("raw_pool", self._pool_raw_impl),
            "proba_pool": self._make_entry("proba_pool",
                                           self._pool_proba_impl),
            "classify_pool": self._make_entry("classify_pool",
                                              self._pool_classify_impl),
            "quantize": self._make_entry("quantize", self._quantize_impl),
        }

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, ensemble: ObliviousEnsemble,
              config: Optional[PredictConfig] = None, *,
              expected_batch: Optional[int] = None,
              on_trace: Optional[Callable[[], None]] = None,
              prepare: bool = True,
              **config_kw: Any) -> "Predictor":
        """Resolve the config and prepare the model — the only place any
        per-ensemble preparation happens.

        `expected_batch` feeds the fused block tuner's padding-waste
        penalty (serving passes its largest bucket).  `config_kw` is a
        convenience for `Predictor.build(ens, strategy="fused")` style
        calls; it cannot be combined with an explicit `config`.
        `prepare=False` defers the model lowering to the first local
        predict — for plans used only through `sharded(mesh)`, which
        lowers per tree shard and would never read the local copy.
        """
        if config is None:
            config = PredictConfig(**config_kw)
        elif config_kw:
            raise TypeError("pass either a PredictConfig or config kwargs, "
                            f"not both: {sorted(config_kw)}")
        resolved = config.resolve(ensemble, n_rows=expected_batch)
        lowered, pads, secs = (_lower_model(ensemble, resolved) if prepare
                               else (None, 0, 0.0))
        return cls(ensemble, resolved, lowered, on_trace=on_trace,
                   build_model_pads=pads, lower_time_s=secs)

    @classmethod
    def from_catboost_json(cls, path: str | pathlib.Path,
                           config: Optional[PredictConfig] = None,
                           **build_kw: Any) -> "Predictor":
        """Build a plan straight from a CatBoost JSON model export."""
        return cls.build(load_catboost_json(path), config, **build_kw)

    # -- plan internals ----------------------------------------------------
    @property
    def lowered(self) -> LoweredEnsemble:
        """The physical `LoweredEnsemble` this plan scores through
        (lowering it first for deferred-prepare plans)."""
        return self._ensure_prepared()

    @property
    def schema_fingerprint(self) -> str:
        """Fingerprint of this plan's quantization schema: pools are
        scoreable iff their fingerprint matches."""
        if self._schema_fingerprint is None:
            self._schema_fingerprint = borders_fingerprint(
                self.ensemble.borders)
        return self._schema_fingerprint

    def _note_trace(self, name: str) -> None:
        with self._lock:
            self._traces[name] = self._traces.get(name, 0) + 1
        if self._on_trace is not None:
            self._on_trace()

    def _make_entry(self, name: str, impl: Callable) -> Callable:
        def traced(x):
            # Body runs only when jax traces a new shape; counting here
            # counts exactly the XLA compiles for this entry point (and
            # keeps shape bookkeeping off the cached-dispatch hot path).
            self._note_trace(name)
            with self._lock:
                self._entry_shapes.add((name,) + tuple(x.shape))
            if _TRACER.enabled:
                # one instant per XLA compile: (entry, layout, batch
                # bucket) — the timeline marker for every cache miss
                _TRACER.instant(f"compile/{name}", "compile",
                                entry=name, layout=self.config.layout,
                                batch=int(x.shape[0]))
            return impl(x)
        return jax.jit(traced)

    def _ensure_prepared(self) -> LoweredEnsemble:
        """Model lowering for a `prepare=False` plan, eagerly (never
        inside a trace: lowering must run once, not once per compile)."""
        p = self._lowered
        if p is None:
            with self._lock:
                p = self._lowered
                if p is None:
                    p, pads, secs = _lower_model(self.ensemble, self.config)
                    self._lowered = p
                    self._build_model_pads = pads
                    self._lower_time_s = secs
        return p

    def _accumulate_trees(self, bins: jax.Array) -> jax.Array:
        """Staged index+gather over the lowered model, from bins.

        Shared by the float path (after its binarize stage) and the
        quantized-pool path (which starts here — binarize never runs).
        `bins` may be int32 or uint8; the registry routes uint8 to the
        u8 kernel variants.  The per-layout kernel routing lives on the
        `LoweredEnsemble` itself (`leaf_sum`): soa runs the classic
        index+gather (optionally tree-blocked), depth_major matmuls
        against the precomputed one-hot, depth_grouped loops depth
        groups.  A fused-strategy plan scoring a pool also lands here:
        its trees are padded to cfg.block_t multiples, so the staged
        kernels get that block shape.
        """
        cfg = self.config
        block_t = (cfg.block_t if cfg.strategy == "fused"
                   else STAGED_TREE_ALIGN)
        return self._lowered.leaf_sum(bins, backend=cfg.backend,
                                      block_t=block_t)

    def _raw_impl(self, x: jax.Array) -> jax.Array:
        cfg, p = self.config, self._lowered
        base = self.ensemble.base_score[None, :]
        if cfg.strategy == "fused":
            return base + p.fused_raw(x, backend=cfg.backend,
                                      block_n=cfg.block_n,
                                      block_t=cfg.block_t)
        bins = ops.binarize_prepadded(x, p.borders, backend=cfg.backend)
        return base + self._accumulate_trees(bins)

    def _proba_impl(self, x: jax.Array) -> jax.Array:
        return proba_from_raw(self._raw_impl(x), self.ensemble.n_outputs)

    def _classify_impl(self, x: jax.Array) -> jax.Array:
        return classify_from_raw(self._raw_impl(x),
                                 self.ensemble.n_outputs)

    # -- quantized-pool path (binarize skipped entirely) -------------------
    def _pool_raw_impl(self, bins: jax.Array) -> jax.Array:
        # Pool bins carry the unpadded feature axis (shareable across
        # plans); pad data-side up to the lowered borders' aligned F.
        p = self._lowered
        bins = ops.pad_features(bins, p.borders.shape[1])
        base = self.ensemble.base_score[None, :]
        return base + self._accumulate_trees(bins)

    def _pool_proba_impl(self, bins: jax.Array) -> jax.Array:
        return proba_from_raw(self._pool_raw_impl(bins),
                              self.ensemble.n_outputs)

    def _pool_classify_impl(self, bins: jax.Array) -> jax.Array:
        return classify_from_raw(self._pool_raw_impl(bins),
                                 self.ensemble.n_outputs)

    def _quantize_impl(self, x: jax.Array) -> jax.Array:
        # Binarize against the *lowered* borders (zero model-side pads
        # at trace time), then drop the alignment columns so the pool is
        # schema-wide shareable, not plan-layout specific.
        p = self._lowered
        bins = ops.binarize_u8_prepadded(x, p.borders,
                                         backend=self.config.backend)
        return bins[:, :self.ensemble.n_features]

    def _check_pool(self, pool: QuantizedPool) -> None:
        if pool.fingerprint != self.schema_fingerprint:
            raise ValueError(
                "QuantizedPool schema mismatch: pool was quantized under "
                f"fingerprint {pool.fingerprint} but this plan's borders "
                f"have fingerprint {self.schema_fingerprint} — its "
                "split_bins would index a different bin space.  "
                "Re-quantize with this plan's `quantize(x)` (pools are "
                "only shareable across models with identical borders).")

    def _call(self, name: str, x) -> jax.Array:
        if self._lowered is None:
            self._ensure_prepared()
        if isinstance(x, QuantizedPool):
            self._check_pool(x)
            bins = x.bins
            if not (isinstance(bins, jax.Array)
                    and bins.dtype == jnp.uint8):
                bins = jnp.asarray(bins, jnp.uint8)
            return self._entries[name + "_pool"](bins)
        if not (isinstance(x, jax.Array) and x.dtype == jnp.float32):
            x = jnp.asarray(x, jnp.float32)   # skip no-op asarray dispatch
        return self._entries[name](x)

    # -- public entry points -----------------------------------------------
    def quantize(self, x) -> QuantizedPool:
        """Binarize a float batch once into a reusable `QuantizedPool`.

        (N, F) float -> uint8 pool; `raw/proba/classify` accept the
        pool and skip binarization entirely.  Pools are shareable
        across every plan whose ensemble has identical borders
        (`schema_fingerprint` guards this at score time)."""
        if self.ensemble.borders.shape[0] > MAX_BINS - 1:
            raise ValueError(
                f"cannot quantize to uint8 bins: ensemble has "
                f"{self.ensemble.borders.shape[0]} borders "
                f"(> {MAX_BINS - 1})")
        self._ensure_prepared()
        x = jnp.asarray(x, jnp.float32)
        return QuantizedPool(self._entries["quantize"](x),
                             self.schema_fingerprint)

    def raw(self, x) -> jax.Array:
        """(N, F) floats or a `QuantizedPool` -> (N, C) raw scores
        (tree sum + base score).  The pool path never binarizes."""
        return self._call("raw", x)

    def proba(self, x) -> jax.Array:
        """(N, F) floats or a `QuantizedPool` -> (N, max(C, 2))
        class probabilities."""
        return self._call("proba", x)

    def classify(self, x) -> jax.Array:
        """(N, F) floats or a `QuantizedPool` -> (N,) int32 class ids."""
        return self._call("classify", x)

    def raw_uncached(self, x) -> jax.Array:
        """Un-jitted raw scores — for callers that bring their own jit
        (the `core.predict` shim, shard_map bodies).  Accepts floats or
        a `QuantizedPool` like `raw`."""
        self._ensure_prepared()
        if isinstance(x, QuantizedPool):
            self._check_pool(x)
            return self._pool_raw_impl(jnp.asarray(x.bins, jnp.uint8))
        return self._raw_impl(jnp.asarray(x, jnp.float32))

    def _shard_raw(self, lw: LoweredEnsemble, data: jax.Array,
                   kind: str, cfg: PredictConfig) -> jax.Array:
        """Shard-local raw tree sum (no base score) over one lowered
        model — the body every mesh entry maps.  `lw` is the plan's
        own `LoweredEnsemble` (or one tree shard of it) passed through
        shard_map as a replicated/partitioned pytree, so the full
        registry dispatch — any layout, any backend — runs per shard.
        `kind` is "pool" (uint8 bins, binarize never dispatched) or
        "float"."""
        block_t = (cfg.block_t if cfg.strategy == "fused"
                   else STAGED_TREE_ALIGN)
        if kind == "pool":
            bins = ops.pad_features(data, lw.borders.shape[1])
            return lw.leaf_sum(bins, backend=cfg.backend, block_t=block_t)
        if cfg.strategy == "fused":
            return lw.fused_raw(data, backend=cfg.backend,
                                block_n=cfg.block_n, block_t=cfg.block_t)
        bins = ops.binarize_prepadded(data, lw.borders,
                                      backend=cfg.backend)
        return lw.leaf_sum(bins, backend=cfg.backend, block_t=block_t)

    def sharded(self, mesh, *, data_axes: Sequence[str] = ("data",),
                model_axis: str = "model",
                strategy: Optional[str] = None,
                shard_axis: str = "auto") -> Callable[[Any], jax.Array]:
        """Mesh-distributed raw scores over floats or a `QuantizedPool`.

        The plan's own `LoweredEnsemble` — whatever layout it resolved
        to: soa / depth_major / depth_grouped / bitpacked — flows into
        `shard_map` as a pytree, so every shard runs the exact same
        registry-dispatched kernels as the single-device plan:

          * **row sharding** (the bulk default): the model is
            replicated (`P()`), rows partition over `data_axes`; a
            `QuantizedPool` shards its uint8 bins directly — binarize
            is never dispatched (the pool contract), and the result is
            bit-for-bit the single-device plan's.
          * **tree sharding** (giant ensembles): `layout.shard_trees`
            splits the tree axis into neutral-padded equal slices,
            stacked over `model_axis`; shard partial sums combine with
            a `psum` (float re-association: parity ~1e-6, not exact).
          * **hybrid**: a mesh carrying both `data_axes` and
            `model_axis` shards rows *and* trees (PR-2's semantics,
            now on the lowered pytree).

        `shard_axis` ("rows" | "trees" | "auto") picks how a pure data
        mesh is used; "auto" asks `tuning.best_shard_axis` per batch.
        Row counts need not divide the mesh: ragged batches are padded
        to the row-shard multiple inside the jitted entry and sliced
        back (pad rows are zeros; they never reach the caller).

        `strategy` overrides the plan's strategy for the shard body
        (serving forces `staged` for auto-resolved plans).  The
        shard_map closures are built once per (mesh, axes, strategy,
        shard_axis) and cached on the plan; jit handles per-shape
        caching under that."""
        from repro.compat import shard_map

        key = (id(mesh), tuple(data_axes), model_axis, strategy,
               shard_axis)
        fn = self._sharded_cache.get(key)
        if fn is not None:
            return fn
        if shard_axis not in ("auto", "rows", "trees"):
            raise ValueError(f"shard_axis must be auto|rows|trees, "
                             f"got {shard_axis!r}")

        cfg = self.config
        if strategy is not None and strategy != cfg.strategy:
            cfg = dataclasses.replace(cfg, strategy=strategy)
            if not cfg.is_resolved:   # staged->fused needs block shapes
                cfg = cfg.resolve(self.ensemble)
        lowered = self._ensure_prepared()
        ens = self.ensemble
        t_align = (cfg.block_t if cfg.strategy == "fused"
                   else STAGED_TREE_ALIGN)

        axis_sizes = dict(mesh.shape)
        row_axes = tuple(a for a in data_axes if a in axis_sizes)
        tree_on_model = (model_axis in axis_sizes
                         and axis_sizes[model_axis] > 1)

        def _n_shards(axes):
            out = 1
            for a in axes:
                out *= int(axis_sizes[a])
            return out

        # mode -> (row axes, tree axes); "trees" on a pure data mesh
        # reuses the data axes as the model split
        modes: dict[str, tuple[tuple, tuple]] = {}
        if tree_on_model:
            modes["hybrid"] = (row_axes, (model_axis,))
            pick = lambda n: "hybrid"                     # noqa: E731
        elif shard_axis == "trees":
            modes["trees"] = ((), row_axes)
            pick = lambda n: "trees"                      # noqa: E731
        elif shard_axis == "rows" or _n_shards(row_axes) <= 1:
            modes["rows"] = (row_axes, ())
            pick = lambda n: "rows"                       # noqa: E731
        else:
            modes["rows"] = (row_axes, ())
            modes["trees"] = ((), row_axes)
            k = _n_shards(row_axes)

            def pick(n):
                return tuning.best_shard_axis(
                    n, ens.n_trees, k, n_outputs=ens.n_outputs,
                    leaf_table_bytes=lowered.leaf_table_bytes())

        entries: dict[tuple, Callable] = {}

        def _entry(mode: str, kind: str) -> Callable:
            cached = entries.get((mode, kind))
            if cached is not None:
                return cached
            r_axes, t_axes = modes[mode]
            n_row = _n_shards(r_axes)
            dp = P(r_axes) if r_axes else P()
            n_tree = _n_shards(t_axes)
            if n_tree > 1:
                stacked = layout_mod.stack_tree_shards(
                    layout_mod.shard_trees(lowered, n_tree,
                                           t_align=t_align))

                def _local(st, data):
                    lw = layout_mod.unstack_tree_shard(st)
                    return jax.lax.psum(
                        self._shard_raw(lw, data, kind, cfg), t_axes)

                smapped = shard_map(_local, mesh=mesh,
                                    in_specs=(P(t_axes), dp),
                                    out_specs=dp, check_rep=False)
                model_arg = stacked
            else:
                def _local(lw, data):
                    return self._shard_raw(lw, data, kind, cfg)

                smapped = shard_map(_local, mesh=mesh,
                                    in_specs=(P(), dp),
                                    out_specs=dp, check_rep=False)
                model_arg = lowered
            name = f"sharded_{kind}"

            def _impl(data):
                self._note_trace(name)
                with self._lock:
                    self._entry_shapes.add((name,) + tuple(data.shape))
                if _TRACER.enabled:
                    _TRACER.instant(f"compile/{name}", "compile",
                                    entry=name, layout=cfg.layout,
                                    batch=int(data.shape[0]),
                                    shard_mode=mode,
                                    row_shards=n_row,
                                    tree_shards=n_tree)
                n = data.shape[0]
                n_pad = -(-n // n_row) * n_row
                if n_pad != n:
                    data = ops._pad_dim(data, 0, n_pad, kind="data")
                out = ens.base_score[None, :] + smapped(model_arg, data)
                return out[:n] if n_pad != n else out

            jitted = jax.jit(_impl)
            entries[(mode, kind)] = jitted
            return jitted

        n_devices = int(np.prod([int(s) for s in axis_sizes.values()])) \
            if axis_sizes else 1

        def fn(x):
            if isinstance(x, QuantizedPool):
                self._check_pool(x)
                data = x.bins
                if not (isinstance(data, jax.Array)
                        and data.dtype == jnp.uint8):
                    data = jnp.asarray(data, jnp.uint8)
                kind = "pool"
            else:
                data = x
                if not (isinstance(data, jax.Array)
                        and data.dtype == jnp.float32):
                    data = jnp.asarray(data, jnp.float32)
                kind = "float"
            mode = pick(data.shape[0])
            if not _TRACER.enabled:
                return _entry(mode, kind)(data)
            with _TRACER.span(f"sharded/{kind}", "sharded",
                              shard_axis=mode, devices=n_devices,
                              rows=int(data.shape[0]),
                              layout=cfg.layout):
                return _entry(mode, kind)(data)

        self._sharded_cache[key] = fn
        return fn

    # -- introspection -----------------------------------------------------
    def _sharded_trace_impl(self, mesh, kind: str) -> Callable:
        """Un-jitted row-sharded raw impl over `mesh` (real or
        `AbstractMesh`) — the surface the contract checker's
        shard-parity pass abstract-traces.  Rows shard over every mesh
        axis, the lowered model replicates: the jaxpr must not
        all-gather the bins panel back onto one shard."""
        from repro.compat import shard_map

        lowered = self._ensure_prepared()
        cfg = self.config
        dp = P(tuple(mesh.axis_names))

        def _local(lw, data):
            return self._shard_raw(lw, data, kind, cfg)

        smapped = shard_map(_local, mesh=mesh, in_specs=(P(), dp),
                            out_specs=dp, check_rep=False)
        base = self.ensemble.base_score[None, :]
        return lambda data: base + smapped(lowered, data)

    def trace_entries(self, batch_sizes: Sequence[int] = (8,),
                      entries: Optional[Sequence[str]] = None, *,
                      mesh=None) -> dict[str, Any]:
        """Abstract traces (ClosedJaxprs) of the plan's entry points —
        the surface the contract checker's transfer/retrace lints walk.

        Traces the *un-jitted* impl methods with `jax.make_jaxpr` over
        ShapeDtypeStructs: nothing is compiled, `stats['traces']` does
        not tick, and repeat walks of the same (entry, batch shape)
        under the same quantization schema are served from a cache
        keyed like `QuantizedPool` scoring — on the borders
        fingerprint — so a re-lowered plan with identical borders
        reuses its traces.  Returns {"<entry>@<batch>": ClosedJaxpr}.

        Pool entries and `quantize` are skipped automatically when the
        ensemble exceeds the uint8 bin budget (they would raise at
        runtime too); pass `entries` to pin an explicit list.

        With `mesh` (a real mesh or a device-free `AbstractMesh`),
        the mesh-distributed entry points join the walk as
        `sharded_raw` / `sharded_raw_pool`, row-sharded over every
        mesh axis — the contract checker's shard-parity pass reads
        these; batch sizes must divide the mesh."""
        self._ensure_prepared()
        impls: dict[str, tuple[Callable, Any]] = {
            "raw": (self._raw_impl, jnp.float32),
            "proba": (self._proba_impl, jnp.float32),
            "classify": (self._classify_impl, jnp.float32),
            "raw_pool": (self._pool_raw_impl, jnp.uint8),
            "proba_pool": (self._pool_proba_impl, jnp.uint8),
            "classify_pool": (self._pool_classify_impl, jnp.uint8),
            "quantize": (self._quantize_impl, jnp.float32),
        }
        mesh_key = None
        if mesh is not None:
            mesh_key = tuple(sorted(dict(mesh.shape).items()))
            impls["sharded_raw"] = (
                self._sharded_trace_impl(mesh, "float"), jnp.float32)
            impls["sharded_raw_pool"] = (
                self._sharded_trace_impl(mesh, "pool"), jnp.uint8)
        if entries is None:
            names = list(impls)
            if self.ensemble.borders.shape[0] > MAX_BINS - 1:
                names = [n for n in names
                         if not n.endswith("_pool") and n != "quantize"]
        else:
            unknown = sorted(set(entries) - set(impls))
            if unknown:
                raise KeyError(f"unknown plan entries {unknown}; "
                               f"known: {sorted(impls)}")
            names = list(entries)
        fingerprint = self.schema_fingerprint
        out: dict[str, Any] = {}
        for name in names:
            impl, dtype = impls[name]
            for n in batch_sizes:
                aval = jax.ShapeDtypeStruct(
                    (int(n), self.ensemble.n_features), dtype)
                key = (name, aval.shape, str(aval.dtype), fingerprint,
                       mesh_key if name.startswith("sharded") else None)
                with self._lock:
                    closed = self._abstract_traces.get(key)
                if closed is None:
                    # trace outside the lock (tracing is slow and
                    # reentrant-safe); first writer wins
                    traced = jax.make_jaxpr(impl)(aval)
                    with self._lock:
                        closed = self._abstract_traces.setdefault(
                            key, traced)
                        if closed is traced:
                            self._abstract_trace_misses += 1
                out[f"{name}@{int(n)}"] = closed
        return out

    @property
    def stats(self) -> dict[str, Any]:
        """Plan-cache telemetry: XLA traces per entry point, distinct
        (entry, batch shape) cache keys seen, the physical layout the
        plan lowered to plus the one-time lowering cost (pad ops and
        wall-clock seconds) — what serving dashboards need to see what
        shipped."""
        with self._lock:
            return {
                "traces": dict(self._traces),
                "total_traces": sum(self._traces.values()),
                "cache_entries": len(self._entry_shapes),
                "entry_shapes": sorted(self._entry_shapes),
                "layout": self.config.layout,
                "lower_time_s": self._lower_time_s,
                "build_model_pads": self._build_model_pads,
                "abstract_traces": len(self._abstract_traces),
                "abstract_trace_misses": self._abstract_trace_misses,
            }

    def describe(self) -> dict[str, Any]:
        out = {**self.ensemble.describe(),
               "strategy": self.config.strategy,
               "backend": self.config.backend,
               "layout": self.config.layout,
               "tree_block": self.config.tree_block,
               "block_n": self.config.block_n,
               "block_t": self.config.block_t,
               "schema_fingerprint": self.schema_fingerprint}
        if self._lowered is not None:
            out["lowered"] = self._lowered.describe()
        return out

    def __repr__(self) -> str:
        c = self.config
        return (f"<Predictor {c.strategy}/{c.backend}/{c.layout} "
                f"trees={self.ensemble.n_trees} "
                f"depth={self.ensemble.depth} C={self.ensemble.n_outputs}>")


# --------------------------------------------------------------------------
# CatBoost JSON ingestion
# --------------------------------------------------------------------------
def load_catboost_json(path: str | pathlib.Path) -> ObliviousEnsemble:
    """Parse a CatBoost oblivious-tree JSON export into an ensemble.

    Reads the subset of `save_model(..., format="json")` the paper's
    workloads need: `features_info.float_features[*].borders`,
    `oblivious_trees[*].splits` (float splits only: feature index +
    border value) and flat `leaf_values`, plus `scale_and_bias`.

    Conventions mapped onto this repo's model:
      * split j of a tree contributes bit j of the leaf index
        (CatBoost lists splits bottom-up, matching `ref.leaf_index`)
      * CatBoost's `x > border` with border at sorted index k becomes
        `bins >= k + 1` in quantized space
      * trees shallower than the deepest are padded with always-left
        splits (`PAD_SPLIT_BIN`), their leaf values at indices < 2^d
      * `leaf_values` is length 2^d * dim, leaf-major
    """
    obj = json.loads(pathlib.Path(path).read_text())
    floats = obj.get("features_info", {}).get("float_features", [])
    if not floats:
        raise ValueError(f"{path}: no features_info.float_features — not a "
                         "CatBoost JSON model export?")
    trees = obj.get("oblivious_trees", [])
    if not trees:
        raise ValueError(f"{path}: no oblivious_trees (only oblivious-tree "
                         "models are supported)")
    for t, tree in enumerate(trees):
        if "splits" not in tree or "leaf_values" not in tree:
            raise ValueError(f"{path}: tree {t} is missing "
                             "splits/leaf_values — truncated export?")

    def flat_index(feat, i):
        return int(feat.get("flat_feature_index",
                            feat.get("feature_index", i)))

    n_features = 1 + max(flat_index(f, i) for i, f in enumerate(floats))
    per_feature: list[list[float]] = [[] for _ in range(n_features)]
    for i, f in enumerate(floats):
        per_feature[flat_index(f, i)] = [float(v)
                                         for v in (f.get("borders") or [])]

    depth = max(len(t["splits"]) for t in trees)
    if depth < 1:
        raise ValueError(f"{path}: model has splitless trees only")
    d0 = len(trees[0]["splits"])
    n_leaf0 = len(trees[0]["leaf_values"])
    if n_leaf0 % (1 << d0):
        raise ValueError(f"{path}: tree 0 has {n_leaf0} leaf values, not a "
                         f"multiple of 2^depth={1 << d0}")
    n_outputs = n_leaf0 // (1 << d0)

    n_trees = len(trees)
    sf = np.zeros((n_trees, depth), np.int32)
    sb = np.full((n_trees, depth), PAD_SPLIT_BIN, np.int32)
    lv = np.zeros((n_trees, 1 << depth, n_outputs), np.float32)
    for t, tree in enumerate(trees):
        splits = tree["splits"]
        d = len(splits)
        vals = np.asarray(tree["leaf_values"], np.float32)
        if vals.size != (1 << d) * n_outputs:
            raise ValueError(
                f"{path}: tree {t} has {vals.size} leaf values; expected "
                f"2^{d} * {n_outputs} (inconsistent approx dimension)")
        for j, s in enumerate(splits):
            stype = s.get("split_type", "FloatFeature")
            if stype != "FloatFeature":
                raise ValueError(f"{path}: tree {t} split {j} has type "
                                 f"{stype!r}; only FloatFeature is "
                                 "supported")
            fi = int(s.get("float_feature_index",
                           s.get("feature_index", -1)))
            if not 0 <= fi < n_features:
                raise ValueError(f"{path}: tree {t} split {j} references "
                                 f"feature {fi} outside [0, {n_features})")
            if "border" not in s:
                raise ValueError(f"{path}: tree {t} split {j} has no "
                                 "border value")
            border = float(s["border"])
            feature_borders = per_feature[fi]
            if not feature_borders:
                raise ValueError(f"{path}: tree {t} splits on feature {fi} "
                                 "which has no borders")
            k = int(np.argmin(np.abs(np.asarray(feature_borders) - border)))
            if not np.isclose(feature_borders[k], border,
                              rtol=1e-6, atol=1e-9):
                raise ValueError(
                    f"{path}: tree {t} split {j} border {border} not found "
                    f"among feature {fi}'s borders")
            sf[t, j] = fi
            sb[t, j] = k + 1
        lv[t, :1 << d, :] = vals.reshape(1 << d, n_outputs)

    scale, bias = 1.0, np.zeros((n_outputs,), np.float32)
    snb = obj.get("scale_and_bias")
    if snb:
        scale = float(snb[0])
        raw_bias = snb[1]
        if isinstance(raw_bias, (int, float)):
            raw_bias = [raw_bias]
        b = np.asarray(raw_bias, np.float32)
        if b.size == 1:
            bias = np.full((n_outputs,), float(b[0]), np.float32)
        elif b.size == n_outputs:
            bias = b
        else:
            raise ValueError(f"{path}: scale_and_bias bias has {b.size} "
                             f"entries for {n_outputs} outputs")

    n_borders = np.asarray([len(b) for b in per_feature], np.int32)
    max_b = max(1, int(n_borders.max()))
    borders = np.full((max_b, n_features), np.inf, np.float32)
    for fi, vals in enumerate(per_feature):
        borders[:len(vals), fi] = vals

    return ObliviousEnsemble(
        split_features=jnp.asarray(sf),
        split_bins=jnp.asarray(sb),
        leaf_values=jnp.asarray(lv * np.float32(scale)),
        borders=jnp.asarray(borders),
        n_borders=jnp.asarray(n_borders),
        base_score=jnp.asarray(bias),
    )
