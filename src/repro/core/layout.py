"""Physical ensemble layouts: the lowering layer between Predictor
plans and kernels.

The paper's biggest wins come from reorganizing how oblivious trees are
laid out for the vector unit — hoisting the per-depth pow2 vector out of
the CalcIndexes loop, grouping trees into blocks
(`CalcTreesBlockedImpl`), and streaming the binarized features in the
order the loop consumes them — not from any single intrinsic.  This
module makes that reorganization a first-class compilation step: a
logical `ObliviousEnsemble` is lowered **once** (at `Predictor.build`
time) into a `LoweredEnsemble`, a registered pytree whose arrays are in
the physical order a kernel family wants, pre-padded to that family's
block contracts.  Kernels consume lowered arrays; plans store them; the
tuner picks among them from the ensemble's shape.

Four layouts:

  soa            today's structure-of-arrays — (T, D) splits, one
                 (T, 2^Dmax, C) leaf table — the compatibility default.
  depth_major    splits transposed to (D, T) bit-plane order with the
                 one-hot feature-gather matrix onehot(sf) (T, D, F) and
                 the per-depth pow2 vector precomputed at lower time:
                 leaf_index kernels run a straight matmul instead of
                 rebuilding iota/one-hot per call (the paper's hoisting
                 trick applied to model structure).
  depth_grouped  trees bucketed by *true* depth — a depth-4 tree
                 carries a 16-entry leaf table instead of 2^Dmax —
                 evaluated group-by-group through the soa kernels and
                 summed (the paper's equal-depth tree grouping,
                 CalcTreesBlockedImpl at depth granularity).  Note the
                 per-group summation reassociates the float tree sum
                 (same addends, different order).
  bitpacked      depth-grouped structure with the split arrays
                 transposed to (d, T_d) bit planes in the narrowest
                 integer dtype that holds them: per depth the
                 comparison bins >= sb is ONE bit per doc, 32 docs pack
                 into a uint32 lane word (the paper's vmsgeu mask
                 register) and the `_bp` kernels assemble leaf indexes
                 via integer shift/or — no one-hot, no f32, no MXU
                 until the leaf gather.  For binary-split schemas
                 (<= 1 border per feature) the uint8 pool itself packs
                 into u1 feature planes — `pack_pool_u1` — an 8x pool
                 memory shrink.

Every layout is bit-for-bit the same *math* as the logical model:
identical leaf indices, identical per-tree leaf values.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ops import FEATURE_ALIGN, PAD_SPLIT_BIN

# T-axis alignment of the prepadded staged path (the leaf_index /
# leaf_gather kernels' default tree block).  Canonical definition —
# `core.predictor` re-exports it.
STAGED_TREE_ALIGN = 16


def is_concrete(ensemble) -> bool:
    """Whether the ensemble's structure arrays are concrete (lowerings
    that regroup trees need to *read* split_bins; per-shard plans built
    inside shard_map hold tracers and must stay on "soa")."""
    return not isinstance(ensemble.split_bins, jax.core.Tracer)


# --------------------------------------------------------------------------
# Lowered layouts (registered pytrees; unflatten bypasses __init__ so
# tree ops never re-run lowering-time logic — same scheme as
# `trees.ObliviousEnsemble`)
# --------------------------------------------------------------------------
def _register_lowered(cls, data_fields: tuple[str, ...],
                      meta_fields: tuple[str, ...] = ()):
    def flatten_with_keys(obj):
        children = tuple((jax.tree_util.GetAttrKey(f), getattr(obj, f))
                         for f in data_fields)
        return children, tuple(getattr(obj, f) for f in meta_fields)

    def flatten(obj):
        return (tuple(getattr(obj, f) for f in data_fields),
                tuple(getattr(obj, f) for f in meta_fields))

    def unflatten(aux, children):
        obj = object.__new__(cls)
        for f, c in zip(data_fields, children):
            object.__setattr__(obj, f, c)
        for f, v in zip(meta_fields, aux):
            object.__setattr__(obj, f, v)
        return obj

    jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys,
                                            unflatten, flatten)
    return cls


@dataclasses.dataclass(frozen=True)
class SoaLayout:
    """Structure-of-arrays, a single shared depth (today's layout)."""
    layout_name = "soa"
    borders: jax.Array           # (B, Fp) f32
    split_features: jax.Array    # (Tp, D) i32
    split_bins: jax.Array        # (Tp, D) i32
    leaf_values: jax.Array       # (Tp, L, C) f32
    # staged tree blocking: per-block (sf, sb, lv) slices, pre-cut and
    # pre-padded at lower time so the per-call loop never touches jnp.pad
    tree_blocks: Optional[tuple] = None
    n_outputs: int = 1           # static
    n_model_pads: int = 0        # static: model-side pads spent lowering

    def leaf_sum(self, bins: jax.Array, *, backend: str,
                 block_t: int) -> jax.Array:
        if self.tree_blocks is not None:
            # CalcTreesBlockedImpl with the block slices cut at lower time
            acc = jnp.zeros((bins.shape[0], self.n_outputs), jnp.float32)
            for sf, sb, lv in self.tree_blocks:
                idx = ops.leaf_index_prepadded(bins, sf, sb,
                                               backend=backend,
                                               block_t=block_t)
                acc = acc + ops.leaf_gather_prepadded(idx, lv,
                                                      backend=backend,
                                                      block_t=block_t)
            return acc
        idx = ops.leaf_index_prepadded(bins, self.split_features,
                                       self.split_bins, backend=backend,
                                       block_t=block_t)
        return ops.leaf_gather_prepadded(idx, self.leaf_values,
                                         backend=backend, block_t=block_t)

    def fused_raw(self, x: jax.Array, *, backend: str, block_n: int,
                  block_t: int) -> jax.Array:
        return ops.fused_predict_prepadded(
            x, self.borders, self.split_features, self.split_bins,
            self.leaf_values, backend=backend, block_n=block_n,
            block_t=block_t)

    def leaf_table_bytes(self) -> int:
        return int(np.prod(self.leaf_values.shape)) * 4

    def describe(self) -> dict[str, Any]:
        return {"layout": self.layout_name,
                "leaf_table_bytes": self.leaf_table_bytes(),
                "tree_blocks": (len(self.tree_blocks)
                                if self.tree_blocks else 0)}


@dataclasses.dataclass(frozen=True)
class DepthMajorLayout:
    """Bit-plane order with the feature-gather one-hot precomputed."""
    layout_name = "depth_major"
    borders: jax.Array           # (B, Fp) f32
    onehot: jax.Array            # (Tp, D, Fp) f32 — onehot(sf[t, d])
    split_bins_dm: jax.Array     # (D, Tp) i32 — bit-plane transposed
    pow2: jax.Array              # (D, 1) f32 — hoisted 2^d vector
    leaf_values: jax.Array       # (Tp, L, C) f32
    n_outputs: int = 1           # static
    n_model_pads: int = 0        # static

    def leaf_sum(self, bins: jax.Array, *, backend: str,
                 block_t: int) -> jax.Array:
        idx = ops.leaf_index_dm_prepadded(bins, self.onehot,
                                          self.split_bins_dm, self.pow2,
                                          backend=backend, block_t=block_t)
        return ops.leaf_gather_prepadded(idx, self.leaf_values,
                                         backend=backend, block_t=block_t)

    def fused_raw(self, x: jax.Array, *, backend: str, block_n: int,
                  block_t: int) -> jax.Array:
        return ops.fused_predict_dm_prepadded(
            x, self.borders, self.onehot, self.split_bins_dm, self.pow2,
            self.leaf_values, backend=backend, block_n=block_n,
            block_t=block_t)

    def leaf_table_bytes(self) -> int:
        return int(np.prod(self.leaf_values.shape)) * 4

    def onehot_bytes(self) -> int:
        return int(np.prod(self.onehot.shape)) * 4

    def describe(self) -> dict[str, Any]:
        return {"layout": self.layout_name,
                "leaf_table_bytes": self.leaf_table_bytes(),
                "onehot_bytes": self.onehot_bytes()}


@dataclasses.dataclass(frozen=True)
class DepthGroup:
    """All trees of one true depth, sliced to that depth's shapes."""
    depth: int                   # static: true depth d of the group
    split_features: jax.Array    # (Tg_p, d) i32
    split_bins: jax.Array        # (Tg_p, d) i32
    leaf_values: jax.Array       # (Tg_p, 2^d, C) f32

    @property
    def n_trees(self) -> int:
        return self.split_features.shape[0]


@dataclasses.dataclass(frozen=True)
class DepthGroupedLayout:
    """Trees bucketed by true depth; shallow trees carry small tables."""
    layout_name = "depth_grouped"
    borders: jax.Array           # (B, Fp) f32
    groups: tuple                # tuple[DepthGroup, ...], depth ascending
    n_outputs: int = 1           # static
    n_model_pads: int = 0        # static

    def leaf_sum(self, bins: jax.Array, *, backend: str,
                 block_t: int) -> jax.Array:
        acc = jnp.zeros((bins.shape[0], self.n_outputs), jnp.float32)
        for g in self.groups:
            idx = ops.leaf_index_prepadded(bins, g.split_features,
                                           g.split_bins, backend=backend,
                                           block_t=block_t)
            acc = acc + ops.leaf_gather_prepadded(idx, g.leaf_values,
                                                  backend=backend,
                                                  block_t=block_t)
        return acc

    def fused_raw(self, x: jax.Array, *, backend: str, block_n: int,
                  block_t: int) -> jax.Array:
        # Running the fused kernel once per group would re-execute its
        # stage 1 (binarize x against every border) G times — exactly
        # the work the grouping is supposed to shrink.  Binarize once
        # and reuse the grouped index+gather loop instead; with more
        # than one group this strictly dominates per-group fusion.
        bins = ops.binarize_prepadded(x, self.borders, backend=backend)
        return self.leaf_sum(bins, backend=backend, block_t=block_t)

    def leaf_table_bytes(self) -> int:
        return sum(int(np.prod(g.leaf_values.shape)) * 4
                   for g in self.groups)

    def describe(self) -> dict[str, Any]:
        return {"layout": self.layout_name,
                "leaf_table_bytes": self.leaf_table_bytes(),
                "groups": {int(g.depth): int(g.n_trees)
                           for g in self.groups}}


@dataclasses.dataclass(frozen=True)
class BitpackedGroup:
    """All trees of one true depth, split arrays in bit-plane order."""
    depth: int                   # static: true depth d of the group
    split_features_bp: jax.Array  # (d, Tg_p) i32 — bit-plane transposed
    split_bins_bp: jax.Array     # (d, Tg_p) u8 when thresholds fit, else i32
    leaf_values: jax.Array       # (Tg_p, 2^d, C) f32

    @property
    def n_trees(self) -> int:
        return self.split_features_bp.shape[1]


@dataclasses.dataclass(frozen=True)
class BitpackedLayout:
    """Depth groups with integer bit-plane splits (the paper's
    word-packed comparison loop): leaf indexes assemble via shift/or,
    32-doc comparison bits pack into uint32 lanes, nothing touches f32
    until the leaf gather."""
    layout_name = "bitpacked"
    borders: jax.Array           # (B, Fp) f32
    groups: tuple                # tuple[BitpackedGroup, ...], depth asc
    n_outputs: int = 1           # static
    n_model_pads: int = 0        # static
    binary_split: bool = False   # static: every feature has <= 1 border
    n_features: int = 0          # static: logical pool width F

    def leaf_sum(self, bins: jax.Array, *, backend: str,
                 block_t: int) -> jax.Array:
        acc = jnp.zeros((bins.shape[0], self.n_outputs), jnp.float32)
        for g in self.groups:
            idx = ops.leaf_index_bp_prepadded(bins, g.split_features_bp,
                                              g.split_bins_bp,
                                              backend=backend,
                                              block_t=block_t)
            acc = acc + ops.leaf_gather_prepadded(idx, g.leaf_values,
                                                  backend=backend,
                                                  block_t=block_t)
        return acc

    def fused_raw(self, x: jax.Array, *, backend: str, block_n: int,
                  block_t: int) -> jax.Array:
        if len(self.groups) == 1:
            g = self.groups[0]
            return ops.fused_predict_bp_prepadded(
                x, self.borders, g.split_features_bp, g.split_bins_bp,
                g.leaf_values, backend=backend, block_n=block_n,
                block_t=block_t)
        # multiple groups: binarize once and reuse the grouped
        # index+gather loop (same rationale as DepthGroupedLayout —
        # per-group fusion would re-binarize x against every border
        # once per group)
        bins = ops.binarize_prepadded(x, self.borders, backend=backend)
        return self.leaf_sum(bins, backend=backend, block_t=block_t)

    def leaf_table_bytes(self) -> int:
        return sum(int(np.prod(g.leaf_values.shape)) * 4
                   for g in self.groups)

    def plane_bytes(self) -> int:
        """Bytes held by the split bit planes (both arrays, all groups)."""
        return sum(int(np.prod(g.split_features_bp.shape))
                   * g.split_features_bp.dtype.itemsize
                   + int(np.prod(g.split_bins_bp.shape))
                   * g.split_bins_bp.dtype.itemsize
                   for g in self.groups)

    def pool_row_bytes(self) -> tuple[int, int]:
        """(uint8 bytes, u1-plane bytes) one quantized pool row costs.

        The u1 figure — ceil(F/32) uint32 words — is achievable only
        for binary-split schemas (`binary_split`), where every bin id
        is 0/1 and `pack_pool_u1` packs the pool losslessly: the 8x
        pool-memory shrink of the paper's single-border case.
        """
        f = max(int(self.n_features), 1)
        return f, -(-f // 32) * 4

    def describe(self) -> dict[str, Any]:
        u8, u1 = self.pool_row_bytes()
        return {"layout": self.layout_name,
                "leaf_table_bytes": self.leaf_table_bytes(),
                "plane_bytes": self.plane_bytes(),
                "groups": {int(g.depth): int(g.n_trees)
                           for g in self.groups},
                "binary_split": self.binary_split,
                "pool_row_bytes_u8": u8,
                "pool_row_bytes_u1": u1,
                "pool_shrink_x": (u8 / u1) if self.binary_split else 1.0}


def _pad_tree_axis(a, axis: int, target: int, value=0):
    return ops._pad_dim(a, axis, target, value=value, kind="model")


def _shard_bounds(n_trees: int, n_shards: int, t_align: int):
    """(padded total, per-shard size) for an equal T-axis split where
    every shard stays a `t_align` multiple."""
    unit = max(n_shards * max(t_align, 1), 1)
    total = ops._round_up(max(n_trees, 1), unit)
    return total, total // n_shards


def shard_trees(lowered: LoweredEnsemble, n_shards: int, *,
                t_align: int = 1) -> list:
    """Split a lowered ensemble's tree axis into `n_shards` equal
    slices for mesh model-parallel evaluation.

    Every shard is the same layout class with identical shapes and
    identical static metadata, so the shards stack into one leading
    mesh axis (`stack_tree_shards`) and flow through `shard_map` with
    `PartitionSpec(model_axis)` on every leaf.  Slices are padded with
    *neutral* trees — split features 0, split bins `PAD_SPLIT_BIN`
    (always-left), all-zero leaf rows — so a padded tree contributes
    exactly 0.0 and

        sum_k shard_k.leaf_sum(bins)  ==  lowered.leaf_sum(bins)

    up to float re-association: the per-shard partial sums reduce in a
    different order than the single-device tree loop, so tree-sharded
    results match at ~1e-6, not bit-for-bit (the row-sharded data path
    keeps exact equality — see docs/distributed.md).

    Grouped layouts (depth_grouped / bitpacked) shard *within* each
    depth group: every shard keeps the full group list (same static
    depths, same jaxpr) with 1/K of each group's trees.
    """
    if n_shards <= 1:
        return [lowered]
    if isinstance(lowered, SoaLayout):
        if lowered.tree_blocks is not None:
            raise ValueError(
                "shard_trees on a tree-blocked soa plan is unsupported: "
                "the block slices were cut for the single-device loop; "
                "lower with tree_block=0 before tree-sharding")
        total, per = _shard_bounds(lowered.split_features.shape[0],
                                   n_shards, t_align)
        sf = _pad_tree_axis(lowered.split_features, 0, total)
        sb = _pad_tree_axis(lowered.split_bins, 0, total,
                            value=PAD_SPLIT_BIN)
        lv = _pad_tree_axis(lowered.leaf_values, 0, total)
        return [SoaLayout(lowered.borders,
                          sf[k * per:(k + 1) * per],
                          sb[k * per:(k + 1) * per],
                          lv[k * per:(k + 1) * per], None,
                          n_outputs=lowered.n_outputs,
                          n_model_pads=lowered.n_model_pads)
                for k in range(n_shards)]
    if isinstance(lowered, DepthMajorLayout):
        total, per = _shard_bounds(lowered.onehot.shape[0], n_shards,
                                   t_align)
        oh = _pad_tree_axis(lowered.onehot, 0, total)
        sb = _pad_tree_axis(lowered.split_bins_dm, 1, total,
                            value=PAD_SPLIT_BIN)
        lv = _pad_tree_axis(lowered.leaf_values, 0, total)
        return [DepthMajorLayout(lowered.borders,
                                 oh[k * per:(k + 1) * per],
                                 sb[:, k * per:(k + 1) * per],
                                 lowered.pow2,
                                 lv[k * per:(k + 1) * per],
                                 n_outputs=lowered.n_outputs,
                                 n_model_pads=lowered.n_model_pads)
                for k in range(n_shards)]
    if isinstance(lowered, DepthGroupedLayout):
        shard_groups = [[] for _ in range(n_shards)]
        for g in lowered.groups:
            total, per = _shard_bounds(g.n_trees, n_shards, t_align)
            sf = _pad_tree_axis(g.split_features, 0, total)
            sb = _pad_tree_axis(g.split_bins, 0, total,
                                value=PAD_SPLIT_BIN)
            lv = _pad_tree_axis(g.leaf_values, 0, total)
            for k in range(n_shards):
                shard_groups[k].append(
                    DepthGroup(g.depth, sf[k * per:(k + 1) * per],
                               sb[k * per:(k + 1) * per],
                               lv[k * per:(k + 1) * per]))
        return [DepthGroupedLayout(lowered.borders, tuple(gs),
                                   n_outputs=lowered.n_outputs,
                                   n_model_pads=lowered.n_model_pads)
                for gs in shard_groups]
    if isinstance(lowered, BitpackedLayout):
        shard_groups = [[] for _ in range(n_shards)]
        for g in lowered.groups:
            total, per = _shard_bounds(g.n_trees, n_shards, t_align)
            sf = _pad_tree_axis(g.split_features_bp, 1, total)
            # uint8 planes can't hold PAD_SPLIT_BIN; pad 0 instead —
            # the padded trees' leaf rows are all-zero, so whichever
            # leaf the always-true comparison selects contributes 0.0
            pad_bin = (0 if g.split_bins_bp.dtype == jnp.uint8
                       else PAD_SPLIT_BIN)
            sb = _pad_tree_axis(g.split_bins_bp, 1, total, value=pad_bin)
            lv = _pad_tree_axis(g.leaf_values, 0, total)
            for k in range(n_shards):
                shard_groups[k].append(
                    BitpackedGroup(g.depth, sf[:, k * per:(k + 1) * per],
                                   sb[:, k * per:(k + 1) * per],
                                   lv[k * per:(k + 1) * per]))
        return [BitpackedLayout(lowered.borders, tuple(gs),
                                n_outputs=lowered.n_outputs,
                                n_model_pads=lowered.n_model_pads,
                                binary_split=lowered.binary_split,
                                n_features=lowered.n_features)
                for gs in shard_groups]
    raise TypeError(f"shard_trees: unsupported lowered type "
                    f"{type(lowered).__name__}")


def stack_tree_shards(shards: list):
    """Stack per-shard lowered ensembles (from `shard_trees`) into one
    pytree with a leading mesh axis on every array leaf, ready for
    `shard_map` with `in_specs=P(model_axis)`.  The shard body peels
    the unit leading axis back off with `unstack_tree_shard`."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *shards)


def unstack_tree_shard(stacked):
    """Drop the unit leading mesh axis `shard_map` leaves on every
    array of a stacked shard (inverse of `stack_tree_shards` inside
    the mapped body)."""
    return jax.tree_util.tree_map(lambda a: a[0], stacked)


def pack_pool_u1(bins: jax.Array) -> jax.Array:
    """Pack a binary-split quantized pool (N, F) of 0/1 bins into u1
    feature planes -> (N, ceil(F/32)) uint32.

    Only valid when every bin id is 0 or 1 (<= 1 border per feature —
    `BitpackedLayout.binary_split`); ragged feature tails are
    zero-padded lanes.  One row shrinks from F bytes to ceil(F/32)
    words: the paper's 8x pool-memory reduction for binary splits.
    """
    from repro.kernels import ref
    return jnp.transpose(ref.pack_bits(jnp.transpose(bins)))


def unpack_pool_u1(planes: jax.Array, n_features: int) -> jax.Array:
    """Inverse of `pack_pool_u1` -> (N, n_features) int32 bins."""
    from repro.kernels import ref
    return jnp.transpose(ref.unpack_bits(jnp.transpose(planes), n_features))


_register_lowered(SoaLayout,
                  ("borders", "split_features", "split_bins",
                   "leaf_values", "tree_blocks"),
                  ("n_outputs", "n_model_pads"))
_register_lowered(DepthMajorLayout,
                  ("borders", "onehot", "split_bins_dm", "pow2",
                   "leaf_values"),
                  ("n_outputs", "n_model_pads"))
_register_lowered(DepthGroup,
                  ("split_features", "split_bins", "leaf_values"),
                  ("depth",))
_register_lowered(DepthGroupedLayout,
                  ("borders", "groups"),
                  ("n_outputs", "n_model_pads"))
_register_lowered(BitpackedGroup,
                  ("split_features_bp", "split_bins_bp", "leaf_values"),
                  ("depth",))
_register_lowered(BitpackedLayout,
                  ("borders", "groups"),
                  ("n_outputs", "n_model_pads", "binary_split",
                   "n_features"))

# The union type plans hold.
LoweredEnsemble = (SoaLayout | DepthMajorLayout | DepthGroupedLayout
                   | BitpackedLayout)


# --------------------------------------------------------------------------
# Layout registry (capability metadata for tuning / docs / CI)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    name: str
    cls: type
    paper_analog: str            # which paper mechanism it encodes
    claimed_ops: tuple[str, ...]  # kernel ops the layout needs impls for
    memory: str                  # memory-cost note (docs table)
    when: str                    # when the tuner picks it

LAYOUTS: dict[str, LayoutSpec] = {
    "soa": LayoutSpec(
        name="soa", cls=SoaLayout,
        paper_analog="CatBoost SoA model arrays (compatibility default)",
        claimed_ops=("binarize", "leaf_index", "leaf_gather",
                     "fused_predict"),
        memory="T x 2^Dmax x C leaf table; (T, D) splits",
        when="uniform shallow models; tracer ensembles (sharded shards)"),
    "depth_major": LayoutSpec(
        name="depth_major", cls=DepthMajorLayout,
        paper_analog="hoisted pow2 / vmsgeu bit-plane loop (CalcIndexes)",
        claimed_ops=("binarize", "leaf_index", "leaf_gather",
                     "fused_predict"),
        memory="soa + T x D x F f32 one-hot gather matrix",
        when="uniform-depth models whose one-hot matrix fits the budget"),
    "depth_grouped": LayoutSpec(
        name="depth_grouped", cls=DepthGroupedLayout,
        paper_analog="equal-depth tree grouping (CalcTreesBlockedImpl)",
        claimed_ops=("binarize", "leaf_index", "leaf_gather",
                     "fused_predict"),
        memory="sum_d T_d x 2^d x C leaf tables (< soa when depths mix)",
        when="mixed true depths with enough shallow-tree savings"),
    "bitpacked": LayoutSpec(
        name="bitpacked", cls=BitpackedLayout,
        paper_analog="word-packed comparison loop (vmsgeu mask word + "
                     "integer shift/or index assembly)",
        claimed_ops=("binarize", "leaf_index", "leaf_gather",
                     "fused_predict"),
        memory="grouped leaf tables + 2 x (d, T_d) integer bit planes; "
               "u1 pool planes when binary-split",
        when="mixed depths whose one-hot/f32 working set blows the "
             "VMEM budget"),
}

LAYOUT_NAMES = tuple(LAYOUTS)


def format_layout_table() -> str:
    """The layout matrix as a markdown table (docs/layouts.md embeds
    this; `launch.serve --show-kernels` prints it)."""
    cols = ("layout", "paper analog", "memory cost", "when tuning picks it")
    rows = [(s.name, s.paper_analog, s.memory, s.when)
            for s in LAYOUTS.values()]
    widths = [max(len(c), *(len(r[i]) for r in rows))
              for i, c in enumerate(cols)]
    def line(vals):
        return "| " + " | ".join(v.ljust(w)
                                 for v, w in zip(vals, widths)) + " |"
    out = [line(cols), "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
    out += [line(r) for r in rows]
    return "\n".join(out)


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------
def lower(ensemble, layout: str = "soa", *, backend: str = "ref",
          t_align: int = STAGED_TREE_ALIGN,
          tree_block: int = 0) -> LoweredEnsemble:
    """Lower a logical `ObliviousEnsemble` into one physical layout.

    The one-time step `Predictor.build` runs: every model-side pad
    (feature axis to the lane width for pallas, tree axis to the kernel
    block) and every structure-derived array (one-hot gather matrix,
    bit-plane transposes, depth groups) is materialized here, so the
    per-call kernels only ever touch data-side padding.

    `backend` decides the padding contract ("pallas" pads to block
    multiples; anything else keeps exact shapes — the jnp reference
    kernels accept any shape, so padding would only add wasted math).
    `t_align` is the tree-axis block (the fused plan's block_t, or
    `STAGED_TREE_ALIGN`); `tree_block` enables the staged soa
    tree-blocked loop (soa layout only).
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; known: "
                         f"{LAYOUT_NAMES}")
    ctx = _LowerCtx(pallas=backend == "pallas", t_align=t_align)
    if layout == "soa":
        return _lower_soa(ensemble, ctx, tree_block)
    if layout == "depth_major":
        return _lower_depth_major(ensemble, ctx)
    if layout == "bitpacked":
        return _lower_bitpacked(ensemble, ctx)
    return _lower_depth_grouped(ensemble, ctx)


class _LowerCtx:
    """Pad helper counting model-side pad ops locally (the global
    `ops.pad_stats` counter may tick from other threads concurrently)."""

    def __init__(self, *, pallas: bool, t_align: int):
        self.pallas = pallas
        self.t_align = max(int(t_align), 1)
        self.n_pads = 0

    def pad(self, a, axis, target, value=0):
        out = ops._pad_dim(a, axis, target, value=value, kind="model")
        if out is not a:
            self.n_pads += 1
        return out

    def pad_borders(self, borders):
        if not self.pallas:
            return borders
        fp = ops._round_up(max(borders.shape[1], 1), FEATURE_ALIGN)
        return self.pad(borders, 1, fp, value=np.float32(np.inf))

    def pad_trees(self, sf, sb, lv):
        if not self.pallas:
            return sf, sb, lv
        tp = ops._round_up(max(sf.shape[0], 1), self.t_align)
        return (self.pad(sf, 0, tp), self.pad(sb, 0, tp,
                                              value=PAD_SPLIT_BIN),
                self.pad(lv, 0, tp))


def _lower_soa(ensemble, ctx: _LowerCtx, tree_block: int) -> SoaLayout:
    borders = ctx.pad_borders(ensemble.borders)
    if tree_block and ensemble.n_trees > tree_block:
        blocks = []
        for start in range(0, ensemble.n_trees, tree_block):
            blk = ensemble.slice_trees(
                start, min(start + tree_block, ensemble.n_trees))
            blocks.append(ctx.pad_trees(blk.split_features, blk.split_bins,
                                        blk.leaf_values))
        # the blocked path never reads the whole-ensemble arrays, so keep
        # the (unpadded) originals rather than holding a second padded
        # copy of the full model
        return SoaLayout(borders, ensemble.split_features,
                         ensemble.split_bins, ensemble.leaf_values,
                         tuple(blocks), n_outputs=ensemble.n_outputs,
                         n_model_pads=ctx.n_pads)
    sf, sb, lv = ctx.pad_trees(ensemble.split_features, ensemble.split_bins,
                               ensemble.leaf_values)
    return SoaLayout(borders, sf, sb, lv, None,
                     n_outputs=ensemble.n_outputs, n_model_pads=ctx.n_pads)


def _lower_depth_major(ensemble, ctx: _LowerCtx) -> DepthMajorLayout:
    borders = ctx.pad_borders(ensemble.borders)
    sf, sb, lv = ctx.pad_trees(ensemble.split_features, ensemble.split_bins,
                               ensemble.leaf_values)
    D = ensemble.depth
    Fp = borders.shape[1]
    # The per-call iota/one-hot the soa kernels rebuild, materialized
    # once: row (t, d) of the gather matrix selects feature sf[t, d].
    f_ids = jnp.arange(Fp, dtype=jnp.int32)[None, None, :]
    onehot = (f_ids == sf[:, :, None]).astype(jnp.float32)   # (Tp, D, Fp)
    pow2 = jnp.asarray((1 << np.arange(D, dtype=np.int64))
                       .astype(np.float32)[:, None])          # (D, 1)
    return DepthMajorLayout(borders, onehot, jnp.transpose(sb), pow2, lv,
                            n_outputs=ensemble.n_outputs,
                            n_model_pads=ctx.n_pads)


def _lower_depth_grouped(ensemble, ctx: _LowerCtx) -> DepthGroupedLayout:
    if not is_concrete(ensemble):
        raise ValueError(
            "depth_grouped lowering reads split_bins to bucket trees by "
            "true depth; the ensemble holds tracers (per-shard plans "
            "inside shard_map must lower to 'soa')")
    borders = ctx.pad_borders(ensemble.borders)
    # Depth-0 trees (every level padded) still need one always-left
    # level so the kernels see D >= 1; their single reachable leaf is
    # index 0 either way.
    depths = np.maximum(np.asarray(ensemble.true_depths), 1)
    sf = np.asarray(ensemble.split_features)
    sb = np.asarray(ensemble.split_bins)
    lv = np.asarray(ensemble.leaf_values)
    groups = []
    for d in sorted(set(int(v) for v in depths)):
        rows = np.flatnonzero(depths == d)
        gsf = jnp.asarray(sf[rows][:, :d])
        gsb = jnp.asarray(sb[rows][:, :d])
        # trailing pad levels always go left, so only the first 2^d
        # leaves are reachable — the shallow tree's actual table
        glv = jnp.asarray(lv[rows][:, :1 << d])
        gsf, gsb, glv = ctx.pad_trees(gsf, gsb, glv)
        groups.append(DepthGroup(d, gsf, gsb, glv))
    return DepthGroupedLayout(borders, tuple(groups),
                              n_outputs=ensemble.n_outputs,
                              n_model_pads=ctx.n_pads)


def _lower_bitpacked(ensemble, ctx: _LowerCtx) -> BitpackedLayout:
    if not is_concrete(ensemble):
        raise ValueError(
            "bitpacked lowering reads split_bins to bucket trees and "
            "narrow threshold planes; the ensemble holds tracers "
            "(per-shard plans inside shard_map must lower to 'soa')")
    borders = ctx.pad_borders(ensemble.borders)
    # Same depth bucketing as depth_grouped (depth-0 trees clamp to one
    # always-left level), then each group's split arrays transpose to
    # (d, Tg_p) bit-plane order.  Threshold planes narrow to uint8 when
    # every value fits — comparing uint8 bins against a uint8 plane
    # never widens the gathered panel — but pallas lowering pads trees
    # with PAD_SPLIT_BIN (2^30), which only int32 can hold.
    depths = np.maximum(np.asarray(ensemble.true_depths), 1)
    sf = np.asarray(ensemble.split_features)
    sb = np.asarray(ensemble.split_bins)
    lv = np.asarray(ensemble.leaf_values)
    groups = []
    for d in sorted(set(int(v) for v in depths)):
        rows = np.flatnonzero(depths == d)
        gsf_np = sf[rows][:, :d]
        gsb_np = sb[rows][:, :d]
        narrow = not ctx.pallas and gsb_np.size and gsb_np.max() <= 255 \
            and gsb_np.min() >= 0
        gsf, gsb, glv = ctx.pad_trees(jnp.asarray(gsf_np),
                                      jnp.asarray(gsb_np),
                                      jnp.asarray(lv[rows][:, :1 << d]))
        gsb_bp = jnp.transpose(gsb)
        if narrow:
            gsb_bp = gsb_bp.astype(jnp.uint8)
        groups.append(BitpackedGroup(d, jnp.transpose(gsf), gsb_bp, glv))
    n_borders = np.asarray(ensemble.n_borders)
    return BitpackedLayout(borders, tuple(groups),
                           n_outputs=ensemble.n_outputs,
                           n_model_pads=ctx.n_pads,
                           binary_split=bool((n_borders <= 1).all()),
                           n_features=int(ensemble.borders.shape[1]))
