"""Functional GBDT prediction — thin shims over `core.predictor`.

.. deprecated::
    The kwarg-threaded functions here (`raw_predict` & co. with
    `strategy=` / `backend=` / `tree_block=` / `block_n=` / `block_t=`)
    build a throwaway one-shot plan per call: the model arrays are
    re-padded and the block tuner re-run every time.  They keep every
    old signature working, but new code — and anything that predicts
    more than once per model — should build the plan once:

        from repro.core.predictor import PredictConfig, Predictor
        plan = Predictor.build(ensemble, PredictConfig(strategy="fused"))
        plan.raw(x); plan.proba(x); plan.classify(x)
        plan.sharded(mesh)(x)

    See docs/api.md for the migration table.

Pipeline (paper fig. 1): BinarizeFeatures -> CalcTreesBlockedImpl
{ CalcIndexesBasic -> CalculateLeafValues[Multi] } with every stage mapped
to a kernel op.  Strategies: staged (paper-faithful three passes), fused
(single Pallas pass), auto (fused on TPU, staged-ref on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.predictor import (PredictConfig, Predictor, Strategy,
                                  classify_from_raw, proba_from_raw)
from repro.core.quantize import QuantizedPool
from repro.core.trees import ObliviousEnsemble


def _one_shot(ensemble: ObliviousEnsemble, x, strategy, backend,
              tree_block, block_n, block_t) -> Predictor:
    """One-shot plan for the legacy kwarg path.  Per-call preparation is
    exactly what `Predictor.build` exists to hoist — acceptable here
    because this shim is documented as the slow compatibility path."""
    n = len(x) if isinstance(x, QuantizedPool) else x.shape[0]
    return Predictor.build(
        ensemble,
        PredictConfig(strategy=strategy, backend=backend,
                      tree_block=tree_block, block_n=block_n,
                      block_t=block_t),
        expected_batch=n)


def raw_predict(ensemble: ObliviousEnsemble, x, *,
                strategy: Strategy = "auto",
                backend: str = "auto",
                tree_block: int = 0,
                block_n: int | None = None,
                block_t: int | None = None) -> jax.Array:
    """(N, F) float32 — or a `QuantizedPool` — -> (N, C) float32 raw
    scores (sum over trees); the pool path skips binarization.

    Deprecated kwarg path — see the module docstring; prefer
    `Predictor.build(...).raw(x)` (and `plan.quantize(x)` for the
    quantize-once workflow).
    """
    plan = _one_shot(ensemble, x, strategy, backend, tree_block,
                     block_n, block_t)
    return plan.raw_uncached(x)


def predict_proba(ensemble: ObliviousEnsemble, x: jax.Array, **kw) -> jax.Array:
    """Deprecated kwarg path; prefer `Predictor.build(...).proba(x)`."""
    return proba_from_raw(raw_predict(ensemble, x, **kw),
                          ensemble.n_outputs)


def predict_class(ensemble: ObliviousEnsemble, x: jax.Array, **kw) -> jax.Array:
    """Deprecated kwarg path; prefer `Predictor.build(...).classify(x)`."""
    return classify_from_raw(raw_predict(ensemble, x, **kw),
                             ensemble.n_outputs)


# --------------------------------------------------------------------------
# Distributed prediction
# --------------------------------------------------------------------------
def predict_sharded(ensemble: ObliviousEnsemble, x: jax.Array, mesh,
                    *, data_axes=("data",), model_axis: str = "model",
                    strategy: Strategy = "staged") -> jax.Array:
    """Data-parallel over samples, tree-parallel over the model axis.

    Deprecated one-shot path: the plan (and its shard_map closure) is
    rebuilt on every call.  Prefer holding a
    `Predictor.build(...).sharded(mesh)` callable, which is built once
    and cached on the plan.  `prepare=False`: only the per-shard locals
    inside the shard_map body prepare model arrays — the throwaway
    plan's own copy would never be read.
    """
    plan = Predictor.build(ensemble,
                           PredictConfig(strategy=strategy, backend="auto"),
                           prepare=False)
    return plan.sharded(mesh, data_axes=data_axes,
                        model_axis=model_axis)(x)


def shard_inputs(x: jax.Array, mesh, data_axes=("data",)) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(data_axes)))
