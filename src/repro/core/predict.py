"""Vectorized GBDT prediction — the paper's contribution as a JAX module.

Pipeline (paper fig. 1): BinarizeFeatures -> CalcTreesBlockedImpl
{ CalcIndexesBasic -> CalculateLeafValues[Multi] } with every stage mapped
to a kernel op.  Three execution strategies:

  staged  — paper-faithful: three separate passes (binarize, leaf index,
            leaf gather), each vectorized.  Tree blocking mirrors
            CalcTreesBlockedImpl.
  fused   — beyond-paper: single fused Pallas pass (see kernels/fused_predict).
  auto    — fused on TPU, staged-ref on CPU.

`predict_sharded` distributes over a device mesh: samples over the data
axes, trees over the model axis with a final psum — GBDT's tree sum is
embarrassingly reducible, which is what makes the model-parallel axis
useful for very large ensembles (10k trees x 256 leaves x 20 classes is
a ~200 MB model; sharding trees keeps it VMEM-friendly per shard).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.trees import ObliviousEnsemble
from repro.kernels import ops

Strategy = Literal["auto", "staged", "fused"]


def raw_predict(ensemble: ObliviousEnsemble, x: jax.Array, *,
                strategy: Strategy = "auto",
                backend: str = "auto",
                tree_block: int = 0,
                block_n: int | None = None,
                block_t: int | None = None) -> jax.Array:
    """(N, F) float32 -> (N, C) float32 raw scores (sum over trees).

    block_n/block_t override the fused kernel's Pallas block shapes;
    left as None they are autotuned per ensemble by `kernels.tuning`.
    """
    if strategy == "auto":
        strategy = "fused" if jax.default_backend() == "tpu" else "staged"
    base = ensemble.base_score[None, :]
    if strategy == "fused":
        return base + ops.fused_predict(
            x, ensemble.borders, ensemble.split_features,
            ensemble.split_bins, ensemble.leaf_values, backend=backend,
            block_n=block_n, block_t=block_t)
    bins = ops.binarize(x, ensemble.borders, backend=backend)
    if tree_block and ensemble.n_trees > tree_block:
        # Paper-faithful CalcTreesBlockedImpl: process trees in blocks so the
        # (leaf_values, idx) working set stays cache/VMEM resident.
        acc = jnp.zeros((x.shape[0], ensemble.n_outputs), jnp.float32)
        for start in range(0, ensemble.n_trees, tree_block):
            blk = ensemble.slice_trees(start, min(start + tree_block,
                                                  ensemble.n_trees))
            idx = ops.leaf_index(bins, blk.split_features, blk.split_bins,
                                 backend=backend)
            acc = acc + ops.leaf_gather(idx, blk.leaf_values, backend=backend)
        return base + acc
    idx = ops.leaf_index(bins, ensemble.split_features, ensemble.split_bins,
                         backend=backend)
    return base + ops.leaf_gather(idx, ensemble.leaf_values, backend=backend)


def predict_proba(ensemble: ObliviousEnsemble, x: jax.Array, **kw) -> jax.Array:
    raw = raw_predict(ensemble, x, **kw)
    if ensemble.n_outputs == 1:
        p = jax.nn.sigmoid(raw[:, 0])
        return jnp.stack([1.0 - p, p], axis=1)
    return jax.nn.softmax(raw, axis=-1)


def predict_class(ensemble: ObliviousEnsemble, x: jax.Array, **kw) -> jax.Array:
    raw = raw_predict(ensemble, x, **kw)
    if ensemble.n_outputs == 1:
        return (raw[:, 0] > 0.0).astype(jnp.int32)
    return jnp.argmax(raw, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------------------
# Distributed prediction
# --------------------------------------------------------------------------
def predict_sharded(ensemble: ObliviousEnsemble, x: jax.Array, mesh,
                    *, data_axes=("data",), model_axis: str = "model",
                    strategy: Strategy = "staged") -> jax.Array:
    """Data-parallel over samples, tree-parallel over the model axis.

    Tree shards compute partial sums; a single psum over the model axis
    yields the ensemble total.  in/out shardings are explicit so this
    lowers cleanly on the production meshes.
    """
    from repro.compat import shard_map

    dp = P(data_axes)
    tree_p = P(model_axis)

    def _local(sf, sb, lv, borders, xs):
        local = ObliviousEnsemble(sf, sb, lv, borders, ensemble.n_borders)
        part = raw_predict(local, xs, strategy=strategy)
        return jax.lax.psum(part, model_axis)  # base added by caller

    fn = shard_map(
        _local, mesh=mesh,
        in_specs=(tree_p, tree_p, tree_p, P(), dp),
        out_specs=dp,
    )
    return ensemble.base_score[None, :] + fn(
        ensemble.split_features, ensemble.split_bins,
        ensemble.leaf_values, ensemble.borders, x)


def shard_inputs(x: jax.Array, mesh, data_axes=("data",)) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(data_axes)))
