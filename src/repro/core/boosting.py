"""Histogram-based gradient boosting of oblivious decision trees.

CatBoost-style trainer. Plain boosting by default; CatBoost's ordered
boosting (prefix Newton estimates along a random permutation — removes
prediction shift) is available via BoostingParams.ordered.
Each boosting iteration fits one oblivious tree:

  level d in 0..depth-1:
    hist[f, leaf, bin] <- segment-sum of (g, h) over (current leaf, bin)
    gain[f, b] = sum_leaf  G_l^2/(H_l+l2)  for left/right partitions
    the SAME (f*, b*) split is applied to every leaf  (oblivious)
    leaf |= [bins[:, f*] >= b*] << d

  leaf values: w_l = -lr * G_l / (H_l + l2)    (Newton step)

Two trainers share this math:

  * `fit` (the default) is a thin front-end over the quantized-first
    subsystem in `repro.training.gbdt`: the float matrix is binarized
    ONCE into a uint8 `QuantizedPool` and boosting runs registered
    `histogram` kernels over it — zero binarize dispatches inside the
    loop, per-iteration checkpoint/resume, and the fitted ensemble
    round-trips through `Predictor.build` exactly.
  * `fit_scan` is the seed float-path trainer (the whole fit is one
    `lax.scan` over trees -> compiles once).  Kept as the benchmark
    baseline and the differential oracle the quantized trainer is
    tested against.

Feature subsampling (rsm) is supported via per-tree gain masking.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as losses_lib
from repro.core import quantize
from repro.core.trees import ObliviousEnsemble

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class BoostingParams:
    n_trees: int = 100
    depth: int = 6
    learning_rate: float = 0.1
    l2_reg: float = 3.0
    max_bins: int = 64
    rsm: float = 1.0              # feature subsample per tree
    ordered: bool = False         # CatBoost-style ordered boosting: the
    #                               raw-prediction update for sample i uses
    #                               only samples before i in a random
    #                               permutation (prefix Newton estimates),
    #                               removing target leakage / prediction
    #                               shift. Stored leaf values (inference)
    #                               still use all samples.
    seed: int = 0


def _ordered_update(leaf, g, h, key, lr, l2, n_leaves):
    """Per-sample leaf values from PREFIX statistics along a random
    permutation, grouped by leaf (segmented exclusive prefix sums via one
    sort — no (L, N) blowup)."""
    N = leaf.shape[0]
    pos = jnp.argsort(jax.random.permutation(key, N))     # rank of sample i
    order = jnp.lexsort((pos, leaf))          # leaf-grouped, rank-ordered
    g_s, h_s, leaf_s = g[order], h[order], leaf[order]
    excl_g = jnp.cumsum(g_s, axis=0) - g_s    # exclusive overall prefix
    excl_h = jnp.cumsum(h_s, axis=0) - h_s
    start = jnp.concatenate([jnp.ones((1,), bool),
                             leaf_s[1:] != leaf_s[:-1]])
    idx = jnp.arange(N)
    last_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(start, idx, -1))
    prefix_g = excl_g - excl_g[last_start]    # within-leaf exclusive prefix
    prefix_h = excl_h - excl_h[last_start]
    w_sorted = -lr * prefix_g / (prefix_h + l2)
    return jnp.zeros_like(g).at[order].set(w_sorted)


def _gain_term(gs, hs, l2):
    return gs * gs / (hs + l2)


def _build_tree(bins, g, h, n_borders, key, *, depth: int, max_bins: int,
                l2: float, rsm: float):
    """Fit one oblivious tree. Returns (sf (D,), sb (D,), sum_g/h per leaf)."""
    N, F = bins.shape
    C = g.shape[1]
    B = max_bins                       # bin ids in [0, B-1]
    L = 1 << depth

    feat_ok = jnp.ones((F,), bool)
    if rsm < 1.0:
        keep = jnp.maximum(1, int(F * rsm))
        perm = jax.random.permutation(key, F)
        feat_ok = jnp.zeros((F,), bool).at[perm[:keep]].set(True)

    b_iota = jnp.arange(B, dtype=jnp.int32)
    # valid split borders: 1 <= b <= n_borders[f]
    valid = (b_iota[None, :] >= 1) & (b_iota[None, :] <= n_borders[:, None])
    valid = valid & feat_ok[:, None]                    # (F, B)

    leaf = jnp.zeros((N,), jnp.int32)
    sf = jnp.zeros((depth,), jnp.int32)
    sb = jnp.zeros((depth,), jnp.int32)

    for d in range(depth):
        seg = leaf[None, :] * B + bins.T                # (F, N)
        hist_g = jax.vmap(
            lambda s: jax.ops.segment_sum(g, s, num_segments=L * B))(seg)
        hist_h = jax.vmap(
            lambda s: jax.ops.segment_sum(h, s, num_segments=L * B))(seg)
        hist_g = hist_g.reshape(F, L, B, C)
        hist_h = hist_h.reshape(F, L, B, C)

        incl_g = jnp.cumsum(hist_g, axis=2)
        incl_h = jnp.cumsum(hist_h, axis=2)
        total_g = incl_g[:, :, -1:, :]
        total_h = incl_h[:, :, -1:, :]
        # left of border b = bins < b  -> inclusive cumsum shifted by one.
        left_g = jnp.pad(incl_g[:, :, :-1, :], ((0, 0), (0, 0), (1, 0), (0, 0)))
        left_h = jnp.pad(incl_h[:, :, :-1, :], ((0, 0), (0, 0), (1, 0), (0, 0)))
        right_g = total_g - left_g
        right_h = total_h - left_h

        gain = (_gain_term(left_g, left_h, l2)
                + _gain_term(right_g, right_h, l2)).sum(axis=(1, 3))  # (F, B)
        # a split must put mass on both sides (h > 0 for all losses here);
        # degenerate splits (e.g. constant features) are never selected
        nonempty = (left_h.sum(axis=(1, 3)) > 0) \
            & (right_h.sum(axis=(1, 3)) > 0)
        gain = jnp.where(valid & nonempty, gain, NEG_INF)

        flat = jnp.argmax(gain.reshape(-1))
        f_star = (flat // B).astype(jnp.int32)
        b_star = (flat % B).astype(jnp.int32)
        sf = sf.at[d].set(f_star)
        sb = sb.at[d].set(b_star)
        go_right = (bins[:, f_star] >= b_star).astype(jnp.int32)
        leaf = leaf | (go_right << d)

    sum_g = jax.ops.segment_sum(g, leaf, num_segments=L)      # (L, C)
    sum_h = jax.ops.segment_sum(h, leaf, num_segments=L)
    return sf, sb, sum_g, sum_h, leaf


@functools.partial(jax.jit, static_argnames=("loss", "depth", "max_bins",
                                             "n_trees", "lr", "l2", "rsm",
                                             "ordered"))
def _fit_scan(bins, y, raw0, n_borders, key, *, loss, depth, max_bins,
              n_trees, lr, l2, rsm, ordered=False):
    def step(carry, _):
        raw, key = carry
        key, sub, sub2 = jax.random.split(key, 3)
        g, h = loss.grad_hess(raw, y)
        sf, sb, sum_g, sum_h, leaf = _build_tree(
            bins, g, h, n_borders, sub, depth=depth, max_bins=max_bins,
            l2=l2, rsm=rsm)
        w = -lr * sum_g / (sum_h + l2)                 # (L, C)
        if ordered:
            raw = raw + _ordered_update(leaf, g, h, sub2, lr, l2,
                                        1 << depth)
        else:
            raw = raw + w[leaf]
        return (raw, key), (sf, sb, w, loss.value(raw, y))

    (raw, _), (sfs, sbs, ws, vals) = jax.lax.scan(
        step, (raw0, key), None, length=n_trees)
    return raw, sfs, sbs, ws, vals


def fit(x: np.ndarray, y: np.ndarray, *, loss: losses_lib.Loss,
        params: BoostingParams,
        borders: Optional[jax.Array] = None,
        n_borders: Optional[jax.Array] = None,
        ) -> tuple[ObliviousEnsemble, dict]:
    """Train a GBDT on raw float features. Returns (ensemble, history).

    Front-end over `repro.training.gbdt.GBDTTrainer`: quantizes once
    into a uint8 pool (or int32 bins when the borders exceed the uint8
    bin space) and boosts on that — same math, same RNG stream and same
    history semantics as the seed `fit_scan`, but through the
    registered `histogram` kernels.
    """
    # lazy import: training.gbdt imports this module for the shared
    # boosting math (BoostingParams, _ordered_update, ...)
    from repro.training import gbdt as gbdt_lib

    x = np.asarray(x, np.float32)
    if borders is None:
        borders, n_borders = quantize.compute_borders(x, params.max_bins)
    trainer = gbdt_lib.GBDTTrainer(loss, params)
    if int(borders.shape[0]) <= quantize.MAX_BINS - 1:
        pool = quantize.quantize_pool(jnp.asarray(x), borders)
        return trainer.fit_pool(pool, y, borders=borders,
                                n_borders=n_borders)
    bins = quantize.binarize_matrix(jnp.asarray(x), borders)
    return trainer.fit_bins(bins, y, borders=borders, n_borders=n_borders)


def fit_scan(x: np.ndarray, y: np.ndarray, *, loss: losses_lib.Loss,
             params: BoostingParams,
             borders: Optional[jax.Array] = None,
             n_borders: Optional[jax.Array] = None,
             ) -> tuple[ObliviousEnsemble, dict]:
    """The seed float-path trainer: one `lax.scan` over trees.

    Binarizes its own float matrix every fit and scatters histograms
    through `segment_sum` — kept verbatim as the benchmark baseline and
    the differential oracle for the quantized-first trainer."""
    x = np.asarray(x, np.float32)
    yj = jnp.asarray(y)
    if borders is None:
        borders, n_borders = quantize.compute_borders(x, params.max_bins)
    bins = quantize.binarize_matrix(jnp.asarray(x), borders)
    raw0 = loss.init_raw(yj)
    key = jax.random.PRNGKey(params.seed)

    raw, sfs, sbs, ws, vals = _fit_scan(
        bins, yj, raw0, n_borders, key, loss=loss, depth=params.depth,
        max_bins=params.max_bins, n_trees=params.n_trees,
        lr=params.learning_rate, l2=params.l2_reg, rsm=params.rsm,
        ordered=params.ordered)

    ensemble = ObliviousEnsemble(
        split_features=sfs.astype(jnp.int32),
        split_bins=sbs.astype(jnp.int32),
        leaf_values=ws.astype(jnp.float32),
        borders=borders,
        n_borders=n_borders,
        base_score=raw0[0].astype(jnp.float32),
    )
    history = {
        "train_loss": np.asarray(vals),
        "final_metric": float(loss.metric(raw, yj)),
    }
    return ensemble, history
