"""Oblivious-tree ensemble: the CatBoost model structure, as a JAX pytree.

Structure-of-arrays layout (exactly what the paper's hotspots consume):
  split_features (T, D) int32 — feature id tested at depth d of tree t
  split_bins     (T, D) int32 — border id; sample goes right iff bin >= split_bin
  leaf_values    (T, 2^D, C) float32
  borders        (B, F) float32 — per-feature bin borders (padded with +inf)
  n_borders      (F,)   int32   — true border count per feature

All trees share a single depth D (CatBoost pads shallower trees the same
way: repeat a split or use an always-false one; we use split_bin = PAD so
the padded levels always go left).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# Canonical definition lives with the kernels (padding happens there);
# re-exported here because the model layer is where most callers look.
from repro.kernels.ops import PAD_SPLIT_BIN  # noqa: F401


@dataclasses.dataclass(frozen=True)
class ObliviousEnsemble:
    split_features: jax.Array    # (T, D) int32
    split_bins: jax.Array        # (T, D) int32
    leaf_values: jax.Array       # (T, 2^D, C) float32
    borders: jax.Array           # (B, F) float32
    n_borders: jax.Array         # (F,) int32
    base_score: jax.Array = None  # (C,) float32 additive offset

    def __post_init__(self):
        # Default the base score to zeros at *construction* time only.
        # The pytree unflatten below bypasses __init__, so tree_map /
        # tree_unflatten never re-enter this default path — a mapped-to-
        # None leaf stays None instead of crashing on
        # `None.shape` (regression: tests/test_gbdt.py pytree round-trip).
        if self.base_score is None:
            object.__setattr__(
                self, "base_score",
                jnp.zeros((self.leaf_values.shape[2],), jnp.float32))

    @property
    def n_trees(self) -> int:
        return self.split_features.shape[0]

    @property
    def depth(self) -> int:
        return self.split_features.shape[1]

    @property
    def true_depths(self) -> np.ndarray:
        """(T,) int32 — each tree's depth before depth padding.

        A tree shallower than the shared ensemble depth carries trailing
        always-left levels (`split_bins == PAD_SPLIT_BIN`); its true
        depth is the level count with those trailing pads stripped (a
        PAD level *between* real levels still counts — only the trailing
        run is padding, matching the importer's convention).  Model
        structure, so concrete arrays only: reading it on traced arrays
        raises (use `layout.is_concrete` to guard).
        """
        sb = np.asarray(self.split_bins)
        if sb.shape[0] == 0:
            return np.zeros((0,), np.int32)
        trailing_pad = np.cumprod(
            (sb == PAD_SPLIT_BIN)[:, ::-1], axis=1).sum(axis=1)
        return (sb.shape[1] - trailing_pad).astype(np.int32)

    def lower(self, layout: str = "soa", **lower_kw):
        """Lower the logical model into a physical `LoweredEnsemble`
        layout (see `repro.core.layout`): "soa", "depth_major" or
        "depth_grouped"."""
        from repro.core import layout as layout_mod
        return layout_mod.lower(self, layout, **lower_kw)

    @property
    def n_outputs(self) -> int:
        return self.leaf_values.shape[2]

    @property
    def n_features(self) -> int:
        return self.borders.shape[1]

    def slice_trees(self, start: int, stop: int) -> "ObliviousEnsemble":
        """Tree-block view (the paper's CalcTreesBlockedImpl granularity)."""
        if not 0 <= start <= stop <= self.n_trees:
            raise ValueError(
                f"slice_trees({start}, {stop}) out of range for an "
                f"ensemble of {self.n_trees} trees "
                "(need 0 <= start <= stop <= n_trees)")
        return dataclasses.replace(
            self,
            split_features=self.split_features[start:stop],
            split_bins=self.split_bins[start:stop],
            leaf_values=self.leaf_values[start:stop],
        )

    # -- persistence (used by serving + checkpoint tests) ------------------
    def save(self, path: str | pathlib.Path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez(
            path,
            split_features=np.asarray(self.split_features),
            split_bins=np.asarray(self.split_bins),
            leaf_values=np.asarray(self.leaf_values),
            borders=np.asarray(self.borders),
            n_borders=np.asarray(self.n_borders),
            base_score=np.asarray(self.base_score),
        )

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "ObliviousEnsemble":
        with np.load(path) as z:
            return cls(**{k: jnp.asarray(z[k]) for k in z.files})

    def describe(self) -> dict[str, Any]:
        return dict(n_trees=self.n_trees, depth=self.depth,
                    n_outputs=self.n_outputs, n_features=self.n_features,
                    n_leaf_params=int(np.prod(self.leaf_values.shape)))

    def describe_json(self) -> str:
        return json.dumps(self.describe())


# Pytree registration.  Not `jax.tree_util.register_dataclass`: its
# unflatten calls the constructor, which would re-run __post_init__'s
# base_score default on every tree_unflatten — with non-array leaves
# (tree_map to None, tree_transpose, structural unflattens) that path
# dereferences `leaf_values.shape` on whatever leaf happens to be there.
# Unflattening here rebuilds the instance field-by-field without
# __init__, so lowering/mapping an ensemble is a pure structural
# operation and the zeros default exists only on user construction.
_ENSEMBLE_FIELDS = ("split_features", "split_bins", "leaf_values",
                    "borders", "n_borders", "base_score")


def _ensemble_flatten_with_keys(e: "ObliviousEnsemble"):
    children = tuple((jax.tree_util.GetAttrKey(f), getattr(e, f))
                     for f in _ENSEMBLE_FIELDS)
    return children, None


def _ensemble_flatten(e: "ObliviousEnsemble"):
    return tuple(getattr(e, f) for f in _ENSEMBLE_FIELDS), None


def _ensemble_unflatten(_aux, children) -> "ObliviousEnsemble":
    obj = object.__new__(ObliviousEnsemble)
    for f, c in zip(_ENSEMBLE_FIELDS, children):
        object.__setattr__(obj, f, c)
    return obj


jax.tree_util.register_pytree_with_keys(
    ObliviousEnsemble, _ensemble_flatten_with_keys, _ensemble_unflatten,
    _ensemble_flatten)


def empty_ensemble(n_features: int, depth: int, n_outputs: int,
                   borders: jax.Array, n_borders: jax.Array
                   ) -> ObliviousEnsemble:
    return ObliviousEnsemble(
        split_features=jnp.zeros((0, depth), jnp.int32),
        split_bins=jnp.zeros((0, depth), jnp.int32),
        leaf_values=jnp.zeros((0, 2 ** depth, n_outputs), jnp.float32),
        borders=borders,
        n_borders=n_borders,
    )


def truncate_tree_depths(ensemble: ObliviousEnsemble,
                         depths) -> ObliviousEnsemble:
    """Truncate tree t to `depths[t]` levels via trailing always-left
    pads — the CatBoost shallow-tree convention (`split_bins` =
    `PAD_SPLIT_BIN` beyond the true depth, unreachable leaf values
    zeroed).  The canonical builder of mixed-depth ensembles: the
    layout tests and the layout-sweep benchmark both construct their
    covertype-style mixed-depth models through this, so the convention
    lives in exactly one place.  `depths[t]` may be 0 (a constant tree:
    only leaf 0 reachable) up to `ensemble.depth` (unchanged).
    """
    depths = np.asarray(depths, np.int64)
    if depths.shape != (ensemble.n_trees,):
        raise ValueError(f"need one depth per tree: got shape "
                         f"{depths.shape} for {ensemble.n_trees} trees")
    if depths.size and not (0 <= depths.min()
                            and depths.max() <= ensemble.depth):
        raise ValueError(f"depths must lie in [0, {ensemble.depth}], "
                         f"got [{depths.min()}, {depths.max()}]")
    sb = np.asarray(ensemble.split_bins).copy()
    lv = np.asarray(ensemble.leaf_values).copy()
    for t, d in enumerate(depths):
        sb[t, d:] = PAD_SPLIT_BIN
        lv[t, 1 << d:] = 0.0
    return dataclasses.replace(ensemble, split_bins=jnp.asarray(sb),
                               leaf_values=jnp.asarray(lv))


def concat_ensembles(a: ObliviousEnsemble, b: ObliviousEnsemble
                     ) -> ObliviousEnsemble:
    """Append b's trees to a (a's borders/base_score win).

    Two ensembles are only summable when they agree on tree depth,
    output width and the quantization borders — a mismatch silently
    produces garbage leaf sums, so each is a hard error here.
    """
    if a.depth != b.depth:
        raise ValueError(f"cannot concat ensembles of different depth: "
                         f"{a.depth} vs {b.depth}")
    if a.n_outputs != b.n_outputs:
        raise ValueError(f"cannot concat ensembles with different "
                         f"n_outputs: {a.n_outputs} vs {b.n_outputs}")
    if a.borders.shape != b.borders.shape:
        raise ValueError(f"cannot concat ensembles quantized with "
                         f"different border tables: {a.borders.shape} vs "
                         f"{b.borders.shape}")
    if not (isinstance(a.borders, jax.core.Tracer)
            or isinstance(b.borders, jax.core.Tracer)):
        if not np.array_equal(np.asarray(a.borders), np.asarray(b.borders)):
            raise ValueError(
                "cannot concat ensembles quantized with different border "
                "values: split_bins index into incompatible bin spaces")
    return dataclasses.replace(
        a,
        split_features=jnp.concatenate([a.split_features, b.split_features]),
        split_bins=jnp.concatenate([a.split_bins, b.split_bins]),
        leaf_values=jnp.concatenate([a.leaf_values, b.leaf_values]),
    )
