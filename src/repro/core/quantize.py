"""Feature quantization: border computation + binarization.

CatBoost quantizes float features into <= 255 bins at train time; borders
are (approximately) quantile-based.  `compute_borders` reproduces the
Median+Uniform-ish default with pure quantiles; `binarize_matrix` applies
them through the kernel op (paper hotspot: BinarizeFloatsNonSse).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def compute_borders(x: np.ndarray, max_bins: int = 64
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-feature quantile borders.

    Returns (borders (B, F) float32 padded with +inf, n_borders (F,) int32)
    where B = max_bins - 1 (bins = borders + 1).
    """
    x = np.asarray(x, np.float32)
    n, f = x.shape
    n_borders = max_bins - 1
    qs = np.linspace(0.0, 1.0, max_bins + 1)[1:-1]       # interior quantiles
    borders = np.full((n_borders, f), np.inf, np.float32)
    counts = np.zeros((f,), np.int32)
    for j in range(f):
        col = x[:, j]
        col = col[np.isfinite(col)]
        uniq = np.unique(np.quantile(col, qs)) if col.size else np.array([])
        # Drop degenerate borders (constant features yield none).
        uniq = uniq[np.isfinite(uniq)]
        counts[j] = len(uniq)
        borders[:len(uniq), j] = uniq.astype(np.float32)
    return jnp.asarray(borders), jnp.asarray(counts)


def binarize_matrix(x: jax.Array, borders: jax.Array, *,
                    backend: str = "auto") -> jax.Array:
    """(N, F) float32 -> (N, F) int32 bin ids via the binarize kernel."""
    return ops.binarize(x, borders, backend=backend)
