"""Feature quantization: border computation, binarization, and the
`QuantizedPool` value type the quantized-first evaluation API is built on.

CatBoost quantizes float features into <= 255 bins at train time (the
255-border cap is what makes one byte per (sample, feature) possible);
borders are (approximately) quantile-based.  `compute_borders`
reproduces the Median+Uniform-ish default with pure quantiles;
`quantize_pool` binarizes once into a schema-stamped uint8 pool that
`Predictor.raw/proba/classify` score directly — the paper's evaluators
never touch float features, they run `CalcIndexesBasic` over the
quantized representation (paper hotspot: BinarizeFloatsNonSse runs
once, not per predict).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

# Bin ids must fit uint8: ids span [0, n_borders], so 255 borders is the
# cap (CatBoost's own limit).  max_bins = n_borders + 1.
MAX_BINS = 256


def compute_borders(x: np.ndarray, max_bins: int = 64
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-feature quantile borders.

    Returns (borders (B, F) float32 padded with +inf, n_borders (F,) int32)
    where B = max_bins - 1 (bins = borders + 1).

    `max_bins` is capped at 256 so bin ids always fit the uint8
    quantized-pool representation.  Constant and all-NaN columns get
    zero borders (a border no sample can cross splits nothing), without
    tripping numpy's empty-quantile warning path.
    """
    if not 2 <= max_bins <= MAX_BINS:
        raise ValueError(
            f"max_bins must be in [2, {MAX_BINS}] (bin ids must fit "
            f"uint8: <= {MAX_BINS - 1} borders), got {max_bins}")
    x = np.asarray(x, np.float32)
    n, f = x.shape
    n_borders = max_bins - 1
    qs = np.linspace(0.0, 1.0, max_bins + 1)[1:-1]       # interior quantiles
    borders = np.full((n_borders, f), np.inf, np.float32)
    counts = np.zeros((f,), np.int32)
    for j in range(f):
        col = x[:, j]
        col = col[np.isfinite(col)]
        if col.size == 0:          # all-NaN/inf column: nothing to split
            continue
        hi = col.max()
        if col.min() == hi:        # constant column: no border separates
            continue
        uniq = np.unique(np.quantile(col, qs).astype(np.float32))
        # A border is useful only if some sample lands on each side
        # (x > border for some, not all); quantiles at the column max
        # are degenerate, as are any non-finite leftovers.
        uniq = uniq[np.isfinite(uniq) & (uniq < hi)]
        counts[j] = len(uniq)
        borders[:len(uniq), j] = uniq
    return jnp.asarray(borders), jnp.asarray(counts)


def borders_fingerprint(borders) -> str:
    """Schema fingerprint of a quantization: models sharing it accept
    the same `QuantizedPool` (same feature count, same border values,
    hence the same bin-id space for `split_bins` to index into).

    The hash covers exactly the borders array — the only input
    binarization reads — so `quantize_pool(x, ens.borders)` and
    `Predictor.quantize` stamp identical fingerprints for identical
    borders with no extra arguments to keep in sync."""
    b = np.ascontiguousarray(np.asarray(borders, np.float32))
    h = hashlib.sha1()
    h.update(repr(b.shape).encode())
    h.update(b.tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class QuantizedPool:
    """A batch binarized once: uint8 bins + the schema they were
    quantized under.

    This is the interface the fast evaluators are built on — quantize
    once, score many (multi-model serving, repeated scoring, train-time
    eval).  `Predictor.raw/proba/classify` accept a pool and skip
    binarization entirely; the fingerprint guards against scoring a
    pool through a model quantized with different borders (silent
    garbage otherwise — `split_bins` would index a different bin space).
    """
    bins: jax.Array                # (N, F) uint8 — unpadded feature axis
    fingerprint: str               # `borders_fingerprint` of the schema

    def __post_init__(self):
        if self.bins.ndim != 2:
            raise ValueError(f"pool bins must be (N, F), got shape "
                             f"{tuple(self.bins.shape)}")
        if self.bins.dtype != jnp.uint8:
            raise ValueError(f"pool bins must be uint8, got "
                             f"{self.bins.dtype}")

    @property
    def n_rows(self) -> int:
        return self.bins.shape[0]

    @property
    def n_features(self) -> int:
        return self.bins.shape[1]

    def __len__(self) -> int:
        return self.n_rows

    def slice_rows(self, start: int, stop: int) -> "QuantizedPool":
        """Row-range view (serving chunks oversized pools with this)."""
        return dataclasses.replace(self, bins=self.bins[start:stop])

    def pad_rows(self, target: int) -> "QuantizedPool":
        """Zero-pad to `target` rows (bucketed serving).  Bin 0 per
        feature is exactly what binarizing a zero-padded float row
        against +inf-padded borders yields, so padded rows are sliced
        off downstream just like the float path's."""
        n = self.n_rows
        if n == target:
            return self
        if n > target:
            raise ValueError(f"cannot pad {n} pool rows down to {target}")
        pad = jnp.zeros((target - n, self.n_features), jnp.uint8)
        return dataclasses.replace(
            self, bins=jnp.concatenate([jnp.asarray(self.bins), pad]))


def quantize_pool(x, borders, *, backend: str = "auto") -> QuantizedPool:
    """Binarize a float batch once into a reusable `QuantizedPool`.

    Requires <= 255 borders (uint8 bin ids); `backend` follows the
    kernel registry's legacy shim values ("auto"/"ref"/"pallas" or an
    exact implementation name).
    """
    if borders.shape[0] > MAX_BINS - 1:
        raise ValueError(
            f"quantize_pool needs <= {MAX_BINS - 1} borders for uint8 "
            f"bins, got {borders.shape[0]} (compute_borders caps "
            f"max_bins at {MAX_BINS})")
    x = jnp.asarray(x, jnp.float32)
    bins = ops.binarize_u8(x, borders, backend=backend)
    return QuantizedPool(bins, borders_fingerprint(borders))


def quantize_pool_chunked(x_iter, borders, *,
                          backend: str = "auto") -> QuantizedPool:
    """Build a `QuantizedPool` from an iterator of float row-chunks.

    The streaming counterpart of `quantize_pool`: each (n_i, F) chunk is
    binarized independently and only the one-byte bins are retained, so
    peak float memory is O(largest chunk) while the finished pool is the
    same N x F uint8 array `quantize_pool` would have produced on the
    concatenated matrix (binarization is row-independent).  This is the
    memory contract the bulk scorer's prequantize path depends on —
    datasets that never fit in float32 still fit as bins (4x smaller),
    and datasets that don't even fit as bins stream chunk-by-chunk
    through `repro.scoring.BulkScorer` instead of pooling at all.
    """
    if borders.shape[0] > MAX_BINS - 1:
        raise ValueError(
            f"quantize_pool_chunked needs <= {MAX_BINS - 1} borders for "
            f"uint8 bins, got {borders.shape[0]}")
    parts: list[np.ndarray] = []
    n_features = int(borders.shape[1])
    for chunk in x_iter:
        chunk = np.asarray(chunk, np.float32)
        if chunk.ndim != 2 or chunk.shape[1] != n_features:
            raise ValueError(
                f"chunk shape {chunk.shape} does not match the "
                f"(n, {n_features}) the borders describe")
        if chunk.shape[0] == 0:
            continue
        parts.append(np.asarray(
            ops.binarize_u8(jnp.asarray(chunk), borders, backend=backend),
            np.uint8))
    bins = (np.concatenate(parts, axis=0) if parts
            else np.zeros((0, n_features), np.uint8))
    return QuantizedPool(jnp.asarray(bins), borders_fingerprint(borders))


def compute_borders_chunked(x_iter, max_bins: int = 64, *,
                            sample_rows: int = 65536, seed: int = 0
                            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """`compute_borders` over a row-chunk iterator, via reservoir sample.

    Streams (n_i, F) float chunks, keeping a uniform row reservoir of at
    most `sample_rows` rows, then computes quantile borders on the
    sample — border computation never materializes the full float
    matrix.  When the stream holds <= `sample_rows` rows the result is
    exactly `compute_borders` on the concatenated matrix (quantiles are
    order-independent and no row is dropped); beyond that the borders
    are sample-quantile approximations, which is also what CatBoost's
    own subsampled border builder does for large pools.
    """
    rng = np.random.default_rng(seed)
    reservoir: Optional[np.ndarray] = None
    seen = 0
    for chunk in x_iter:
        chunk = np.asarray(chunk, np.float32)
        if chunk.ndim != 2:
            raise ValueError(f"chunks must be (n, F), got {chunk.shape}")
        if chunk.shape[0] == 0:
            continue
        if reservoir is None:
            reservoir = np.empty((0, chunk.shape[1]), np.float32)
        if reservoir.shape[1] != chunk.shape[1]:
            raise ValueError(f"ragged chunk widths: {reservoir.shape[1]} "
                             f"then {chunk.shape[1]}")
        take = min(max(sample_rows - reservoir.shape[0], 0),
                   chunk.shape[0])
        if take:
            reservoir = np.concatenate([reservoir, chunk[:take]], axis=0)
            chunk = chunk[take:]
            seen += take
        if chunk.shape[0] == 0:
            continue
        # classic reservoir replacement for the overflow rows: stream
        # row k (0-based) replaces a uniform slot with prob S/(k+1);
        # draws are vectorized, replacements applied in stream order so
        # later rows overwrite earlier ones hitting the same slot
        draws = rng.integers(0, seen + 1 + np.arange(chunk.shape[0]))
        seen += chunk.shape[0]
        for i in np.nonzero(draws < sample_rows)[0]:
            reservoir[draws[i]] = chunk[i]
    if reservoir is None:
        raise ValueError("compute_borders_chunked needs at least one "
                         "non-empty chunk")
    return compute_borders(reservoir, max_bins)


def binarize_matrix(x: jax.Array, borders: jax.Array, *,
                    backend: str = "auto") -> jax.Array:
    """(N, F) float32 -> (N, F) int32 bin ids.

    .. deprecated::
        Thin shim over the registry-dispatched `kernels.ops.binarize`
        (the same treatment `core.predict` got): kept for existing
        callers like `core.boosting`.  New code wanting a reusable
        quantized batch should build a `QuantizedPool` via
        `quantize_pool` / `Predictor.quantize`, which yields the uint8
        representation the quantized-first scoring path consumes.
    """
    return ops.binarize(x, borders, backend=backend)
