"""Gradient-boosting losses with first/second-order derivatives.

Covers the paper's five workloads (Table 1):
  MQ2008            -> YetiRank      (implemented as grouped PairLogit)
  Santander         -> LogLoss
  Covertype         -> MultiClass
  YearPredictionMSD -> MAE
  image-embeddings  -> MultiClass
plus RMSE and Quantile for completeness.

Each loss exposes:
  n_raw(n_classes)        — width of the raw prediction vector
  init_raw(y)             — base score
  grad_hess(raw, y)       — (g, h), both (N, C)
  value(raw, y)           — scalar training objective
  metric(raw, y)          — paper-comparable quality metric (see Table 5)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class Loss:
    name: str = "base"

    def n_raw(self, n_classes: int) -> int:
        return 1

    def init_raw(self, y: jax.Array) -> jax.Array:
        return jnp.zeros((y.shape[0], self.n_raw(0)), jnp.float32)

    def grad_hess(self, raw, y):
        raise NotImplementedError

    def value(self, raw, y):
        raise NotImplementedError

    def metric(self, raw, y):
        raise NotImplementedError


@dataclasses.dataclass(eq=False)
class RMSE(Loss):
    name: str = "RMSE"

    def init_raw(self, y):
        return jnp.full((y.shape[0], 1), jnp.mean(y), jnp.float32)

    def grad_hess(self, raw, y):
        g = raw[:, 0] - y
        return g[:, None], jnp.ones_like(g)[:, None]

    def value(self, raw, y):
        # L = 1/2 (r - y)^2  (so grad = r - y, hess = 1)
        return 0.5 * jnp.mean((raw[:, 0] - y) ** 2)

    def metric(self, raw, y):
        return jnp.sqrt(jnp.mean((raw[:, 0] - y) ** 2))


@dataclasses.dataclass(eq=False)
class MAE(Loss):
    """CatBoost MAE: gradient = sign, unit hessian (gradient step)."""
    name: str = "MAE"

    def init_raw(self, y):
        return jnp.full((y.shape[0], 1), jnp.median(y), jnp.float32)

    def grad_hess(self, raw, y):
        g = jnp.sign(raw[:, 0] - y)
        return g[:, None], jnp.ones_like(g)[:, None]

    def value(self, raw, y):
        return jnp.mean(jnp.abs(raw[:, 0] - y))

    def metric(self, raw, y):
        return self.value(raw, y)


@dataclasses.dataclass(eq=False)
class Quantile(Loss):
    alpha: float = 0.5
    name: str = "Quantile"

    def init_raw(self, y):
        return jnp.full((y.shape[0], 1), jnp.quantile(y, self.alpha),
                        jnp.float32)

    def grad_hess(self, raw, y):
        d = raw[:, 0] - y
        g = jnp.where(d > 0, 1.0 - self.alpha, -self.alpha)
        return g[:, None], jnp.ones_like(g)[:, None]

    def value(self, raw, y):
        d = y - raw[:, 0]
        return jnp.mean(jnp.maximum(self.alpha * d, (self.alpha - 1.0) * d))

    def metric(self, raw, y):
        return self.value(raw, y)


@dataclasses.dataclass(eq=False)
class LogLoss(Loss):
    name: str = "LogLoss"

    def init_raw(self, y):
        p = jnp.clip(jnp.mean(y), 1e-6, 1 - 1e-6)
        return jnp.full((y.shape[0], 1), jnp.log(p / (1 - p)), jnp.float32)

    def grad_hess(self, raw, y):
        p = jax.nn.sigmoid(raw[:, 0])
        return (p - y)[:, None], jnp.maximum(p * (1 - p), 1e-12)[:, None]

    def value(self, raw, y):
        z = raw[:, 0]
        return jnp.mean(jnp.logaddexp(0.0, z) - y * z)

    def metric(self, raw, y):
        """Accuracy (paper Table 5 reports accuracy)."""
        return jnp.mean(((raw[:, 0] > 0).astype(jnp.float32) == y)
                        .astype(jnp.float32))


@dataclasses.dataclass(eq=False)
class MultiClass(Loss):
    n_classes: int = 2
    name: str = "MultiClass"

    def n_raw(self, n_classes: int) -> int:
        return self.n_classes

    def init_raw(self, y):
        return jnp.zeros((y.shape[0], self.n_classes), jnp.float32)

    def grad_hess(self, raw, y):
        p = jax.nn.softmax(raw, axis=-1)
        onehot = jax.nn.one_hot(y.astype(jnp.int32), self.n_classes)
        g = p - onehot
        h = jnp.maximum(p * (1 - p), 1e-12)
        return g, h

    def value(self, raw, y):
        logp = jax.nn.log_softmax(raw, axis=-1)
        return -jnp.mean(jnp.take_along_axis(
            logp, y.astype(jnp.int32)[:, None], axis=1))

    def metric(self, raw, y):
        return jnp.mean((jnp.argmax(raw, axis=-1) == y.astype(jnp.int32))
                        .astype(jnp.float32))


@dataclasses.dataclass(eq=False)
class PairLogitGrouped(Loss):
    """Grouped pairwise ranking (YetiRank-family surrogate).

    `group_index` is a (G, S) int32 matrix of flat sample ids, padded with
    -1.  Gradients are computed on the padded (G, S, S) pairwise tensor and
    scattered back to flat layout — MQ2008 has ~120 docs/query max, so the
    padded tensor stays small.
    """
    group_index: Optional[np.ndarray] = None     # (G, S) int32, -1 padded
    name: str = "PairLogit"

    def _padded(self, v):
        gi = jnp.asarray(self.group_index)
        safe = jnp.maximum(gi, 0)
        return v[safe], gi >= 0

    def grad_hess(self, raw, y):
        s, valid = self._padded(raw[:, 0])          # (G, S)
        rel, _ = self._padded(y)
        diff = s[:, :, None] - s[:, None, :]        # (G, S, S) s_i - s_j
        better = (rel[:, :, None] > rel[:, None, :])
        pair_ok = (better & valid[:, :, None] & valid[:, None, :]).astype(
            jnp.float32)
        sig = jax.nn.sigmoid(-diff)                 # d/ds_i log(1+e^-(si-sj))
        # For each ordered pair (i better than j): g_i += -sig, g_j += +sig.
        g_pad = (-sig * pair_ok).sum(2) + (sig * pair_ok).sum(1)
        h_pad = (sig * (1 - sig) * pair_ok).sum(2) + (
            sig * (1 - sig) * pair_ok).sum(1)
        gi = jnp.asarray(self.group_index)
        flat_g = jnp.zeros((raw.shape[0],), jnp.float32)
        flat_h = jnp.zeros((raw.shape[0],), jnp.float32)
        safe = jnp.maximum(gi, 0).reshape(-1)
        w = (gi >= 0).astype(jnp.float32).reshape(-1)
        flat_g = flat_g.at[safe].add(g_pad.reshape(-1) * w)
        flat_h = flat_h.at[safe].add(h_pad.reshape(-1) * w)
        return flat_g[:, None], jnp.maximum(flat_h, 1e-3)[:, None]

    def value(self, raw, y):
        s, valid = self._padded(raw[:, 0])
        rel, _ = self._padded(y)
        diff = s[:, :, None] - s[:, None, :]
        better = (rel[:, :, None] > rel[:, None, :])
        pair_ok = (better & valid[:, :, None] & valid[:, None, :]).astype(
            jnp.float32)
        losses = jnp.logaddexp(0.0, -diff) * pair_ok
        return losses.sum() / jnp.maximum(pair_ok.sum(), 1.0)

    def metric(self, raw, y):
        """Pairwise ranking accuracy (fraction of correctly ordered pairs)."""
        s, valid = self._padded(raw[:, 0])
        rel, _ = self._padded(y)
        better = (rel[:, :, None] > rel[:, None, :])
        pair_ok = (better & valid[:, :, None] & valid[:, None, :]).astype(
            jnp.float32)
        correct = ((s[:, :, None] > s[:, None, :]).astype(jnp.float32)
                   * pair_ok)
        return correct.sum() / jnp.maximum(pair_ok.sum(), 1.0)


def make_loss(name: str, *, n_classes: int = 2,
              group_index: Optional[np.ndarray] = None,
              alpha: float = 0.5) -> Loss:
    name = name.lower()
    if name == "rmse":
        return RMSE()
    if name == "mae":
        return MAE()
    if name == "quantile":
        return Quantile(alpha=alpha)
    if name == "logloss":
        return LogLoss()
    if name == "multiclass":
        return MultiClass(n_classes=n_classes)
    if name in ("pairlogit", "yetirank"):
        return PairLogitGrouped(group_index=group_index)
    raise ValueError(f"unknown loss {name!r}")
