"""KNN embedding featurizer (paper: image-embeddings workload).

CatBoost's embedding features run KNN over stored training embeddings;
the hotspot is L2SqrDistance (paper Table 4: 91.6% of total time before
vectorization).  Features produced per query embedding:
  - per-class fraction among the k nearest neighbours   (C features)
  - mean distance to the k nearest                      (1 feature)

The distance computation uses the MXU matrix kernel (beyond-paper batched
form); `rowwise=True` switches to the paper-faithful per-query kernel.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclasses.dataclass(eq=False)
class KNNFeaturizer:
    train_embeddings: jax.Array       # (M, K) float32
    train_labels: jax.Array           # (M,) int32
    n_classes: int
    k: int = 16

    @property
    def n_features(self) -> int:
        return self.n_classes + 1

    def transform(self, queries: jax.Array, *, backend: str = "auto",
                  rowwise: bool = False, batch_size: int = 4096
                  ) -> jax.Array:
        """(Q, K) embeddings -> (Q, n_classes + 1) KNN features."""
        outs = []
        for s in range(0, queries.shape[0], batch_size):
            q = queries[s:s + batch_size]
            if rowwise:
                dists = jnp.stack([
                    ops.l2sq_rowwise(q[i], self.train_embeddings,
                                     backend=backend)
                    for i in range(q.shape[0])])
            else:
                dists = ops.l2sq_matrix(q, self.train_embeddings,
                                        backend=backend)
            outs.append(self._features_from_dists(dists))
        return jnp.concatenate(outs, axis=0)

    @functools.partial(jax.jit, static_argnums=0)
    def _features_from_dists(self, dists: jax.Array) -> jax.Array:
        neg_top, nbr_idx = jax.lax.top_k(-dists, self.k)        # (Q, k)
        nbr_labels = self.train_labels[nbr_idx]                 # (Q, k)
        onehot = jax.nn.one_hot(nbr_labels, self.n_classes)     # (Q, k, C)
        frac = jnp.mean(onehot, axis=1)                         # (Q, C)
        mean_dist = jnp.mean(-neg_top, axis=1, keepdims=True)   # (Q, 1)
        return jnp.concatenate([frac, mean_dist], axis=1)


def augment_with_knn(x: np.ndarray, emb: np.ndarray,
                     featurizer: KNNFeaturizer, **kw) -> np.ndarray:
    """Concatenate tabular features with KNN features over embeddings."""
    feats = featurizer.transform(jnp.asarray(emb), **kw)
    return np.concatenate([np.asarray(x, np.float32), np.asarray(feats)],
                          axis=1)
