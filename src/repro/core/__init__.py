"""Core: the paper's contribution — vectorized oblivious-tree GBDT in JAX.

Compiled-plan prediction lives in `predictor` (`PredictConfig` +
`Predictor`, the prepare-once API); `predict` keeps the legacy kwarg
shims.  Training substrate in `boosting`; model structure in `trees`;
KNN embedding features in `knn`.
"""
from repro.core import (boosting, knn, losses, predict, predictor,  # noqa: F401
                        quantize, trees)
from repro.core.predictor import PredictConfig, Predictor  # noqa: F401
