"""Core: the paper's contribution — vectorized oblivious-tree GBDT in JAX.

Compiled-plan prediction lives in `predictor` (`PredictConfig` +
`Predictor`, the prepare-once API); `predict` keeps the legacy kwarg
shims.  Training substrate in `boosting`; model structure in `trees`;
physical model layouts (the lowering layer between plans and kernels)
in `layout`; KNN embedding features in `knn`.
"""
from repro.core import (boosting, knn, layout, losses, predict,  # noqa: F401
                        predictor, quantize, trees)
from repro.core.predictor import PredictConfig, Predictor  # noqa: F401
