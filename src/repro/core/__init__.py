"""Core: the paper's contribution — vectorized oblivious-tree GBDT in JAX.

Prediction pipeline (paper fig. 1) lives in `predict`; training substrate
in `boosting`; model structure in `trees`; KNN embedding features in `knn`.
"""
from repro.core import boosting, knn, losses, predict, quantize, trees  # noqa: F401
