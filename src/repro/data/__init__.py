"""Data substrate: synthetic paper workloads + batching/sharding pipeline."""
from repro.data import pipeline, synthetic  # noqa: F401
