"""Data pipeline: batching, device sharding, background prefetch.

Host-side numpy batching with a double-buffered prefetch thread, plus
sharded device placement for the production meshes.  Also provides the
synthetic token stream used by LM training (examples/train_lm.py and the
trainer tests) — real deployments would swap `TokenSource` for a file-
backed loader; the interface (`__iter__` yielding dict batches) is the
contract.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class BatchIterator:
    """Shuffled epoch iterator over array dicts."""

    def __init__(self, arrays: dict[str, np.ndarray], batch_size: int, *,
                 shuffle: bool = True, seed: int = 0,
                 drop_remainder: bool = True):
        self.arrays = arrays
        self.n = next(iter(arrays.values())).shape[0]
        for v in arrays.values():
            assert v.shape[0] == self.n, "ragged arrays"
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.rng = np.random.default_rng(seed)
        self.drop_remainder = drop_remainder

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        order = (self.rng.permutation(self.n) if self.shuffle
                 else np.arange(self.n))
        stop = (self.n - self.n % self.batch_size if self.drop_remainder
                else self.n)
        for s in range(0, stop, self.batch_size):
            sel = order[s:s + self.batch_size]
            yield {k: v[sel] for k, v in self.arrays.items()}


class TokenSource:
    """Synthetic LM token stream: (tokens, labels) with next-token labels."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)

    def next_batch(self, step: int | None = None) -> dict[str, np.ndarray]:
        rng = (np.random.default_rng(step) if step is not None else self.rng)
        # Markov-ish stream so a model can actually reduce loss.
        base = rng.integers(0, self.vocab_size,
                            size=(self.batch_size, self.seq_len + 1))
        base[:, 1::2] = (base[:, 0::2][:, :base[:, 1::2].shape[1]]
                         + 1) % self.vocab_size
        return {"tokens": base[:, :-1].astype(np.int32),
                "labels": base[:, 1:].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.next_batch(step)
            step += 1


def shard_batch(batch: dict[str, np.ndarray], mesh: Mesh,
                spec: P = P(("data",))) -> dict[str, jax.Array]:
    sharding = NamedSharding(mesh, spec)
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


class Prefetcher:
    """Background-thread double buffering between host batching and device.

    At multi-pod scale the same pattern runs per host; the queue bound is
    the straggler cushion (a slow host falls behind by at most `depth`
    batches before backpressure kicks in).
    """

    def __init__(self, it: Iterator, *, depth: int = 2,
                 transform: Optional[Callable] = None):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.transform = transform
        # A worker exception must reach the consumer: without this, an
        # error raised by `it` or `transform` would hit the bare
        # `finally: q.put(None)` and the consumer would see a clean
        # end-of-stream — silently truncated output.
        self._err: Optional[BaseException] = None

        def worker():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    if self.transform is not None:
                        item = self.transform(item)
                    self.q.put(item)
            except BaseException as e:      # noqa: BLE001 — re-raised below
                self._err = e
            finally:
                self.q.put(None)

        # named so trace exports label this track (obs.trace reads
        # thread names for its Chrome thread_name metadata rows)
        self.thread = threading.Thread(target=worker, daemon=True,
                                       name="prefetcher")
        self.thread.start()

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is None:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                return
            yield item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
