"""Synthetic datasets matching the paper's five workloads (Table 1).

The container is offline, so we generate learnable synthetic data with the
exact shapes, class counts and loss functions of the paper's benchmarks.
Every generator is deterministic (seeded) and supports `scale` to shrink
row counts for tests while keeping feature dimensionality faithful.

| name              | rows x cols      | classes | loss       | depth | lr   |
|-------------------|------------------|---------|------------|-------|------|
| mq2008            | 9630 x 46        | (rank)  | YetiRank   | 6     | 0.02 |
| santander         | 400000 x 200     | 2       | LogLoss    | 1     | 0.01 |
| covertype         | 464800 x 54      | 7       | MultiClass | 8     | 0.50 |
| year_prediction   | 515345 x 90      | (reg)   | MAE        | 6     | 0.30 |
| image_embeddings  | 5649 x 512 (emb) | 20      | MultiClass | 4     | 0.05 |
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.boosting import BoostingParams


@dataclasses.dataclass
class Dataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    loss: str
    n_classes: int = 0
    params: BoostingParams = dataclasses.field(
        default_factory=BoostingParams)
    group_index_train: Optional[np.ndarray] = None   # ranking only, (G, S)
    group_index_test: Optional[np.ndarray] = None
    emb_train: Optional[np.ndarray] = None           # embeddings only
    emb_test: Optional[np.ndarray] = None

    @property
    def shape(self):
        return self.x_train.shape, self.x_test.shape


def _class_mixture(rng, n, f, c, *, informative=0.4, noise=1.0,
                   integer_frac=0.0):
    """Gaussian class mixture with optional integer-valued features."""
    n_inf = max(2, int(f * informative))
    centers = rng.normal(scale=2.0, size=(c, n_inf))
    y = rng.integers(0, c, size=n)
    x = rng.normal(scale=noise, size=(n, f)).astype(np.float32)
    x[:, :n_inf] += centers[y]
    if integer_frac > 0:
        n_int = int(f * integer_frac)
        x[:, -n_int:] = np.round(x[:, -n_int:] * 3)
    return x.astype(np.float32), y.astype(np.int32)


def covertype(scale: float = 1.0, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    n = int(464800 * scale)
    x, y = _class_mixture(rng, n, 54, 7, informative=0.5, integer_frac=0.4)
    cut = int(n * 0.7)                    # paper: 70:30 split
    return Dataset("covertype", x[:cut], y[:cut], x[cut:], y[cut:],
                   loss="multiclass", n_classes=7,
                   params=BoostingParams(depth=8, learning_rate=0.5))


def santander(scale: float = 1.0, seed: int = 1) -> Dataset:
    rng = np.random.default_rng(seed)
    n = int(400000 * scale)
    x, y = _class_mixture(rng, 2 * n, 200, 2, informative=0.2, noise=2.0)
    # non-normalized: scale features wildly, like the real Santander data
    x *= rng.lognormal(1.0, 1.0, size=(1, 200)).astype(np.float32)
    return Dataset("santander", x[:n], y[:n], x[n:], y[n:],
                   loss="logloss", n_classes=2,
                   params=BoostingParams(depth=1, learning_rate=0.01))


def year_prediction_msd(scale: float = 1.0, seed: int = 2) -> Dataset:
    rng = np.random.default_rng(seed)
    n_tr, n_te = int(463715 * scale), int(51630 * scale)
    n = n_tr + n_te
    f = 90
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=(f,)).astype(np.float32) * (rng.random(f) < 0.3)
    year = 1965.0 + 15.0 * np.tanh(x @ w / 3.0) + 20.0 * rng.random(n) + \
        5.0 * np.sin(x[:, 0] * 2)
    y = np.clip(year, 1922, 2011).astype(np.float32)
    x *= rng.lognormal(0.5, 0.8, size=(1, f)).astype(np.float32)
    return Dataset("year_prediction_msd", x[:n_tr], y[:n_tr],
                   x[n_tr:], y[n_tr:], loss="mae",
                   params=BoostingParams(depth=6, learning_rate=0.3))


def _group_index(rng, n_docs, avg_group):
    """Pack n_docs into groups; return (G, S) -1-padded index matrix."""
    sizes = []
    left = n_docs
    while left > 0:
        s = int(np.clip(rng.poisson(avg_group), 2, 120))
        s = min(s, left)
        sizes.append(s)
        left -= s
    S = max(sizes)
    gi = np.full((len(sizes), S), -1, np.int32)
    pos = 0
    for g, s in enumerate(sizes):
        gi[g, :s] = np.arange(pos, pos + s)
        pos += s
    return gi


def mq2008(scale: float = 1.0, seed: int = 3) -> Dataset:
    rng = np.random.default_rng(seed)
    n_tr, n_te = int(9630 * scale), int(2874 * scale)
    f = 46
    w = rng.normal(size=(f,)).astype(np.float32)

    def make(n):
        x = rng.random(size=(n, f)).astype(np.float32)
        score = x @ w + 0.5 * rng.normal(size=n)
        rel = np.digitize(score, np.quantile(score, [0.6, 0.85])).astype(
            np.float32)          # relevance 0/1/2 like LETOR
        return x, rel

    x_tr, y_tr = make(n_tr)
    x_te, y_te = make(n_te)
    return Dataset("mq2008", x_tr, y_tr, x_te, y_te, loss="yetirank",
                   params=BoostingParams(depth=6, learning_rate=0.02),
                   group_index_train=_group_index(rng, n_tr, 12),
                   group_index_test=_group_index(rng, n_te, 12))


def image_embeddings(scale: float = 1.0, seed: int = 4) -> Dataset:
    """resnet34-style 512-dim embeddings, 20 classes (PASCAL VOC subset)."""
    rng = np.random.default_rng(seed)
    n_tr, n_te = int(2808 * scale), int(2841 * scale)
    c, k = 20, 512
    centers = rng.normal(scale=1.2, size=(c, k)).astype(np.float32)

    def make(n):
        y = rng.integers(0, c, size=n).astype(np.int32)
        e = centers[y] + rng.normal(scale=1.0, size=(n, k)).astype(np.float32)
        e = np.maximum(e, 0.0)          # post-ReLU embeddings are nonneg
        return e, y

    e_tr, y_tr = make(n_tr)
    e_te, y_te = make(n_te)
    # tabular features are the embeddings themselves; KNN features appended
    # by the featurizer at fit time (see examples/embeddings_knn.py)
    return Dataset("image_embeddings", e_tr, y_tr, e_te, y_te,
                   loss="multiclass", n_classes=20,
                   params=BoostingParams(depth=4, learning_rate=0.05),
                   emb_train=e_tr, emb_test=e_te)


REGISTRY = {
    "covertype": covertype,
    "santander": santander,
    "year_prediction_msd": year_prediction_msd,
    "mq2008": mq2008,
    "image_embeddings": image_embeddings,
}


def load(name: str, scale: float = 1.0, seed: int | None = None) -> Dataset:
    kw = {} if seed is None else {"seed": seed}
    return REGISTRY[name](scale=scale, **kw)
