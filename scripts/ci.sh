#!/usr/bin/env bash
# CI smoke: tier-1 tests + the import-time-sensitive entrypoints.
#
# The failure class this guards against: an import that works on one jax
# version and not the pinned one (e.g. `from jax import shard_map`)
# breaks the *entire* suite at collection.  Importing every package
# module first localizes such a break to one line of output.
#
#   bash scripts/ci.sh          # full tier-1 run
#   CI_QUICK=1 bash scripts/ci.sh   # skip the slow learning tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# The quick benches below write their scenario JSONs here instead of
# results/perf (which stays the committed full-run trajectory); the
# perf-regression gate at the end compares this dir against the
# committed baselines — reusing the runs CI does anyway.
PERF_FRESH="$(mktemp -d)"
trap 'rm -rf "$PERF_FRESH"' EXIT

echo "== import check (every repro module) =="
python - <<'EOF'
import importlib, pathlib, pkgutil, sys

import repro
failures = []
for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
    try:
        importlib.import_module(mod.name)
    except Exception as e:          # noqa: BLE001 — report, keep walking
        failures.append((mod.name, repr(e)))
for name, err in failures:
    print(f"IMPORT FAIL {name}: {err}", file=sys.stderr)
sys.exit(1 if failures else 0)
EOF

echo "== tier-1 pytest =="
if [[ "${CI_QUICK:-0}" == "1" ]]; then
    python -m pytest -x -q tests/test_serving.py tests/test_kernels.py \
        tests/test_kernel_blocks.py tests/test_sharding.py \
        tests/test_quantized.py
else
    python -m pytest -x -q
fi

echo "== differential kernel harness (full registry capability matrix) =="
# every (op x impl x layout x bin-dtype) cell of registry.table(),
# enumerated at collection time, vs the ref oracle — its own step so a
# kernel/oracle divergence is named before the broader suite output
python -m pytest -x -q tests/test_differential.py

echo "== kernel contract checker (static capability/dtype/VMEM lints) =="
# abstract-traces the full registry matrix (no execution, no compiles)
# and fails on any unsuppressed contract violation: uint8 widening,
# bitpacked float excursions, VMEM working sets past the tuning
# models, plan transfer/retrace hygiene, capability claims.
# --no-write keeps the committed results/analysis/ artifact.
python -m repro.launch.analyze --check --no-write >/dev/null

echo "== kernel registry smoke (introspection surface) =="
python -c "from repro.kernels import registry; rows = registry.table(); \
  assert all(any(r['op'] == op for r in rows) for op in registry.CORE_OPS); \
  print(registry.format_table())"

echo "== layout capability smoke (every layout covers the ops it claims) =="
python - <<'EOF'
from repro.core import layout
from repro.kernels import registry

for name, spec in layout.LAYOUTS.items():
    for op in spec.claimed_ops:
        impls = registry.impls_for_layout(op, name)
        assert impls, f"layout {name} claims op {op} but no impl consumes it"
# the integer bitpacked pipeline must keep its own structure kernels
assert registry.impls_for_layout("leaf_index", "bitpacked"), \
    "bitpacked lost its leaf_index impls"
assert registry.impls_for_layout("fused_predict", "bitpacked"), \
    "bitpacked lost its fused_predict impls"
assert "layouts" in registry.format_table().splitlines()[0]
print(layout.format_layout_table())
EOF

echo "== quickstart example =="
python examples/quickstart.py

echo "== serving benchmark (quick) =="
python -m benchmarks.serving_bench --quick >/dev/null

echo "== scoring smoke (BulkScorer end-to-end via launch/score.py) =="
# small synthetic dataset through the bulk-scoring CLI; --check verifies
# the streamed output against the one-shot Predictor path bit-for-bit
python -m repro.launch.score --dataset covertype --scale 0.002 \
    --trees 10 --chunk 256 --strategy staged --backend ref \
    --check >/dev/null

echo "== scoring benchmark (quick, parity + chunk-shape + throughput gate) =="
# --check fails the build unless BulkScorer output matches the naive
# predict_batch loop exactly, every bulk run compiled <= 2 chunk
# shapes, and the best scorer beats the naive loop (1.2x floor in
# quick mode).  --out-dir diverts the scenario JSONs to the perf-gate
# scratch dir (the committed results/perf/ JSONs stay untouched).
python -m benchmarks.scoring_bench --quick --check \
    --out-dir "$PERF_FRESH" >/dev/null

echo "== train smoke (streamed source -> GBDTTrainer -> exact serve parity) =="
# --check fails unless serve parity is EXACT (0.0), boosting performed
# zero binarize dispatches, histogram dispatches stayed <= depth, the
# source exceeded one chunk (genuinely out-of-core), and the train loss
# decreased
python -m repro.launch.train_gbdt --dataset covertype --scale 0.002 \
    --repeat 2 --trees 6 --depth 3 --chunk 512 --max-bins 32 \
    --backend ref --check >/dev/null

echo "== training benchmark (quick: seed-float vs pool vs streamed) =="
# --check fails unless the pool path reproduces the seed float scan to
# the leaf-value level, streamed == pool, and a warmed pool refit
# performs zero new histogram dispatches (compiled-shape contract)
python -m benchmarks.training_bench --quick --check \
    --out-dir "$PERF_FRESH" >/dev/null

echo "== predictor smoke benchmark (prepared / prequantized / registry / layouts) =="
# --check fails the build if the prepared-plan path is below parity
# with the kwarg path it replaced, if a quantized scenario
# (prepared+prequantized vs prepared-float, quantize-once score-many
# over ModelRegistry) diverges from its float path (ref backend, so
# same kernel math), or if any lowered layout (all four: soa /
# depth_major / depth_grouped / bitpacked swept over a mixed-depth
# ensemble) diverges from the jnp reference — the layout parity gate.
# --out-dir diverts this run's JSONs to the perf-gate scratch dir so
# the committed results/perf/ trajectory is not clobbered.
python -m benchmarks.predictor_bench --quick --check \
    --out-dir "$PERF_FRESH" >/dev/null

echo "== mesh smoke (sharded parity tests + weak-scaling gate) =="
# row-sharded pool/float predict must match single-device bit-for-bit
# on every layout with zero binarize dispatches, tree-sharded psum to
# reassociated-float tolerance, and K x R registry replicas must route;
# the tests force 4 host devices in their own subprocesses, so no
# XLA_FLAGS leaks into this shell
python -m pytest -x -q tests/test_distributed_gbdt.py
# weak-scaling gate: one subprocess per device count, exact parity at
# every K and >= 1.5x rows/s at K=4 vs K=1 on the prequantized bulk
# scenario.  --out-dir diverts the JSONs to the perf-gate scratch dir.
python -m benchmarks.mesh_bench --quick --check \
    --out-dir "$PERF_FRESH" >/dev/null

echo "== observability smoke (span tracer + metrics hub end to end) =="
# a tiny bulk-scoring run with --trace-out/--metrics-out, then assert
# the Chrome trace parses and contains the span taxonomy CI depends on
# (dispatch/<op> kernel spans, compile/<entry> instants, the
# bulk/quantize|score|sink pipeline) and the metrics export carries the
# scoring snapshot
OBS_TRACE="$PERF_FRESH/obs-trace.json"
OBS_METRICS="$PERF_FRESH/obs-metrics.json"
python -m repro.launch.score --dataset covertype --scale 0.002 \
    --trees 10 --chunk 256 --strategy staged --backend ref \
    --trace-out "$OBS_TRACE" --metrics-out "$OBS_METRICS" >/dev/null
python - "$OBS_TRACE" "$OBS_METRICS" <<'EOF'
import json, sys

trace = json.load(open(sys.argv[1]))
names = [e["name"] for e in trace["traceEvents"]]
for want in ("dispatch/", "compile/", "bulk/quantize", "bulk/score",
             "bulk/sink"):
    assert any(n.startswith(want) for n in names), \
        f"trace missing {want} spans: {sorted(set(names))[:20]}"
assert all({"ph", "pid", "tid"} <= set(e) for e in
           trace["traceEvents"]), "malformed Chrome trace events"
assert all("ts" in e for e in trace["traceEvents"] if e["ph"] != "M"), \
    "timed events missing ts"
metrics = json.load(open(sys.argv[2]))
snap = metrics["metrics"]["scoring/bulk"]
assert snap["rows"] > 0 and "rows_per_s" in snap, snap
print(f"obs smoke OK: {len(names)} events, "
      f"{snap['rows']} rows metered")
EOF

echo "== perf-regression gate (fresh quick runs vs committed baselines) =="
# compares the scenario JSONs the benches above just wrote against the
# committed results/perf trajectory: speedup ratios within the
# tolerance band, parity errors capped, exactness flags and
# zero-dispatch contracts intact.  Exits non-zero on regression.
python -m repro.launch.perf_gate --check --fresh-dir "$PERF_FRESH"

echo "CI OK"
