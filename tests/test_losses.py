"""Loss derivative checks against numeric gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.data.synthetic import _group_index


def _numeric_grad(f, raw, eps=1e-3):
    g = np.zeros_like(raw)
    for i in range(raw.shape[0]):
        for c in range(raw.shape[1]):
            p = raw.copy()
            p[i, c] += eps
            m = raw.copy()
            m[i, c] -= eps
            g[i, c] = (f(p) - f(m)) / (2 * eps)
    return g


@pytest.mark.parametrize("name,make_y", [
    ("rmse", lambda rng, n: rng.normal(size=n).astype(np.float32)),
    ("logloss", lambda rng, n: (rng.random(n) < 0.5).astype(np.float32)),
    ("quantile", lambda rng, n: rng.normal(size=n).astype(np.float32)),
])
def test_grad_matches_numeric(name, make_y):
    rng = np.random.default_rng(0)
    n = 12
    y = make_y(rng, n)
    loss = losses.make_loss(name)
    raw = rng.normal(size=(n, 1)).astype(np.float32)

    def f(r):
        return float(loss.value(jnp.asarray(r), jnp.asarray(y))) * n

    g, h = loss.grad_hess(jnp.asarray(raw), jnp.asarray(y))
    gn = _numeric_grad(f, raw)
    np.testing.assert_allclose(np.asarray(g), gn, rtol=5e-2, atol=5e-2)
    assert np.all(np.asarray(h) >= 0)


def test_multiclass_grad_matches_numeric():
    rng = np.random.default_rng(1)
    n, c = 8, 4
    y = rng.integers(0, c, n)
    loss = losses.make_loss("multiclass", n_classes=c)
    raw = rng.normal(size=(n, c)).astype(np.float32)

    def f(r):
        return float(loss.value(jnp.asarray(r), jnp.asarray(y))) * n

    g, h = loss.grad_hess(jnp.asarray(raw), jnp.asarray(y))
    gn = _numeric_grad(f, raw)
    np.testing.assert_allclose(np.asarray(g), gn, rtol=5e-2, atol=5e-2)
    assert np.all(np.asarray(h) >= 0)


def test_pairlogit_grad_matches_numeric():
    rng = np.random.default_rng(2)
    n = 14
    gi = _group_index(rng, n, 5)
    y = rng.integers(0, 3, n).astype(np.float32)
    loss = losses.make_loss("yetirank", group_index=gi)
    raw = rng.normal(size=(n, 1)).astype(np.float32)
    n_pairs_norm = None

    def f(r):
        # value() is mean over pairs; grads sum over pairs -> rescale
        s, valid = loss._padded(jnp.asarray(r)[:, 0])
        rel, _ = loss._padded(jnp.asarray(y))
        better = (rel[:, :, None] > rel[:, None, :])
        ok = (better & valid[:, :, None] & valid[:, None, :])
        n_pairs = float(ok.sum())
        return float(loss.value(jnp.asarray(r), jnp.asarray(y))) * n_pairs

    g, h = loss.grad_hess(jnp.asarray(raw), jnp.asarray(y))
    gn = _numeric_grad(f, raw)
    np.testing.assert_allclose(np.asarray(g), gn, rtol=1e-1, atol=1e-1)
    assert np.all(np.asarray(h) > 0)


def test_pairlogit_gradient_sums_to_zero():
    """Pairwise losses are translation-invariant within a group."""
    rng = np.random.default_rng(3)
    n = 20
    gi = _group_index(rng, n, 6)
    y = rng.integers(0, 3, n).astype(np.float32)
    loss = losses.make_loss("yetirank", group_index=gi)
    raw = rng.normal(size=(n, 1)).astype(np.float32)
    g, _ = loss.grad_hess(jnp.asarray(raw), jnp.asarray(y))
    assert abs(float(jnp.sum(g))) < 1e-3
