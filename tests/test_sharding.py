"""Sharding rule tests: spec trees match param trees, divisibility holds,
TP/EP/FSDP axes land where designed.  No multi-device compile needed —
specs are pure metadata."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.compat import abstract_mesh
from repro.distributed import sharding as shd
from repro.models import transformer as tf


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    # AbstractMesh carries shape info without real devices
    return abstract_mesh(shape, axes)


@pytest.mark.parametrize("arch", list(configs.ARCHS))
def test_specs_cover_params_and_divide(arch):
    cfg = configs.get(arch)
    mesh = fake_mesh()
    max_pos = 32768
    shapes = tf.param_shapes(cfg, max_positions=max_pos)
    specs = shd.param_specs(cfg, mesh, max_positions=max_pos)
    flat_sh = jax.tree_util.tree_leaves_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    flat_sp = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    for (pa, shape), (pb, spec) in zip(flat_sh, flat_sp):
        assert pa == pb
        assert len(spec) <= len(shape), (pa, spec, shape)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert shape[i] % size == 0, (pa, shape, spec)


def test_tp_axes_on_dense_weights():
    cfg = configs.get("internlm2-20b")
    specs = shd.param_specs(cfg, fake_mesh())
    b = specs["blocks"]
    assert b["wq"] == P(None, "data", "model")     # fsdp + TP
    assert b["wo"] == P(None, "model", "data")
    assert b["w_gate"] == P(None, "data", "model")
    assert b["w_out"] == P(None, "model", "data")
    assert specs["embed"] == P(None, "model")


def test_moe_expert_vs_ffn_sharding():
    kimi = shd.param_specs(configs.get("kimi-k2-1t-a32b"), fake_mesh())
    assert kimi["blocks"]["w_gate"] == P(None, "model", "data", None)
    mixtral = shd.param_specs(configs.get("mixtral-8x22b"), fake_mesh())
    # 8 experts < 16-way axis -> TP inside expert ffn
    assert mixtral["blocks"]["w_gate"] == P(None, None, "data", "model")
    assert mixtral["blocks"]["w_out"] == P(None, None, "model", "data")


def test_kv_heads_not_divisible_fall_back():
    cfg = configs.get("glm4-9b")                    # kv=2 < 16
    specs = shd.param_specs(cfg, fake_mesh())
    assert specs["blocks"]["wk"] == P(None, None, None)
    assert specs["blocks"]["wq"] == P(None, None, "model")


def test_uneven_vocab_not_sharded():
    cfg = configs.get("internvl2-1b")               # vocab 151655
    specs = shd.param_specs(cfg, fake_mesh())
    assert specs["lm_head"][-1] is None


def test_batch_and_cache_specs():
    from repro.configs.base import SHAPES
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    cfg = configs.get("internlm2-20b")
    bs = shd.batch_specs(cfg, SHAPES["train_4k"], mesh)
    assert bs["tokens"] == P(("pod", "data"), None)
    bs1 = shd.batch_specs(cfg, SHAPES["long_500k"], mesh)
    assert bs1["tokens"] == P(None, None)           # batch 1: replicated
    cs = shd.cache_specs(cfg, SHAPES["decode_32k"], mesh)
    assert cs["k"][2] == "model"                    # sequence-sharded KV


def test_opt_state_specs_mirror_params():
    cfg = configs.get("glm4-9b")
    mesh = fake_mesh()
    ps = shd.param_specs(cfg, mesh)
    adam = shd.opt_state_specs(ps, "adamw")
    assert adam["m"]["blocks"]["wq"] == ps["blocks"]["wq"]
    fact = shd.opt_state_specs(ps, "adafactor")
    wq = ps["blocks"]["wq"]
    assert fact["vr"]["blocks"]["wq"] == P(*wq[:-1])
    assert fact["vc"]["blocks"]["wq"] == P(*wq[:-2], wq[-1])


def test_collective_bytes_parser():
    import importlib
    dr = importlib.import_module("repro.launch.hlo_analysis")
    hlo = """
  %ar = f32[128,256] all-reduce(f32[128,256] %x), replica_groups={}
  %ag = bf16[64]{0} all-gather(bf16[32]{0} %y), dimensions={0}
  %cp = f32[2,2]{1,0} collective-permute(f32[2,2]{1,0} %z)
  %dot = f32[128,128] dot(f32[128,64] %a, f32[64,128] %b)
"""
    got = dr.collective_bytes_from_hlo(hlo)
    assert got["all-reduce"] == 128 * 256 * 4
    assert got["all-gather"] == 64 * 2
    assert got["collective-permute"] == 16
    assert got["total"] == 128 * 256 * 4 + 128 + 16
