"""Per-architecture smoke tests (deliverable f): each assigned arch at a
reduced config runs one forward + one train step on CPU, asserting output
shapes and finiteness; plus serve-path consistency (prefill+decode ==
full forward)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import steps as steps_lib
from repro.models import transformer as tf
from repro.training import optimizer as opt_lib

ARCHS = list(configs.ARCHS)
B, S = 2, 32


def _batch(cfg, rng, seq=S, with_labels=True):
    batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                    (B, seq)).astype(np.int32)}
    if with_labels:
        batch["labels"] = rng.integers(0, cfg.vocab_size,
                                       (B, seq)).astype(np.int32)
    if cfg.frontend:
        batch["frontend_embeds"] = rng.normal(
            size=(B, cfg.frontend_seq, cfg.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = configs.get(arch, smoke=True)
    rng = np.random.default_rng(0)
    params = tf.init_params(cfg, jax.random.PRNGKey(0), max_positions=S)
    logits, aux = jax.jit(functools.partial(tf.forward, cfg))(
        params, _batch(cfg, rng))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(jnp.asarray(aux)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = configs.get(arch, smoke=True)
    rng = np.random.default_rng(1)
    params = tf.init_params(cfg, jax.random.PRNGKey(1), max_positions=S)
    opt = opt_lib.adamw(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(cfg, opt))
    batch = _batch(cfg, rng)
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(m1["loss"]) and np.isfinite(m2["loss"])
    # same batch twice: the optimizer must be making progress
    assert m2["loss"] < m1["loss"]
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree_util.tree_map(lambda a, b: a - b, p1, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.get(arch, smoke=True)
    rng = np.random.default_rng(2)
    params = tf.init_params(cfg, jax.random.PRNGKey(2),
                            max_positions=S + 8)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :S]}
    if cfg.frontend:
        fe = rng.normal(size=(B, cfg.frontend_seq,
                              cfg.d_model)).astype(np.float32)
        full["frontend_embeds"] = fe
        pre["frontend_embeds"] = fe
    logits_full, _ = jax.jit(functools.partial(tf.forward, cfg))(params,
                                                                 full)
    want = np.asarray(logits_full[:, S, :], np.float32)
    max_seq = S + 8 + (cfg.frontend_seq if cfg.family == "vlm" else 0)
    _, cache = jax.jit(functools.partial(tf.prefill, cfg,
                                         max_seq=max_seq))(params, pre)
    got_l, cache = jax.jit(functools.partial(tf.decode_step, cfg))(
        params, cache, toks[:, S:S + 1])
    got = np.asarray(got_l[:, 0, :], np.float32)
    err = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 2e-3, err


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_token_decode(arch):
    """Greedy decode 4 tokens: cache position advances, logits stay finite."""
    cfg = configs.get(arch, smoke=True)
    rng = np.random.default_rng(3)
    params = tf.init_params(cfg, jax.random.PRNGKey(3),
                            max_positions=S + 8)
    pre = _batch(cfg, rng, with_labels=False)
    max_seq = S + 8 + (cfg.frontend_seq if cfg.family == "vlm" else 0)
    logits, cache = jax.jit(functools.partial(
        tf.prefill, cfg, max_seq=max_seq))(params, pre)
    dec = jax.jit(functools.partial(tf.decode_step, cfg))
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    start = int(cache["pos"])
    for i in range(4):
        logits, cache = dec(params, cache, tok)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    assert int(cache["pos"]) == start + 4


def test_param_counts_match_literature():
    """Full configs must hit the published parameter counts (+-10%)."""
    expect = {"internlm2-20b": 20e9, "glm4-9b": 9.4e9,
              "stablelm-12b": 12.1e9, "granite-34b": 34e9,
              "zamba2-1.2b": 1.2e9, "mamba2-1.3b": 1.3e9,
              "kimi-k2-1t-a32b": 1.04e12, "mixtral-8x22b": 141e9,
              "internvl2-1b": 0.63e9, "whisper-small": 0.24e9}
    for name, want in expect.items():
        got = configs.get(name).param_count()
        assert abs(got - want) / want < 0.10, (name, got, want)
    # MoE active params
    assert abs(configs.get("kimi-k2-1t-a32b").active_param_count()
               - 32e9) / 32e9 < 0.1
    assert abs(configs.get("mixtral-8x22b").active_param_count()
               - 39e9) / 39e9 < 0.1
