"""Multi-device tests: run in subprocesses with 8 forced host devices
(XLA locks device count at first init, so the main pytest process stays
single-device; the dry-run spec forbids setting the flag globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8) -> dict:
    """Run `body` (must print one json line as last stdout line)."""
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prelude + body],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_flash_decode_matches_reference():
    res = run_sub("""
from repro.distributed.collectives import flash_decode
from repro.models.layers import decode_attention
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, S, H, KVH, Dh = 4, 32, 8, 2, 16
q = rng.normal(size=(B, H, Dh)).astype(np.float32)
k = rng.normal(size=(B, S, KVH, Dh)).astype(np.float32)
v = rng.normal(size=(B, S, KVH, Dh)).astype(np.float32)
valid = jnp.asarray(20, jnp.int32)
with mesh:
    got = flash_decode(mesh)(q, k, v, valid)
want = decode_attention(jnp.asarray(q)[:, None], jnp.asarray(k),
                        jnp.asarray(v), valid)[:, 0]
err = float(jnp.max(jnp.abs(got - want)))
print(json.dumps({"err": err}))
""")
    assert res["err"] < 1e-4, res


def test_compressed_allreduce_error_feedback():
    res = run_sub("""
from functools import partial
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import compressed_psum_grads
from repro.compat import make_mesh
mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(1)
g = rng.normal(size=(8, 64)).astype(np.float32)

def local(g, r):
    mean, r2 = compressed_psum_grads({"w": g[0]}, {"w": r[0]}, "data")
    return mean["w"][None], r2["w"][None]

fn = shard_map(local, mesh=mesh, in_specs=(P("data"), P("data")),
               out_specs=(P("data"), P("data")))
r0 = np.zeros_like(g)
with mesh:
    mean, resid = fn(g, r0)
true_mean = g.mean(0)
err = float(np.max(np.abs(np.asarray(mean)[0] - true_mean)))
scale = float(np.abs(true_mean).max())
# residual bounded by quantization step
rmax = float(np.abs(np.asarray(resid)).max())
gmax = float(np.abs(g).max(axis=1).mean())
print(json.dumps({"err": err, "scale": scale, "rmax": rmax, "gmax": gmax}))
""")
    # int8 quantization: error <= nshards * step/2 / n ~ max/254
    assert res["err"] <= res["scale"] * 0.05 + 0.02, res
    assert res["rmax"] <= res["gmax"] / 100.0, res


def test_ring_allgather_matmul():
    res = run_sub("""
from repro.distributed.collectives import ring_allgather_matmul
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(2)
x = rng.normal(size=(16, 32)).astype(np.float32)
w = rng.normal(size=(32, 8)).astype(np.float32)
with mesh:
    got = ring_allgather_matmul(mesh, axis="model")(x, w)
err = float(np.max(np.abs(np.asarray(got) - x @ w)))
print(json.dumps({"err": err}))
""")
    assert res["err"] < 1e-3, res


def test_sharded_gbdt_predict_psum():
    res = run_sub("""
from repro.core import boosting, losses, predict
from repro.core.boosting import BoostingParams
rng = np.random.default_rng(3)
x = rng.normal(size=(256, 12)).astype(np.float32)
y = (x[:, 0] + x[:, 3] > 0).astype(np.float32)
loss = losses.make_loss("logloss")
ens, _ = boosting.fit(x, y, loss=loss,
                      params=BoostingParams(n_trees=16, depth=3,
                                            learning_rate=0.3))
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
xj = jnp.asarray(x[:64])
want = np.asarray(predict.raw_predict(ens, xj, strategy="staged",
                                      backend="ref"))
with mesh:
    got = np.asarray(predict.predict_sharded(ens, xj, mesh))
err = float(np.max(np.abs(got - want)))
print(json.dumps({"err": err}))
""")
    assert res["err"] < 1e-4, res


def test_elastic_reshard_8_to_4():
    """Checkpoint written under an 8-device mesh restores on 4 devices."""
    res = run_sub("""
import tempfile
from repro import configs
from repro.data.pipeline import TokenSource
from repro.training.trainer import Trainer, TrainerConfig
from repro.launch.mesh import make_local_mesh

cfg = configs.get("glm4-9b", smoke=True)
ts = TokenSource(cfg.vocab_size, 16, 8)
def batches():
    s = 0
    while True:
        yield ts.next_batch(s); s += 1

with tempfile.TemporaryDirectory() as d:
    from repro.compat import make_mesh
    mesh8 = make_mesh((4, 2), ("data", "model"))
    tr = Trainer(cfg, mesh8, d, TrainerConfig(total_steps=4, ckpt_every=2))
    tr.init_or_restore()
    tr.train(batches())
    loss8 = None
    # restore onto a DIFFERENT mesh (2x2 over 4 devices)
    mesh4 = make_mesh((2, 2), ("data", "model"),
                      devices=jax.devices()[:4])
    tr2 = Trainer(cfg, mesh4, d, TrainerConfig(total_steps=6, ckpt_every=2))
    ok = tr2.restore()
    hist = tr2.train(batches())
    print(json.dumps({"restored": ok, "resume_step": 4,
                      "final": tr2.step,
                      "losses_finite": all(np.isfinite(h["loss"])
                                           for h in hist)}))
""")
    assert res["restored"] and res["final"] == 6 and res["losses_finite"]


def test_ring_attention_matches_plain():
    res = run_sub("""
from repro.distributed.collectives import ring_attention
from repro.models.layers import attention
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(4)
B, S, H, KVH, Dh = 2, 32, 6, 2, 8      # 6 heads: does NOT divide 4
q = rng.normal(size=(B, S, H, Dh)).astype(np.float32)
k = rng.normal(size=(B, S, KVH, Dh)).astype(np.float32)
v = rng.normal(size=(B, S, KVH, Dh)).astype(np.float32)
with mesh:
    got = ring_attention(mesh)(q, k, v)
want = attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                 causal=True)
err = float(jnp.max(jnp.abs(got - want)))
print(json.dumps({"err": err}))
""")
    assert res["err"] < 1e-4, res
