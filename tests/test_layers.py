"""Layer-level correctness: attention causality/chunk-equivalence, RoPE
properties, MoE dispatch conservation, SSD vs naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as ll
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib


def test_attention_causal():
    """Perturbing a future token must not change earlier outputs."""
    rng = np.random.default_rng(0)
    B, S, H, KVH, Dh = 2, 16, 4, 2, 8
    q = rng.normal(size=(B, S, H, Dh)).astype(np.float32)
    k = rng.normal(size=(B, S, KVH, Dh)).astype(np.float32)
    v = rng.normal(size=(B, S, KVH, Dh)).astype(np.float32)
    out1 = np.asarray(ll.attention(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), causal=True))
    k2, v2 = k.copy(), v.copy()
    k2[:, -1] += 10.0
    v2[:, -1] -= 5.0
    out2 = np.asarray(ll.attention(jnp.asarray(q), jnp.asarray(k2),
                                   jnp.asarray(v2), causal=True))
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], atol=1e-6)
    assert np.abs(out1[:, -1] - out2[:, -1]).max() > 1e-3


def test_attention_chunked_equals_plain():
    rng = np.random.default_rng(1)
    B, S, H, KVH, Dh = 2, 64, 4, 4, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KVH, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KVH, Dh)).astype(np.float32))
    plain = ll.attention(q, k, v, causal=True)
    chunked = ll.attention(q, k, v, causal=True, q_chunk=16)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_masks_older():
    rng = np.random.default_rng(2)
    B, S, H, Dh, W = 1, 32, 2, 8, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    v = rng.normal(size=(B, S, H, Dh)).astype(np.float32)
    out1 = np.asarray(ll.attention(q, k, jnp.asarray(v), causal=True,
                                   window=W))
    v2 = v.copy()
    v2[:, 0] += 100.0                 # outside the window of position 31
    out2 = np.asarray(ll.attention(q, k, jnp.asarray(v2), causal=True,
                                   window=W))
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-5)


def test_rope_preserves_norm_and_relative_angle():
    rng = np.random.default_rng(3)
    B, S, H, Dh = 1, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    pos = jnp.arange(S)
    rot = ll.apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(rot), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # dot(q_i, k_j) depends only on i-j: shift both positions by 5
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    rot_q1 = ll.apply_rope(q, pos, 1e4)
    rot_k1 = ll.apply_rope(x, pos, 1e4)
    rot_q2 = ll.apply_rope(q, pos + 5, 1e4)
    rot_k2 = ll.apply_rope(x, pos + 5, 1e4)
    d1 = np.einsum("bshd,bshd->bsh", np.asarray(rot_q1), np.asarray(rot_k1))
    d2 = np.einsum("bshd,bshd->bsh", np.asarray(rot_q2), np.asarray(rot_k2))
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)


def _moe_weights(rng, E, D, F):
    return (jnp.asarray(rng.normal(size=(D, E)).astype(np.float32)) * 0.3,
            jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32)) * 0.1,
            jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32)) * 0.1,
            jnp.asarray(rng.normal(size=(E, F, D)).astype(np.float32)) * 0.1)


def test_moe_matches_dense_mixture_when_capacity_ample():
    rng = np.random.default_rng(4)
    T, D, E, F, k = 32, 16, 4, 24, 2
    router, wg, wi, wo = _moe_weights(rng, E, D, F)
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    y, metrics = moe_lib.moe_ffn(x, router, wg, wi, wo, top_k=k,
                                 group_size=T, capacity_factor=8.0)
    # dense reference: every expert on every token, combine with gates
    logits = x @ router
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    dense = jnp.zeros_like(x)
    for e in range(E):
        ye = (jax.nn.silu(x @ wg[e]) * (x @ wi[e])) @ wo[e]
        w = jnp.where(top_e == e, top_p, 0.0).sum(-1)
        dense = dense + w[:, None] * ye
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)
    assert float(metrics.drop_frac) == 0.0


def test_moe_drops_overflow_but_stays_finite():
    rng = np.random.default_rng(5)
    T, D, E, F, k = 64, 8, 4, 16, 2
    router, wg, wi, wo = _moe_weights(rng, E, D, F)
    # all tokens identical -> all route to the same experts -> overflow
    x = jnp.ones((T, D), jnp.float32)
    y, metrics = moe_lib.moe_ffn(x, router, wg, wi, wo, top_k=k,
                                 group_size=T, capacity_factor=0.25)
    assert float(metrics.drop_frac) > 0.3
    assert bool(jnp.all(jnp.isfinite(y)))


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step state recurrence (the decode rule)."""
    rng = np.random.default_rng(6)
    B, S, D = 2, 32, 16
    dims = ssm_lib.ssm_dims(D, headdim=8, d_state=4)
    params = ssm_lib.init_ssm_params(jax.random.PRNGKey(0), D, dims)
    u = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32)) * 0.5

    chunked = ssm_lib.ssd_forward(params, u, dims, chunk=8)
    # naive: feed one token at a time through the decode step
    cache = ssm_lib.init_ssm_cache(B, dims)
    outs = []
    for t in range(S):
        y, cache = ssm_lib.ssd_decode_step(params, u[:, t:t + 1], cache,
                                           dims)
        outs.append(y)
    naive = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               rtol=2e-3, atol=2e-3)


def test_ssd_cache_handoff():
    """forward(return_cache) state == state after decoding all tokens."""
    rng = np.random.default_rng(7)
    B, S, D = 1, 16, 8
    dims = ssm_lib.ssm_dims(D, headdim=4, d_state=4)
    params = ssm_lib.init_ssm_params(jax.random.PRNGKey(1), D, dims)
    u = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32)) * 0.5
    _, cache_fwd = ssm_lib.ssd_forward(params, u, dims, chunk=8,
                                       return_cache=True)
    cache = ssm_lib.init_ssm_cache(B, dims)
    for t in range(S):
        _, cache = ssm_lib.ssd_decode_step(params, u[:, t:t + 1], cache,
                                           dims)
    np.testing.assert_allclose(np.asarray(cache_fwd.h),
                               np.asarray(cache.h), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache_fwd.conv),
                               np.asarray(cache.conv), rtol=1e-4, atol=1e-4)