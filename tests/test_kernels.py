"""Pallas kernel validation: interpret=True vs pure-jnp oracles, shape sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import binarize as bk
from repro.kernels import fused_predict as fk
from repro.kernels import l2dist as lk
from repro.kernels import leaf_gather as gk
from repro.kernels import leaf_index as ik


def _toy_ensemble(rng, T, D, F, C, n_bins=32):
    sf = rng.integers(0, F, size=(T, D)).astype(np.int32)
    sb = rng.integers(1, n_bins, size=(T, D)).astype(np.int32)
    lv = rng.normal(size=(T, 2 ** D, C)).astype(np.float32)
    return jnp.asarray(sf), jnp.asarray(sb), jnp.asarray(lv)


def _borders(rng, B, F):
    b = np.sort(rng.normal(size=(B, F)).astype(np.float32), axis=0)
    return jnp.asarray(b)


@pytest.mark.parametrize("N,F,B", [(256, 128, 16), (100, 52, 32),
                                   (513, 200, 255), (32, 1, 1)])
def test_binarize_kernel(N, F, B):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(N, F)).astype(np.float32))
    borders = _borders(rng, B, F)
    got = ops.binarize(x, borders, backend="pallas")
    want = ref.binarize(x, borders)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("N,F,T,D", [(256, 128, 16, 6), (100, 52, 37, 8),
                                     (64, 90, 100, 1), (512, 512, 8, 4)])
def test_leaf_index_kernel(N, F, T, D):
    rng = np.random.default_rng(1)
    bins = jnp.asarray(rng.integers(0, 32, size=(N, F)).astype(np.int32))
    sf, sb, _ = _toy_ensemble(rng, T, D, F, 1)
    got = ops.leaf_index(bins, sf, sb, backend="pallas")
    want = ref.leaf_index(bins, sf, sb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("N,T,D,C", [(128, 16, 6, 1), (100, 37, 8, 7),
                                     (64, 100, 4, 20), (256, 8, 1, 2)])
def test_leaf_gather_kernel(N, T, D, C):
    rng = np.random.default_rng(2)
    idx = jnp.asarray(rng.integers(0, 2 ** D, size=(N, T)).astype(np.int32))
    _, _, lv = _toy_ensemble(rng, T, D, 8, C)
    got = ops.leaf_gather(idx, lv, backend="pallas")
    want = ref.leaf_gather(idx, lv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("N,K", [(256, 128), (100, 512), (37, 90), (8, 8)])
def test_l2_rowwise_kernel(N, K):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(K,)).astype(np.float32))
    refs = jnp.asarray(rng.normal(size=(N, K)).astype(np.float32))
    got = ops.l2sq_rowwise(q, refs, backend="pallas")
    want = ref.l2sq_rowwise(q, refs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (100, 200, 512),
                                   (37, 61, 90), (300, 50, 256)])
def test_l2_matrix_kernel(M, N, K):
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(N, K)).astype(np.float32))
    got = ops.l2sq_matrix(a, b, backend="pallas")
    want = ref.l2sq_matrix(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("N,F,T,D,C,B", [(128, 52, 16, 6, 1, 32),
                                         (100, 90, 40, 6, 1, 255),
                                         (64, 54, 24, 8, 7, 16),
                                         (200, 512, 10, 4, 20, 64)])
def test_fused_predict_kernel(N, F, T, D, C, B):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(N, F)).astype(np.float32))
    borders = _borders(rng, B, F)
    sf, sb, lv = _toy_ensemble(rng, T, D, F, C, n_bins=B)
    got = ops.fused_predict(x, borders, sf, sb, lv, backend="pallas")
    want = ref.fused_predict(x, borders, sf, sb, lv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_pipeline_composition_matches_fused():
    """binarize |> leaf_index |> leaf_gather == fused_predict (both backends)."""
    rng = np.random.default_rng(6)
    N, F, T, D, C, B = 90, 46, 50, 6, 1, 128
    x = jnp.asarray(rng.normal(size=(N, F)).astype(np.float32))
    borders = _borders(rng, B, F)
    sf, sb, lv = _toy_ensemble(rng, T, D, F, C, n_bins=B)
    for backend in ("ref", "pallas"):
        bins = ops.binarize(x, borders, backend=backend)
        idx = ops.leaf_index(bins, sf, sb, backend=backend)
        staged = ops.leaf_gather(idx, lv, backend=backend)
        fused = ops.fused_predict(x, borders, sf, sb, lv, backend=backend)
        np.testing.assert_allclose(np.asarray(staged), np.asarray(fused),
                                   rtol=1e-5, atol=1e-4)


def test_border_edge_semantics():
    """Strict '>' border semantics: x == border stays in the lower bin."""
    x = jnp.asarray([[0.0, 1.0, 1.5, 2.0, 2.5]], dtype=jnp.float32).T
    borders = jnp.asarray([[1.0], [2.0]], dtype=jnp.float32)
    x = x.reshape(5, 1)
    got_ref = ref.binarize(x, borders)
    got_pl = ops.binarize(x, borders, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got_ref).ravel(),
                                  [0, 0, 1, 1, 2])
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(got_ref))


@pytest.mark.parametrize("F,N,C,B,L", [(8, 256, 1, 16, 8), (6, 100, 7, 32, 4),
                                       (16, 512, 3, 8, 16)])
def test_histogram_kernel(F, N, C, B, L):
    from repro.kernels import histogram as hk
    rng = np.random.default_rng(7)
    bins_t = rng.integers(0, B, (F, N)).astype(np.int32)
    leaf = rng.integers(0, L, (N,)).astype(np.int32)
    g = rng.normal(size=(N, C)).astype(np.float32)
    Fp = ((F + 7) // 8) * 8
    Np = ((N + 255) // 256) * 256
    bt = np.zeros((Fp, Np), np.int32)
    bt[:F, :N] = bins_t
    lf = np.zeros((Np,), np.int32)
    lf[:N] = leaf
    gg = np.zeros((Np, C), np.float32)
    gg[:N] = g                       # padded samples carry g == 0
    got = hk.histogram(jnp.asarray(bt), jnp.asarray(lf), jnp.asarray(gg),
                       n_bins=B, n_leaves=L, interpret=True)[:F]
    want = hk.histogram_ref(jnp.asarray(bins_t), jnp.asarray(leaf),
                            jnp.asarray(g), n_bins=B, n_leaves=L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("in_dtype", [np.float32, jnp.bfloat16])
def test_binarize_dtype_sweep(in_dtype):
    """bf16 inputs: binarize via f32 cast matches the f32 oracle on the
    bf16-representable values."""
    rng = np.random.default_rng(8)
    x32 = rng.normal(size=(64, 20)).astype(np.float32)
    x = jnp.asarray(x32).astype(in_dtype)
    borders = jnp.asarray(np.sort(rng.normal(size=(9, 20)), 0)
                          .astype(np.float32))
    got = ops.binarize(x.astype(jnp.float32), borders, backend="pallas")
    want = ref.binarize(x.astype(jnp.float32), borders)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("in_dtype", [np.float32, jnp.bfloat16])
def test_l2_dtype_sweep(in_dtype):
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.normal(size=(40, 64)).astype(np.float32)).astype(
        in_dtype).astype(jnp.float32)
    b = jnp.asarray(rng.normal(size=(30, 64)).astype(np.float32)).astype(
        in_dtype).astype(jnp.float32)
    got = ops.l2sq_matrix(a, b, backend="pallas")
    want = ref.l2sq_matrix(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bins_dtype", [np.int32, np.uint8])
def test_leaf_index_bins_dtype_sweep(bins_dtype):
    """u8 bin storage (CatBoost's on-disk format) -> i32 compute."""
    rng = np.random.default_rng(10)
    bins = rng.integers(0, 32, (100, 24)).astype(bins_dtype)
    sf = jnp.asarray(rng.integers(0, 24, (20, 6)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, 32, (20, 6)).astype(np.int32))
    got = ops.leaf_index(jnp.asarray(bins.astype(np.int32)), sf, sb,
                         backend="pallas")
    want = ref.leaf_index(jnp.asarray(bins.astype(np.int32)), sf, sb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
