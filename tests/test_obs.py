"""Observability subsystem: span tracer (disabled overhead, ring
eviction, Chrome-trace schema, cross-thread spans), the MetricsHub
exports, and the deadline-SLO accounting in ServerMetrics."""
import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import PredictConfig, Predictor
from repro.core.trees import ObliviousEnsemble
from repro.obs import MetricsHub
from repro.obs.trace import Tracer, get_tracer, tracing
from repro.scoring import ArraySink, ArraySource, BulkScorer, ScoreConfig
from repro.serving.metrics import ServerMetrics


def _rand_ensemble(seed=3, n_trees=9, depth=4, n_features=7,
                   n_borders=9, n_outputs=1):
    rng = np.random.default_rng(seed)
    borders = jnp.asarray(
        np.sort(rng.normal(size=(n_borders, n_features)), 0)
        .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, n_features,
                                  (n_trees, depth)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, n_borders,
                                  (n_trees, depth)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(n_trees, 2 ** depth, n_outputs))
                     .astype(np.float32))
    return ObliviousEnsemble(sf, sb, lv, borders,
                             jnp.full((n_features,), n_borders, jnp.int32))


# --------------------------------------------------------------------------
# Tracer core
# --------------------------------------------------------------------------
def test_disabled_span_is_shared_noop_and_records_nothing():
    tr = Tracer()
    s1 = tr.span("a", "cat", big_attr="x" * 100)
    s2 = tr.span("b")
    assert s1 is s2                       # singleton: no allocation
    with s1:
        pass
    tr.instant("i")
    tr.counter("c", v=1.0)
    tr.complete("x", start_ns=0, duration_ns=1)
    assert len(tr) == 0


def test_disabled_overhead_is_small():
    # the hot-path contract: a disabled span() call is an attribute
    # load + bool test.  Loose wall-clock bound (CI boxes are noisy) —
    # this catches accidental allocation/locking on the disabled path,
    # not nanosecond regressions.
    tr = Tracer()
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("hot"):
            pass
    dt = time.perf_counter() - t0
    assert dt / n < 5e-6, f"{dt / n * 1e9:.0f}ns per disabled span"


def test_ring_eviction_is_fifo_and_counts_drops():
    tr = Tracer(capacity=4)
    tr.enable()
    for i in range(7):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert [e["name"] for e in tr.events()] == ["e3", "e4", "e5", "e6"]
    assert tr.dropped == 3


def test_span_records_duration_and_attrs():
    tr = Tracer()
    tr.enable()
    with tr.span("work", "cat", rows=128) as sp:
        sp.set(result="ok")
        time.sleep(0.002)
    (e,) = tr.events()
    assert e["ph"] == "X" and e["name"] == "work"
    assert e["dur_us"] >= 2000
    assert e["args"] == {"rows": 128, "result": "ok"}


def test_complete_event_matches_span_timebase():
    tr = Tracer()
    tr.enable()
    t0 = time.perf_counter_ns()
    tr.complete("pre-timed", "train", start_ns=t0, duration_ns=5000,
                level=2)
    with tr.span("live"):
        pass
    pre, live = tr.events()
    assert pre["dur_us"] == 5.0 and pre["args"] == {"level": 2}
    # same clock: the pre-timed event sits just before the live span
    assert pre["ts_us"] <= live["ts_us"]


def test_chrome_export_schema(tmp_path):
    tr = Tracer()
    tr.enable()
    with tr.span("dispatch/leaf_index", "kernel", op="leaf_index"):
        pass
    tr.instant("compile/raw", "compile", batch=64)
    tr.counter("dispatch_count", "kernel", leaf_index=1.0)
    path = tmp_path / "trace.json"
    obj = tr.export_chrome(path)
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(obj))
    evs = loaded["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert metas and metas[0]["name"] == "thread_name"
    x = next(e for e in evs if e["ph"] == "X")
    assert {"name", "cat", "ts", "dur", "pid", "tid", "args"} <= set(x)
    assert isinstance(x["ts"], float) and x["pid"] == 1
    assert x["tid"] == 0                  # idents remapped to small ints
    i = next(e for e in evs if e["ph"] == "i")
    assert i["s"] == "t"
    c = next(e for e in evs if e["ph"] == "C")
    assert c["args"] == {"leaf_index": 1.0}
    assert loaded["otherData"]["dropped_events"] == 0


def test_export_names_threads_that_already_exited(tmp_path):
    tr = Tracer()
    tr.enable()

    def work():
        with tr.span("bg-span"):
            pass

    t = threading.Thread(target=work, name="my-worker")
    t.start()
    t.join()                    # the thread is dead before export
    obj = tr.export_chrome(tmp_path / "t.json")
    names = [e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M"]
    assert "my-worker" in names


def test_tracing_context_restores_prior_state():
    tr = Tracer()
    with tracing(tr):
        assert tr.enabled
        with tracing(tr):
            pass
        assert tr.enabled            # inner exit restores True
    assert not tr.enabled


# --------------------------------------------------------------------------
# Instrumentation integration: a traced BulkScorer run
# --------------------------------------------------------------------------
def test_bulk_scorer_trace_shows_prefetch_overlap(tmp_path):
    ens = _rand_ensemble()
    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(700, ens.n_features)).astype(np.float32)
    tracer = get_tracer()
    with tracing(tracer, clear=True):
        scorer = BulkScorer({"m": plan},
                            ScoreConfig(chunk_rows=256, prequantize=True))
        scorer.score(ArraySource(x), {"m": ArraySink()})
        events = tracer.events()
    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    # the pipeline spans all fired, once per chunk for quantize/score
    assert len(by_name["bulk/quantize"]) == len(by_name["bulk/score"])
    assert len(by_name["bulk/quantize"]) >= 3
    assert "bulk/sink" in by_name
    # kernel dispatches are tagged with op/impl/layout
    disp = [e for n, evs in by_name.items() if n.startswith("dispatch/")
            for e in evs]
    assert disp and all({"op", "impl", "layout"} <= set(e["args"])
                        for e in disp)
    # prefetch overlap: quantize happens on the worker thread, scoring
    # on the caller thread — distinct tids is what makes the overlap
    # visible on the exported timeline
    q_tids = {e["tid"] for e in by_name["bulk/quantize"]}
    s_tids = {e["tid"] for e in by_name["bulk/score"]}
    assert q_tids and s_tids and not (q_tids & s_tids)
    obj = tracer.export_chrome(tmp_path / "bulk.json")
    thread_labels = {e["args"]["name"] for e in obj["traceEvents"]
                     if e["ph"] == "M"}
    assert "prefetcher" in thread_labels
    assert not tracer.enabled        # context restored


# --------------------------------------------------------------------------
# Deadline-SLO accounting
# --------------------------------------------------------------------------
def test_server_metrics_slo_math():
    m = ServerMetrics("m", deadline_ms=10.0)
    m.note_batch(4, 8, 0.005)        # 5ms: hit, 4 rows
    m.note_batch(2, 2, 0.020)        # 20ms: miss, 2 rows
    m.note_shed(3)
    s = m.snapshot()
    assert s["deadline_hits"] == 4 and s["deadline_misses"] == 2
    assert s["deadline_attainment"] == pytest.approx(4 / 6)
    assert s["shed_requests"] == 3
    assert s["shed_rate"] == pytest.approx(3 / 9)   # 6 served + 3 shed
    # p99-under-deadline sees only the 5ms batch; overall p99 sees both
    assert s["p99_under_deadline_ms"] == pytest.approx(5.0)
    assert s["batch_p99_ms"] > 5.0


def test_server_metrics_slo_disabled_is_vacuous():
    m = ServerMetrics("m")
    m.note_batch(4, 4, 0.5)
    s = m.snapshot()
    assert s["deadline_ms"] is None
    assert s["deadline_attainment"] == 1.0
    assert s["deadline_hits"] == 0 and s["deadline_misses"] == 0
    assert s["shed_rate"] == 0.0


def test_server_metrics_interval_rates_and_reset():
    m = ServerMetrics("m")
    m.note_batch(10, 10, 0.001)
    s1 = m.snapshot()
    assert s1["interval_requests_per_s"] > 0
    s2 = m.snapshot()                 # nothing since the last poll
    assert s2["interval_requests_per_s"] == 0.0
    assert s2["requests_per_s"] > 0   # lifetime rate persists
    m.reset()
    s3 = m.snapshot()
    assert s3["requests"] == 0 and s3["batch_p99_ms"] == 0.0


def test_server_metrics_merge_does_not_consume_intervals():
    a, b = ServerMetrics("m", deadline_ms=5.0), \
        ServerMetrics("m", deadline_ms=5.0)
    a.note_batch(3, 4, 0.001)
    b.note_batch(5, 8, 0.009)
    fleet = ServerMetrics.merge([a, b])
    assert fleet["replicas"] == 2 and fleet["requests"] == 8
    assert fleet["deadline_hits"] == 3 and fleet["deadline_misses"] == 5
    assert fleet["deadline_attainment"] == pytest.approx(3 / 8)
    # the merge read must not have eaten either part's interval window
    assert a.snapshot()["interval_requests_per_s"] > 0
    assert b.snapshot()["interval_requests_per_s"] > 0


# --------------------------------------------------------------------------
# MetricsHub
# --------------------------------------------------------------------------
def test_hub_register_forms_and_snapshot():
    hub = MetricsHub()
    m = ServerMetrics("m")
    hub.register("serving/m", m)                       # .snapshot()
    hub.register("adhoc", lambda: {"x": 1})            # callable
    hub.register("static", {"y": 2.5})                 # mapping
    with pytest.raises(KeyError):
        hub.register("adhoc", lambda: {})              # no silent shadow
    hub.register("adhoc", lambda: {"x": 9}, replace=True)
    snap = hub.snapshot()
    assert snap["adhoc"] == {"x": 9} and snap["static"] == {"y": 2.5}
    assert snap["serving/m"]["requests"] == 0
    assert hub.namespaces() == ["adhoc", "serving/m", "static"]


def test_hub_failing_source_is_isolated():
    hub = MetricsHub()

    def boom():
        raise RuntimeError("dead model")

    hub.register("bad", boom)
    hub.register("good", {"ok": 1})
    snap = hub.snapshot()
    assert snap["good"] == {"ok": 1}
    assert "RuntimeError" in snap["bad"]["error"]


def test_hub_prometheus_format(tmp_path):
    hub = MetricsHub(prefix="repro")
    hub.register("scoring/bulk", {"rows_per_s": 1234.5, "rows": 10,
                                  "model": "cover type", "exact": True,
                                  "nested": {"raw": 3},
                                  "skipme": [1, 2]})
    text = hub.export_prometheus(tmp_path / "m.prom")
    assert (tmp_path / "m.prom").read_text() == text
    assert "# TYPE repro_scoring_bulk_rows_per_s gauge" in text
    assert 'model="cover type"' in text
    assert "repro_scoring_bulk_rows_per_s" in text
    assert "repro_scoring_bulk_exact" in text          # bool -> gauge
    assert "repro_scoring_bulk_nested_raw" in text     # one-level flatten
    assert "skipme" not in text                        # lists skipped


def test_hub_json_export(tmp_path):
    hub = MetricsHub()
    hub.register("a", {"v": 1})
    obj = hub.export_json(tmp_path / "m.json")
    loaded = json.loads((tmp_path / "m.json").read_text())
    assert loaded["metrics"]["a"]["v"] == 1
    assert "collected_at" in loaded and "collected_at" in obj
