"""Optimizer + data pipeline + serving unit tests."""
import queue
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import BatchIterator, Prefetcher, TokenSource
from repro.training import optimizer as opt_lib


@pytest.mark.parametrize("make", [
    lambda: opt_lib.adamw(lr=0.1),
    lambda: opt_lib.adafactor(lr=0.5),
    lambda: opt_lib.sgd(lr=0.05),
])
def test_optimizer_minimizes_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray(np.full((4, 3), 5.0, np.float32)),
              "b": jnp.asarray(np.full((3,), -4.0, np.float32))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
    assert float(loss(params)) < 0.2 * l0


def test_adamw_clips_gradient_norm():
    opt = opt_lib.adamw(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros((10,), jnp.float32)}
    state = opt.init(params)
    huge = {"w": jnp.full((10,), 1e6, jnp.float32)}
    updates, state = opt.update(huge, state, params)
    # clipped: update magnitude bounded by ~lr
    assert float(jnp.max(jnp.abs(updates["w"]))) < 2e-3


def test_adafactor_state_is_factored():
    opt = opt_lib.adafactor()
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((7,))}
    st = opt.init(params)
    assert st["vr"]["big"].shape == (64,)
    assert st["vc"]["big"].shape == (32,)
    assert st["vr"]["vec"].shape == (7,)
    assert st["vc"]["vec"].shape == (0,)


def test_warmup_cosine_schedule():
    s = opt_lib.warmup_cosine(1.0, warmup=10, total=110)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5, rel=1e-3)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(s(jnp.asarray(110))) == pytest.approx(0.1, rel=1e-2)


def test_batch_iterator_covers_epoch():
    arrays = {"x": np.arange(103), "y": np.arange(103) * 2}
    it = BatchIterator(arrays, batch_size=10, seed=0)
    seen = np.concatenate([b["x"] for b in it])
    assert len(seen) == 100 and len(np.unique(seen)) == 100
    for b in BatchIterator(arrays, batch_size=10, seed=0):
        np.testing.assert_array_equal(b["y"], b["x"] * 2)


def test_token_source_deterministic_by_step():
    ts = TokenSource(100, 16, 4)
    a = ts.next_batch(7)
    b = ts.next_batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 100


def test_prefetcher_yields_all_and_transforms():
    src = iter(range(20))
    pf = Prefetcher(src, depth=3, transform=lambda x: x * 2)
    assert list(pf) == [i * 2 for i in range(20)]


def test_batcher_forms_batches():
    from repro.serving.engine import Batcher
    calls = []

    def serve(xs):
        calls.append(len(xs))
        return xs + 1

    b = Batcher(serve, max_batch=8, max_wait_ms=20)
    futs = [b.submit(i, np.asarray([float(i)])) for i in range(16)]
    outs = [f.get(timeout=10) for f in futs]
    for i, o in enumerate(outs):
        assert o[0] == i + 1
    b.close()
    assert sum(calls) == 16
    assert max(calls) > 1          # batching actually happened
