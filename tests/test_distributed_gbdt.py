"""Mesh-sharded GBDT prediction: parity, routing and metric-merge tests.

Multi-device cases run in subprocesses with forced host devices (same
pattern as tests/test_distributed.py — XLA locks the device count at
first init, so the main pytest process must stay single-device).  The
contracts under test:

* row-sharded pool/float predict is *bit-exact* vs single-device on
  every layout (each row's addend order is unchanged — shards just
  partition rows);
* a sharded pool predict performs ZERO binarize dispatches (the PR-3
  fallback that re-pinned per-shard plans to soa and re-binarized is
  the regression this guards);
* tree-sharded predict matches to reassociated-float tolerance (psum
  reorders the tree sum);
* uneven row counts (not divisible by the mesh) pad internally and
  return exactly the unpadded rows;
* K models x R replicas route round-robin and `predict_multi` still
  quantizes once per schema fingerprint.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ENSEMBLE_SETUP = """
from repro.core.trees import ObliviousEnsemble
from repro.core.predictor import Predictor
from repro.kernels import registry
from repro.kernels.ops import PAD_SPLIT_BIN
from repro.compat import make_mesh

def make_ens(T, D, F, B, C, seed=0, leaf_scale=1.0):
    rng = np.random.default_rng(seed)
    depths = rng.integers(2, D + 1, size=T)
    sf = rng.integers(0, F, size=(T, D)).astype(np.int32)
    sb = rng.integers(1, B + 1, size=(T, D)).astype(np.int32)
    for t in range(T):
        sb[t, depths[t]:] = PAD_SPLIT_BIN
    lv = (leaf_scale * rng.normal(size=(T, 1 << D, C))).astype(np.float32)
    borders = np.sort(rng.normal(size=(B, F)).astype(np.float32), axis=0)
    return ObliviousEnsemble(jnp.asarray(sf), jnp.asarray(sb),
                             jnp.asarray(lv), jnp.asarray(borders),
                             jnp.asarray(np.full((F,), B, np.int32)))

def binarize_calls():
    return sum(v for k, v in registry.call_stats().items()
               if k[0].startswith("binarize"))
"""


def run_sub(body: str, devices: int = 4) -> dict:
    """Run `body` (must print one json line as last stdout line)."""
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prelude + body],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_row_sharded_parity_all_layouts():
    """Row-sharded == single-device, bit for bit, on all four layouts,
    for pool and float inputs and for uneven row counts — and the pool
    path never dispatches binarize."""
    res = run_sub(ENSEMBLE_SETUP + """
ens = make_ens(30, 5, 20, 60, 3)
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(7)
x = rng.normal(size=(136, 20)).astype(np.float32)
out = {}
for layout in ("soa", "depth_major", "depth_grouped", "bitpacked"):
    plan = Predictor.build(ens, strategy="staged", backend="ref",
                           layout=layout)
    pool = plan.quantize(x)
    ref_pool = np.asarray(plan.raw(pool))
    ref_float = np.asarray(plan.raw(x))
    fn = plan.sharded(mesh)
    registry.reset_call_stats()
    got_pool = np.asarray(fn(pool))
    nbin = binarize_calls()
    got_float = np.asarray(fn(x))
    # 131 % 4 != 0: the entry pads to a shardable count and slices back
    got_uneven = np.asarray(fn(pool.slice_rows(0, 131)))
    out[layout] = {
        "pool_exact": bool((got_pool == ref_pool).all()),
        "float_exact": bool((got_float == ref_float).all()),
        "uneven_exact": bool((got_uneven == ref_pool[:131]).all()),
        "uneven_rows": got_uneven.shape[0],
        "binarize_calls": nbin,
    }
print(json.dumps(out))
""")
    for layout, r in res.items():
        assert r["pool_exact"], (layout, r)
        assert r["float_exact"], (layout, r)
        assert r["uneven_exact"], (layout, r)
        assert r["uneven_rows"] == 131, (layout, r)
        assert r["binarize_calls"] == 0, (layout, r)


def test_tree_sharded_psum_parity():
    """Tree-sharded predict psums partial leaf sums over the mesh —
    a reassociated float sum, so parity is to tolerance, not bits."""
    res = run_sub(ENSEMBLE_SETUP + """
ens = make_ens(256, 5, 20, 60, 3, seed=3)
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(11)
x = rng.normal(size=(64, 20)).astype(np.float32)
plan = Predictor.build(ens, strategy="staged", backend="ref")
pool = plan.quantize(x)
ref = np.asarray(plan.raw(pool))
fn = plan.sharded(mesh, shard_axis="trees")
got = np.asarray(fn(pool))
gotf = np.asarray(fn(x))
scale = float(np.abs(ref).max())
print(json.dumps({
    "err_pool": float(np.abs(got - ref).max()),
    "err_float": float(np.abs(gotf - ref).max()),
    "scale": scale,
}))
""")
    # reassociated sum of 256 trees: 1e-6 relative to the raw scale
    tol = 1e-6 * max(res["scale"], 1.0) * 4
    assert res["err_pool"] <= tol, res
    assert res["err_float"] <= tol, res


def test_registry_replicas_and_predict_multi():
    """K models x R replicas on one mesh: round-robin routing, merged
    metrics, and quantize-once across every model and replica."""
    res = run_sub(ENSEMBLE_SETUP + """
from repro.serving.engine import GBDTServer, ModelRegistry, ReplicaGroup

import dataclasses
ens_a = make_ens(12, 4, 10, 30, 3, seed=1)
# model b: different trees, *shared* feature schema (same borders) —
# the quantize-once case predict_multi exists for
ens_b = dataclasses.replace(make_ens(12, 4, 10, 30, 3, seed=2),
                            borders=ens_a.borders,
                            n_borders=ens_a.n_borders)
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(5)
xs = rng.normal(size=(40, 10)).astype(np.float32)

reg = ModelRegistry(mesh=mesh)
ga = reg.register("a", ens_a, replicas=2)
gb = reg.register("b", ens_b, replicas=2)
assert isinstance(ga, ReplicaGroup) and len(ga.servers) == 2
assert all(len(np.asarray(s.mesh.devices).reshape(-1)) == 2
           for s in ga.servers)

# parity vs unsharded single-device plans
want_a = np.asarray(Predictor.build(ens_a).proba(xs))
want_b = np.asarray(Predictor.build(ens_b).proba(xs))
q_cost = []
for g, ens in ((ga, ens_a), (gb, ens_b)):
    registry.reset_call_stats()
    g.quantize(xs)
    q_cost.append(binarize_calls())
registry.reset_call_stats()
out = reg.predict_multi(xs)
multi_bin = binarize_calls()
n_schemas = len({s.schema_fingerprint
                 for s in (ga.servers[0], gb.servers[0])})

# round-robin spreads load across the replicas of a group
for _ in range(4):
    ga.predict_batch(xs)
batches = [s.metrics.snapshot()["batches"] for s in ga.servers]
m = reg.metrics()
reg.close()
print(json.dumps({
    "ok_a": bool(np.allclose(out["a"], want_a, atol=1e-6)),
    "ok_b": bool(np.allclose(out["b"], want_b, atol=1e-6)),
    "multi_binarize": multi_bin,
    "quantize_cost": q_cost,
    "n_schemas": n_schemas,
    "batches": batches,
    "replicas_a": m["a"]["replicas"],
    "requests_a": m["a"]["requests"],
    "layout_a": m["a"]["layout"],
}))
""")
    assert res["ok_a"] and res["ok_b"], res
    # predict_multi quantized once per distinct schema: its binarize
    # bill equals one quantize per schema, no more
    assert res["multi_binarize"] == res["quantize_cost"][0] \
        * res["n_schemas"], res
    assert all(b > 0 for b in res["batches"]), res
    assert res["replicas_a"] == 2, res
    assert res["requests_a"] > 0, res
    assert res["layout_a"] != "mixed", res


# -- single-device pieces (no subprocess needed) ---------------------------

def test_best_shard_axis_cost_model():
    from repro.kernels import tuning

    # serving-sized batches with few trees: rows
    assert tuning.best_shard_axis(16384, 100, 4) == "rows"
    # giant ensemble, tiny batch: trees
    assert tuning.best_shard_axis(2, 4096, 4) == "trees"
    # replicating an enormous leaf table across the mesh is the
    # documented tree-shard trigger
    assert tuning.best_shard_axis(
        16384, 8192, 4, leaf_table_bytes=40 << 20) == "trees"
    # a 1-way mesh never tree-shards
    assert tuning.best_shard_axis(2, 8192, 1) == "rows"


def test_replica_submeshes_validation():
    from repro.compat import make_mesh
    from repro.distributed.gbdt import replica_submeshes

    mesh = make_mesh((1,), ("data",))
    subs = replica_submeshes(mesh, 1)
    assert len(subs) == 1 and subs[0].axis_names == ("data",)
    with pytest.raises(ValueError):
        replica_submeshes(mesh, 2)      # 1 device, 2 groups
    with pytest.raises(ValueError):
        replica_submeshes(mesh, 0)


def test_shard_parity_pass_clean():
    """The checker's shard-parity pass over the canonical plans must
    come back clean (no gathering collectives in any sharded entry)."""
    from repro.analysis import passes

    assert passes.shard_parity_findings((8,)) == []


def test_shard_parity_lint_flags_all_gather():
    """Positive control: a sharded entry that all-gathers its panel is
    exactly what the lint exists to flag."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.analysis import passes
    from repro.compat import abstract_mesh, shard_map

    mesh = abstract_mesh((4,), ("data",))

    def local(x):
        full = jax.lax.all_gather(x, "data", tiled=True)
        return jnp.sum(full)[None] * jnp.ones_like(x[:, 0])

    fn = shard_map(local, mesh=mesh, in_specs=(P("data"),),
                   out_specs=P("data"), check_rep=False)
    closed = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((8, 4), jnp.float32))
    findings = passes.sharded_entry_findings("ctrl:sharded_raw", closed)
    assert findings, "all_gather inside shard_map must be flagged"
    assert all(f.rule == "shard-parity" for f in findings)


def test_percentile_reservoir_merge():
    from repro.serving.metrics import PercentileReservoir

    a = PercentileReservoir(max_samples=64, seed=1)
    b = PercentileReservoir(max_samples=64, seed=2)
    for v in range(100):
        a.add(float(v))          # stream ~[0, 100)
    for v in range(300):
        b.add(1000.0 + v)        # stream ~[1000, 1300), 3x larger
    a.merge(b)
    assert a.seen == 400
    assert len(a) <= a.max_samples
    # the merged sample leans toward the larger stream and the merged
    # median lands in b's value range
    assert a.percentile(50) > 500.0
    with pytest.raises(TypeError):
        a.merge([1.0, 2.0])


def test_server_metrics_merge():
    from repro.serving.metrics import ServerMetrics

    parts = []
    for i in range(3):
        m = ServerMetrics(f"m/r{i}")
        m.layout = "soa"
        for _ in range(10 * (i + 1)):
            m.note_batch(4, 8, 0.002 * (i + 1))
        parts.append(m)
    merged = ServerMetrics.merge(parts)
    assert merged["replicas"] == 3
    assert merged["requests"] == 4 * (10 + 20 + 30)
    assert merged["batches"] == 60
    assert merged["layout"] == "soa"
    assert merged["pad_overhead"] == pytest.approx(0.5)
    # percentiles come from the merged reservoir: p99 reflects the
    # slowest replica, not an average of per-part p99s
    assert merged["batch_p99_ms"] == pytest.approx(6.0, rel=0.2)
    parts[1].layout = "bitpacked"
    assert ServerMetrics.merge(parts)["layout"] == "mixed"
    with pytest.raises(ValueError):
        ServerMetrics.merge([])


def test_scoring_metrics_merge():
    from repro.scoring.scorer import ScoringMetrics

    parts = []
    for i in range(2):
        m = ScoringMetrics(f"w{i}")
        m.start()
        for _ in range(5):
            m.note_chunk(100, 128, 0.01)
        m.note_quantize(0.05)
        m.stop()
        parts.append(m)
    merged = ScoringMetrics.merge(parts)
    assert merged["rows"] == 1000
    assert merged["chunks"] == 10
    assert merged["quantize_s"] == pytest.approx(0.1)
    assert merged["score_s"] == pytest.approx(0.1)
    # concurrent workers: fleet wall is the slowest part, not the sum
    assert merged["wall_s"] <= sum(p.snapshot()["wall_s"] for p in parts)
    assert merged["chunk_p50_ms"] == pytest.approx(10.0, rel=0.05)
    with pytest.raises(ValueError):
        ScoringMetrics.merge([])
