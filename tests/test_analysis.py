"""Contract-checker tests: the full matrix must verify clean, and the
lints must actually fire — proven with deliberately-broken toy kernels
registered (and unregistered) around each test, including a fixture
that re-introduces the PR-7 histogram widening bug."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import checker, jaxpr_tools, matrix, passes, report
from repro.kernels import registry


@pytest.fixture(scope="module")
def full_report():
    """One full-matrix run shared by the clean-matrix assertions."""
    return checker.run_check()


# --------------------------------------------------------------------------
# The shipped matrix verifies clean
# --------------------------------------------------------------------------
def test_full_matrix_clean(full_report):
    r = full_report
    assert r.ok, "\n" + r.format(verbose=True)
    assert r.cells >= 70          # 6 ops x impls x layouts x dtypes
    assert r.kernels >= 40        # every pallas cell audited
    assert r.traces > 0
    # layout-identical calls collapse in the trace cache
    assert r.trace_cache_hits > 0


def test_declared_suppressions_are_exercised(full_report):
    """The two shipped suppressions (jnp oracle widenings on
    leaf_index:ref and histogram:ref) must both match real findings —
    and the depth_grouped layout must be among leaf_index:ref's
    suppressed cells (the uint8 promotion audit of PR 6's layout)."""
    sup = full_report.suppressed
    assert all(f.rule == "widening" for f in sup)
    keys = {(f.op, f.impl) for f in sup}
    assert keys == {("leaf_index", "ref"), ("histogram", "ref")}
    assert ("depth_grouped" in
            {f.layout for f in sup if f.op == "leaf_index"})
    assert all(f.dtype == "uint8" for f in sup)


def test_verified_map_covers_every_impl(full_report):
    rows = registry.table()
    assert set(full_report.verified) \
        == {f"{r['op']}:{r['impl']}" for r in rows}
    for key, verdict in full_report.verified.items():
        assert verdict.startswith("ok"), (key, verdict)
    assert full_report.verified["leaf_index:ref"].startswith("ok (")
    assert full_report.verified["histogram:ref"].startswith("ok (")


def test_report_roundtrip(full_report, tmp_path):
    path = full_report.save(tmp_path / "r.json")
    loaded = report.ContractReport.load(path)
    assert loaded.verified == full_report.verified
    assert len(loaded.findings) == len(full_report.findings)
    assert loaded.ok == full_report.ok
    # deterministic artifact: a second save is byte-identical
    again = report.ContractReport.load(path).save(tmp_path / "r2.json")
    assert again.read_bytes() == path.read_bytes()


# --------------------------------------------------------------------------
# Widening lint fires on deliberately-widening toys
# --------------------------------------------------------------------------
def _narrow_check(impls):
    return checker.run_check(impls_filter=impls, include_plan=False,
                             include_tuning=False)


def test_widening_lint_fires_on_toy_kernel():
    """A uint8 leaf_index impl that widens the bins panel into a
    compare (instead of the sanctioned MXU/gather path) must be
    flagged."""
    @registry.register("leaf_index", "toy_widen", dtypes=("uint8",),
                       layouts=("soa",))
    def _toy(bins, sf, sb, **_kw):
        wide = bins.astype(jnp.int32)            # the violation
        gathered = jnp.take(wide, sf.reshape(-1), axis=1)
        go = (gathered.reshape(bins.shape[0], *sf.shape)
              >= sb[None, :, :]).astype(jnp.int32)
        return jnp.sum(go * (2 ** jnp.arange(sf.shape[1]))[None, None, :],
                       axis=2)

    try:
        r = _narrow_check({"leaf_index:toy_widen"})
        hits = [f for f in r.unsuppressed if f.rule == "widening"]
        assert hits, r.format(verbose=True)
        assert not r.ok
        assert r.verified["leaf_index:toy_widen"] == "FAIL"
    finally:
        registry.unregister("leaf_index", "toy_widen")


def test_pr7_histogram_widening_regression():
    """Re-introduce the PR-7 bug in a fixture: uint8 pool bins promoted
    to an int32 segment-id panel (`leaf * n_bins + bins.astype(i32)`)
    before the one-hot — the exact defect the widening lint exists to
    catch.  The lint must fire; the shipped pallas_u8 path (u8-vs-u8
    compare) must stay clean."""
    @registry.register("histogram", "toy_pr7", dtypes=("uint8",),
                       layouts=("soa",))
    def _toy(bins_t, leaf, g, *, n_bins, n_leaves, **_kw):
        seg = leaf[None, :] * n_bins + bins_t.astype(jnp.int32)
        onehot = (seg[:, :, None]
                  == jnp.arange(n_leaves * n_bins)[None, None, :]
                  ).astype(g.dtype)
        return jnp.einsum("fns,nc->fsc", onehot, g)

    try:
        r = _narrow_check({"histogram:toy_pr7"})
        hits = [f for f in r.unsuppressed if f.rule == "widening"]
        assert hits, r.format(verbose=True)
        assert "add" in hits[0].message
    finally:
        registry.unregister("histogram", "toy_pr7")
    clean = _narrow_check({"histogram:pallas_u8"})
    assert clean.ok, clean.format(verbose=True)


def test_int_pipeline_lint_fires_on_float_excursion():
    """A bitpacked leaf_index impl that rebuilds the index through
    floats (the MXU habit) defeats the layout's integer pipeline."""
    @registry.register("leaf_index", "toy_bp_float", dtypes=("int32",),
                       layouts=("bitpacked",))
    def _toy(bins, sf_bp, sb_bp, **_kw):
        d = sf_bp.shape[0]
        cols = jnp.stack([jnp.take(bins, sf_bp[i], axis=1)
                          for i in range(d)], axis=1)
        go = (cols >= sb_bp.T[None, :, :].swapaxes(1, 2)).astype(
            jnp.float32)                          # the violation
        idx = jnp.sum(go * (2.0 ** jnp.arange(d))[None, :, None], axis=1)
        return idx.astype(jnp.int32)

    try:
        r = _narrow_check({"leaf_index:toy_bp_float"})
        hits = [f for f in r.unsuppressed if f.rule == "int-pipeline"]
        assert hits, r.format(verbose=True)
    finally:
        registry.unregister("leaf_index", "toy_bp_float")


# --------------------------------------------------------------------------
# VMEM audit fires on an understated footprint
# --------------------------------------------------------------------------
def test_vmem_audit_fires_on_understated_footprint():
    """A pallas binarize whose kernel materializes a (bn, B, bf) f32
    panel the `binarize_footprint` model knows nothing about must trip
    the vmem-model audit."""
    from jax.experimental import pallas as pl

    @registry.register("binarize", "toy_fat", dtypes=("int32",),
                       layouts=("soa",))
    def _toy(x, borders, **_kw):
        def kernel(x_ref, b_ref, out_ref):
            xv = x_ref[...]
            bv = b_ref[...]
            fat = jnp.sin(xv[:, None, :] * bv[None, :, :])  # (bn, B, bf)
            out_ref[...] = jnp.sum(fat > 0.0, axis=1).astype(jnp.int32)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
            interpret=True)(x, borders)

    try:
        r = _narrow_check({"binarize:toy_fat"})
        hits = [f for f in r.unsuppressed if f.rule == "vmem-model"]
        assert hits, r.format(verbose=True)
        assert "mis-plan" in hits[0].message
    finally:
        registry.unregister("binarize", "toy_fat")


# --------------------------------------------------------------------------
# Suppressions: honored, and flagged when stale
# --------------------------------------------------------------------------
def test_suppression_demotes_finding():
    @registry.register("leaf_index", "toy_sup", dtypes=("uint8",),
                       layouts=("soa",),
                       suppressions=("widening: test fixture",))
    def _toy(bins, sf, sb, **_kw):
        wide = bins.astype(jnp.int32)
        gathered = jnp.take(wide, sf.reshape(-1), axis=1)
        go = gathered.reshape(bins.shape[0], *sf.shape) >= sb[None]
        return jnp.sum(go.astype(jnp.int32), axis=2)

    try:
        r = _narrow_check({"leaf_index:toy_sup"})
        assert r.ok, r.format(verbose=True)
        assert len(r.suppressed) >= 1
        assert r.verified["leaf_index:toy_sup"].startswith("ok (")
    finally:
        registry.unregister("leaf_index", "toy_sup")


def test_unused_suppression_is_flagged():
    @registry.register("leaf_gather", "toy_stale", dtypes=("int32",),
                       layouts=("soa",),
                       suppressions=("widening: no longer needed",))
    def _toy(idx, lv, **_kw):
        return jnp.take_along_axis(
            lv, idx.T[:, :, None], axis=1).sum(axis=0)

    try:
        # narrowed runs skip the stale check by default...
        r = checker.run_check(impls_filter={"leaf_gather:toy_stale"},
                              include_plan=False, include_tuning=False)
        assert not [f for f in r.findings
                    if f.rule == "unused-suppression"]
        # ...and flag it when asked explicitly
        r = checker.run_check(impls_filter={"leaf_gather:toy_stale"},
                              include_plan=False, include_tuning=False,
                              check_unused=True)
        stale = [f for f in r.unsuppressed
                 if f.rule == "unused-suppression"]
        assert stale, r.format(verbose=True)
        assert not r.ok
    finally:
        registry.unregister("leaf_gather", "toy_stale")


def test_unknown_suppression_rule_rejected():
    with pytest.raises(ValueError, match="unknown suppression rule"):
        report.parse_suppressions(("not-a-rule: whatever",))


# --------------------------------------------------------------------------
# Trace cache + plan walk
# --------------------------------------------------------------------------
def test_trace_cache_no_retrace():
    cell = matrix.Cell("binarize", "ref", "soa", "int32")
    matrix.trace_cell(cell)
    before = matrix.cache_stats()
    matrix.trace_cell(cell)
    after = matrix.cache_stats()
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]


def test_plan_walk_never_compiles_and_caches():
    from repro.core.predictor import Predictor
    ens, _ = matrix.canonical_ensemble(n_features=8, n_trees=4)
    plan = Predictor.build(ens, strategy="staged")
    entries = plan.trace_entries(batch_sizes=(4, 8))
    assert "raw@4" in entries and "raw_pool@8" in entries
    stats = plan.stats
    assert stats["total_traces"] == 0          # nothing compiled
    misses = stats["abstract_trace_misses"]
    assert misses == len(entries)
    plan.trace_entries(batch_sizes=(4, 8))     # second walk: all cached
    assert plan.stats["abstract_trace_misses"] == misses
    for name, closed in entries.items():
        assert not passes.entry_findings(name, closed)


def test_entry_lints_fire_on_bad_avals():
    """The retrace lint must flag weak/x64 boundary avals."""
    def weak(x):
        return x + 1                            # weak-typed scalar const
    closed = jax.make_jaxpr(weak)(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    # interior weak scalars are fine — no finding
    assert not [f for f in passes.entry_findings("t", closed)
                if f.rule == "retrace"]
    closed = jax.make_jaxpr(lambda x: x * 2.0)(1.0)  # weak boundary
    assert [f for f in passes.entry_findings("t", closed)
            if f.rule == "retrace"]


# --------------------------------------------------------------------------
# Registry surface
# --------------------------------------------------------------------------
def test_format_table_has_verified_column():
    txt = registry.format_table({"binarize:ref": "ok"})
    header = txt.splitlines()[0]
    assert "verified" in header and "layouts" in header
    row = next(line for line in txt.splitlines()
               if "| binarize" in line and "| ref " in line)
    assert "| ok " in row
    blank = registry.format_table({})
    assert "| - " in blank


def test_unregister_unknown_raises():
    with pytest.raises(KeyError):
        registry.unregister("binarize", "nope")


# --------------------------------------------------------------------------
# Estimator structural pins (fail loudly on a jax upgrade)
# --------------------------------------------------------------------------
def test_pallas_refs_carry_block_shapes():
    from repro.kernels import registry as reg
    cell = matrix.Cell("fused_predict", "pallas", "soa", "uint8")
    closed = matrix.trace_cell(cell)[0]
    calls = jaxpr_tools.find_pallas_calls(closed.jaxpr)
    assert len(calls) == 1
    refs = jaxpr_tools.pallas_ref_avals(calls[0])
    assert len(refs) == 7            # 5 inputs + out + bins scratch
    assert np.dtype(refs[-1].dtype) == np.uint8   # u8 scratch picked
    assert all(hasattr(a, "shape") for a in refs)
    assert jaxpr_tools.peak_live_bytes(
        jaxpr_tools.pallas_kernel_jaxpr(calls[0]),
        include_invars=False) > 0
