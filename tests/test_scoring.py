"""Bulk scoring subsystem: sources/sinks/scorer parity and contracts
(<= 2 compiled chunk shapes, O(chunk) streaming memory, resume by
chunk index, multi-model quantize-once fan-out), the chunked quantize
helpers, Prefetcher error propagation, and the shared metrics
reservoir."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import quantize
from repro.core.predictor import PredictConfig, Predictor
from repro.core.quantize import QuantizedPool, quantize_pool
from repro.core.trees import ObliviousEnsemble
from repro.data.pipeline import Prefetcher
from repro.kernels import registry, tuning
from repro.scoring import (ArraySink, ArraySource, BulkScorer,
                           NpyMemmapSource, NpySink, ScoreConfig,
                           ScoringMetrics, StatsSink, SyntheticSource,
                           TopKSink, iter_chunks, plan_chunks)
from repro.serving.metrics import PercentileReservoir, ServerMetrics


def _rand_ensemble(seed=3, n_trees=13, depth=4, n_features=11,
                   n_borders=9, n_outputs=2):
    rng = np.random.default_rng(seed)
    borders = jnp.asarray(
        np.sort(rng.normal(size=(n_borders, n_features)), 0)
        .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, n_features,
                                  (n_trees, depth)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, n_borders,
                                  (n_trees, depth)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(n_trees, 2 ** depth, n_outputs))
                     .astype(np.float32))
    return ObliviousEnsemble(sf, sb, lv, borders,
                             jnp.full((n_features,), n_borders, jnp.int32))


def _rand_x(ens, n=37, seed=0):
    rng = np.random.default_rng(seed)
    return np.asarray(rng.normal(size=(n, ens.n_features)), np.float32)


def _plan(ens, **kw):
    kw.setdefault("strategy", "staged")
    kw.setdefault("backend", "ref")
    return Predictor.build(ens, PredictConfig(**kw))


# --------------------------------------------------------------------------
# Prefetcher error propagation (satellite regression)
# --------------------------------------------------------------------------
def test_prefetcher_reraises_source_exception():
    def bad_iter():
        yield 1
        yield 2
        raise RuntimeError("disk on fire")

    pf = Prefetcher(bad_iter(), depth=2)
    got = []
    with pytest.raises(RuntimeError, match="disk on fire"):
        for item in pf:
            got.append(item)
    # everything produced before the error was delivered, nothing eaten
    assert got == [1, 2]


def test_prefetcher_reraises_transform_exception():
    pf = Prefetcher(iter(range(5)), depth=2,
                    transform=lambda i: 1 // (i - 2))
    with pytest.raises(ZeroDivisionError):
        list(pf)


def test_prefetcher_normal_stream_and_order():
    pf = Prefetcher(iter(range(7)), depth=2, transform=lambda i: i * i)
    assert list(pf) == [i * i for i in range(7)]


# --------------------------------------------------------------------------
# Chunked quantization helpers (satellite)
# --------------------------------------------------------------------------
def test_quantize_pool_chunked_matches_full_matrix():
    ens = _rand_ensemble()
    x = _rand_x(ens, 103)
    full = quantize_pool(jnp.asarray(x), ens.borders)
    chunked = quantize.quantize_pool_chunked(
        (x[s:s + 16] for s in range(0, len(x), 16)), ens.borders)
    assert chunked.fingerprint == full.fingerprint
    np.testing.assert_array_equal(np.asarray(chunked.bins),
                                  np.asarray(full.bins))


def test_quantize_pool_chunked_never_sees_full_matrix():
    """The memory contract: only O(chunk) float rows in flight."""
    ens = _rand_ensemble()
    x = _rand_x(ens, 200)
    seen = []

    def watched():
        for s in range(0, len(x), 32):
            chunk = x[s:s + 32]
            seen.append(len(chunk))
            yield chunk

    pool = quantize.quantize_pool_chunked(watched(), ens.borders)
    assert pool.n_rows == 200
    assert max(seen) <= 32               # never a dataset-sized slab


def test_quantize_pool_chunked_validates():
    ens = _rand_ensemble()
    with pytest.raises(ValueError, match="match"):
        quantize.quantize_pool_chunked(
            iter([np.zeros((4, ens.n_features + 1), np.float32)]),
            ens.borders)
    empty = quantize.quantize_pool_chunked(iter([]), ens.borders)
    assert empty.n_rows == 0 and empty.n_features == ens.n_features


def test_compute_borders_chunked_exact_when_under_sample_cap():
    x = _rand_x(_rand_ensemble(), 150, seed=5)
    want_b, want_c = quantize.compute_borders(x, max_bins=16)
    got_b, got_c = quantize.compute_borders_chunked(
        (x[s:s + 40] for s in range(0, len(x), 40)), max_bins=16,
        sample_rows=1024)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))


def test_compute_borders_chunked_sampled_shapes_and_validity():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 3)).astype(np.float32)
    borders, counts = quantize.compute_borders_chunked(
        (x[s:s + 100] for s in range(0, 500, 100)), max_bins=8,
        sample_rows=128)
    assert borders.shape == (7, 3)
    assert np.all(np.asarray(counts) > 0)          # continuous columns
    b = np.asarray(borders)
    for j in range(3):                 # sample quantiles stay in range
        c = int(np.asarray(counts)[j])
        assert np.all(b[:c, j] < x[:, j].max())
    with pytest.raises(ValueError, match="non-empty"):
        quantize.compute_borders_chunked(iter([]))


# --------------------------------------------------------------------------
# Sources
# --------------------------------------------------------------------------
def test_array_source_and_iter_chunks():
    x = np.arange(20, dtype=np.float32).reshape(10, 2)
    src = ArraySource(x)
    assert (src.n_rows, src.n_features) == (10, 2)
    np.testing.assert_array_equal(src.read(3, 7), x[3:7])
    chunks = list(iter_chunks(src, 4))
    assert [c.shape[0] for c in chunks] == [4, 4, 2]
    np.testing.assert_array_equal(np.concatenate(chunks), x)
    with pytest.raises(ValueError, match="span"):
        src.read(5, 11)


def test_npy_memmap_source_roundtrip(tmp_path):
    x = np.random.default_rng(0).normal(size=(23, 5)).astype(np.float32)
    path = tmp_path / "x.npy"
    np.save(path, x)
    src = NpyMemmapSource(path)
    assert (src.n_rows, src.n_features) == (23, 5)
    np.testing.assert_array_equal(src.read(4, 9), x[4:9])


def test_synthetic_source_virtual_repeat():
    src = SyntheticSource("covertype", scale=0.001, split="test",
                          repeat=3)
    base = src.base_rows
    assert src.n_rows == 3 * base
    # rows wrap: the second tile equals the first
    np.testing.assert_array_equal(src.read(base, base + 5),
                                  src.read(0, 5))
    # a span crossing the tile boundary stitches correctly
    span = src.read(base - 2, base + 2)
    np.testing.assert_array_equal(span[:2], src.read(base - 2, base))
    np.testing.assert_array_equal(span[2:], src.read(0, 2))


# --------------------------------------------------------------------------
# Sinks
# --------------------------------------------------------------------------
def test_npy_sink_write_and_resume(tmp_path):
    path = tmp_path / "scores.npy"
    sink = NpySink(path)
    sink.open(6, 2)
    sink.write(0, np.ones((3, 2), np.float32))
    assert sink.close() == path
    # resume: rows written before survive, new rows land in place
    sink2 = NpySink(path, resume=True)
    sink2.open(6, 2)
    sink2.write(3, 2 * np.ones((3, 2), np.float32))
    sink2.close()
    out = np.load(path)
    np.testing.assert_array_equal(out[:3], 1.0)
    np.testing.assert_array_equal(out[3:], 2.0)
    # shape mismatch on resume is an error, not silent corruption
    sink3 = NpySink(path, resume=True)
    with pytest.raises(ValueError, match="resume"):
        sink3.open(7, 2)


def test_stats_sink_matches_numpy():
    rng = np.random.default_rng(1)
    ys = rng.normal(size=(90, 3)).astype(np.float32) * [1, 10, 0.1]
    sink = StatsSink()
    sink.open(90, 3)
    for s in range(0, 90, 13):
        sink.write(s, ys[s:s + 13])
    out = sink.close()
    assert out["count"] == 90
    np.testing.assert_allclose(out["mean"], ys.mean(0), rtol=1e-5)
    np.testing.assert_allclose(out["std"], ys.std(0), rtol=1e-5)
    np.testing.assert_allclose(out["min"], ys.min(0))
    np.testing.assert_allclose(out["max"], ys.max(0))


def test_topk_sink_matches_argsort():
    rng = np.random.default_rng(2)
    ys = rng.normal(size=(70, 2)).astype(np.float32)
    sink = TopKSink(5, column=1)
    sink.open(70, 2)
    for s in range(0, 70, 9):
        sink.write(s, ys[s:s + 9])
    out = sink.close()
    want = np.argsort(-ys[:, 1])[:5]
    np.testing.assert_array_equal(out["indices"], want)
    np.testing.assert_allclose(out["scores"], ys[want])
    # bottom-k flips the order
    lo = TopKSink(3, column=1, largest=False)
    lo.open(70, 2)
    lo.write(0, ys)
    np.testing.assert_array_equal(lo.close()["indices"],
                                  np.argsort(ys[:, 1])[:3])


def test_sink_write_validation():
    sink = ArraySink()
    with pytest.raises(ValueError, match="before"):
        sink.write(0, np.zeros((1, 2), np.float32))
    sink.open(4, 2)
    with pytest.raises(ValueError, match="width"):
        sink.write(0, np.zeros((1, 3), np.float32))
    with pytest.raises(ValueError, match="span"):
        sink.write(3, np.zeros((2, 2), np.float32))


# --------------------------------------------------------------------------
# Chunk planning
# --------------------------------------------------------------------------
def test_plan_chunks_two_shape_contract():
    spans = plan_chunks(n_rows=10_000, chunk_rows=1024)
    assert [s.start for s in spans[:2]] == [0, 1024]
    shapes = {s.padded for s in spans}
    assert len(shapes) <= 2
    tail = spans[-1]
    assert tail.n_valid == 10_000 - 9 * 1024
    assert tail.padded >= tail.n_valid          # bucket holds the tail
    assert tail.padded <= 1024


def test_best_chunk_rows_model_aware():
    small = tuning.best_chunk_rows(54, 7, n_borders=63, n_trees=100,
                                   n_leaves=32)
    nodims = tuning.best_chunk_rows(54, 7)
    assert small < nodims            # kernel working set shrinks chunks
    assert small & (small - 1) == 0            # power of two
    # a tiny dataset caps the chunk at its pow2 cover
    assert tuning.best_chunk_rows(54, 7, n_rows=300) <= 512


# --------------------------------------------------------------------------
# BulkScorer: parity + compile contract
# --------------------------------------------------------------------------
def test_bulk_scorer_matches_one_shot_exactly():
    ens = _rand_ensemble()
    plan = _plan(ens)
    x = _rand_x(ens, 150)
    res = BulkScorer(plan, ScoreConfig(chunk_rows=64, output="raw")) \
        .score(ArraySource(x))
    want = np.asarray(plan.raw(jnp.asarray(x)))
    np.testing.assert_array_equal(res.output, want)
    assert len(res.chunk_shapes) <= 2
    # the pool entry traced at most once per distinct chunk shape
    assert plan.stats["traces"].get("raw_pool", 0) <= len(res.chunk_shapes)


def test_bulk_scorer_proba_and_classify_shapes():
    ens = _rand_ensemble(n_outputs=3)
    plan = _plan(ens)
    x = _rand_x(ens, 50)
    proba = BulkScorer(plan, ScoreConfig(chunk_rows=32, output="proba")) \
        .score(ArraySource(x)).output
    np.testing.assert_array_equal(
        proba, np.asarray(plan.proba(jnp.asarray(x))))
    cls = BulkScorer(plan, ScoreConfig(chunk_rows=32,
                                       output="classify")) \
        .score(ArraySource(x)).output
    assert cls.shape == (50, 1)
    np.testing.assert_array_equal(
        cls[:, 0], np.asarray(plan.classify(jnp.asarray(x))))


def test_bulk_scorer_float_fallback_parity():
    """prequantize=False scores float chunks — same scores exactly."""
    ens = _rand_ensemble()
    plan = _plan(ens)
    x = _rand_x(ens, 90)
    res = BulkScorer(plan, ScoreConfig(chunk_rows=32, output="raw",
                                       prequantize=False)) \
        .score(ArraySource(x))
    np.testing.assert_array_equal(
        res.output, np.asarray(plan.raw(jnp.asarray(x))))


def test_bulk_scorer_single_binarize_trace_on_pool_path():
    """The prequantized pipeline binarizes only through the worker's
    quantize entry: across a whole run the registry sees exactly one
    binarize dispatch (the quantize entry's single trace — dispatch
    runs at trace time), never one per scoring entry."""
    ens = _rand_ensemble()
    plan = _plan(ens)
    x = _rand_x(ens, 150)
    registry.reset_call_stats()
    res = BulkScorer(plan, ScoreConfig(chunk_rows=64, output="raw")) \
        .score(ArraySource(x))
    stats = registry.call_stats()
    # every chunk (tail included) binarizes via the one full-chunk
    # quantize trace; the scoring entries trace without binarize
    assert stats.get("binarize", 0) == 1, stats
    assert stats.get("leaf_index", 0) >= 1, stats
    assert plan.stats["traces"].get("quantize", 0) == 1
    assert plan.stats["traces"].get("raw", 0) == 0   # float path unused
    assert len(res.chunk_shapes) == 2


# --------------------------------------------------------------------------
# Degenerate inputs (satellite): 0 rows, sub-chunk source, 1-row tail
# --------------------------------------------------------------------------
def test_zero_row_source():
    ens = _rand_ensemble(n_outputs=2)
    plan = _plan(ens)
    scorer = BulkScorer(plan, ScoreConfig(chunk_rows=32, output="raw"))
    res = scorer.score(ArraySource(np.zeros((0, ens.n_features),
                                            np.float32)))
    assert res.output.shape == (0, 2)
    assert res.metrics["chunks"] == 0
    assert res.metrics["compiles"] == 0          # no trace for no data
    assert res.chunk_shapes == ()


def test_source_smaller_than_one_chunk():
    ens = _rand_ensemble()
    plan = _plan(ens)
    x = _rand_x(ens, 5)
    res = BulkScorer(plan, ScoreConfig(chunk_rows=256, output="raw")) \
        .score(ArraySource(x))
    np.testing.assert_array_equal(
        res.output, np.asarray(plan.raw(jnp.asarray(x))))
    assert len(res.chunk_shapes) == 1


def test_one_row_tail_chunk():
    ens = _rand_ensemble()
    plan = _plan(ens)
    x = _rand_x(ens, 33)                         # 32 + a 1-row tail
    res = BulkScorer(plan, ScoreConfig(chunk_rows=32, output="raw")) \
        .score(ArraySource(x))
    np.testing.assert_array_equal(
        res.output, np.asarray(plan.raw(jnp.asarray(x))))
    assert len(res.chunk_shapes) <= 2
    assert plan.stats["traces"].get("raw_pool", 0) <= 2


def test_predict_pool_on_zero_row_pool():
    from repro.serving.engine import GBDTServer

    ens = _rand_ensemble(n_outputs=2)
    server = GBDTServer(ens, config=PredictConfig(strategy="staged",
                                                  backend="ref"),
                        max_batch=32)
    try:
        pool = QuantizedPool(
            jnp.zeros((0, ens.n_features), jnp.uint8),
            server.schema_fingerprint)
        out = server.predict_pool(pool)
        assert out.shape == (0, 2)
    finally:
        server.close()


# --------------------------------------------------------------------------
# Multi-model fan-out + schema sharing
# --------------------------------------------------------------------------
def test_multi_model_quantizes_once_per_schema():
    ens = _rand_ensemble(n_trees=12)
    plans = {"full": _plan(ens),
             "head": _plan(ens.slice_trees(0, 6)),
             "tail": _plan(ens.slice_trees(6, 12))}
    x = _rand_x(ens, 64)
    scorer = BulkScorer(plans, ScoreConfig(chunk_rows=32, output="raw"))
    registry.reset_call_stats()
    res = scorer.score(ArraySource(x))
    # 3 plans, 1 shared schema -> ONE binarize trace for the whole run
    assert registry.call_stats().get("binarize", 0) == 1
    # only the group's representative plan owns a quantize entry trace
    q_traces = {n: p.stats["traces"].get("quantize", 0)
                for n, p in plans.items()}
    assert sum(q_traces.values()) == 1, q_traces
    # fan-out sums: head + tail == full (same addends, regrouped)
    np.testing.assert_allclose(
        res.outputs["head"] + res.outputs["tail"],
        res.outputs["full"], rtol=1e-5, atol=1e-5)


def test_multi_model_feature_mismatch_rejected():
    a = _rand_ensemble(n_features=11)
    b = _rand_ensemble(seed=7, n_features=9)
    with pytest.raises(ValueError, match="feature count"):
        BulkScorer({"a": _plan(a), "b": _plan(b)})


# --------------------------------------------------------------------------
# Resume
# --------------------------------------------------------------------------
def test_resume_by_chunk_index(tmp_path):
    ens = _rand_ensemble()
    plan = _plan(ens)
    x = _rand_x(ens, 100)
    path = tmp_path / "scores.npy"
    cfg = ScoreConfig(chunk_rows=32, output="raw")
    BulkScorer(plan, cfg).score(ArraySource(x), NpySink(path))
    want = np.load(path).copy()

    # simulate an interrupted run: chunks 0-1 (rows [0, 64)) landed,
    # the process died; resume at chunk 2 into the surviving file
    partial = tmp_path / "resumed.npy"
    mm = np.lib.format.open_memmap(partial, mode="w+",
                                   dtype=np.float32, shape=want.shape)
    mm[:64] = want[:64]
    mm.flush()
    del mm
    res = BulkScorer(plan, cfg).score(
        ArraySource(x), NpySink(partial, resume=True), resume_from=2)
    assert res.metrics["resumed_from"] == 2
    assert res.metrics["rows"] == 100 - 64       # only remaining rows
    np.testing.assert_array_equal(np.load(partial), want)

    with pytest.raises(ValueError, match="resume_from"):
        BulkScorer(plan, cfg).score(ArraySource(x), resume_from=99)


# --------------------------------------------------------------------------
# score_source bridge + metrics units
# --------------------------------------------------------------------------
def test_server_score_source_matches_predict_batch():
    from repro.serving.engine import GBDTServer

    ens = _rand_ensemble(n_outputs=3)
    server = GBDTServer(ens, config=PredictConfig(strategy="staged",
                                                  backend="ref"),
                        max_batch=32)
    try:
        x = _rand_x(ens, 70)
        res = server.score_source(ArraySource(x), chunk_rows=32)
        np.testing.assert_allclose(res.output, server.predict_batch(x),
                                   rtol=1e-6, atol=1e-6)
        assert "rows_per_s" in res.metrics
        # online snapshot reports the same unit (shared dashboards)
        assert "rows_per_s" in server.metrics.snapshot()
        with pytest.raises(TypeError, match="not both"):
            server.score_source(ArraySource(x),
                                config=ScoreConfig(), chunk_rows=32)
    finally:
        server.close()


def test_percentile_reservoir_shared_and_bounded():
    r = PercentileReservoir(max_samples=64, seed=0)
    for v in range(1000):
        r.add(float(v))
    assert len(r) == 64 and r.seen == 1000
    assert 0.0 <= r.percentile(50) <= 999.0
    # both metrics classes sample through the same implementation
    assert isinstance(ServerMetrics("m")._lat, PercentileReservoir)
    assert isinstance(ScoringMetrics("b")._chunk_lat,
                      PercentileReservoir)


def test_scoring_metrics_snapshot_fields():
    m = ScoringMetrics("job")
    m.start()
    m.note_quantize(0.01)
    m.note_chunk(100, 128, 0.02)
    m.stop()
    snap = m.snapshot()
    assert snap["rows"] == 100 and snap["chunks"] == 1
    assert snap["rows_per_s"] > 0
    assert 0.0 < snap["quantize_frac"] < 1.0
    assert snap["pad_overhead"] == pytest.approx(28 / 128)


def test_scorer_rejects_bad_config_and_sinks():
    ens = _rand_ensemble()
    plan = _plan(ens)
    with pytest.raises(ValueError, match="output"):
        ScoreConfig(output="logits")
    with pytest.raises(TypeError, match="not both"):
        BulkScorer(plan, ScoreConfig(), chunk_rows=64)
    with pytest.raises(ValueError, match="at least one"):
        BulkScorer({})
    scorer = BulkScorer({"a": plan, "b": plan})
    with pytest.raises(ValueError, match="no sink"):
        scorer.score(ArraySource(_rand_x(ens, 8)), {"a": ArraySink()})
    with pytest.raises(ValueError, match="single"):
        scorer.score(ArraySource(_rand_x(ens, 8)), ArraySink())


def test_bulk_scorer_through_streaming_sinks():
    """StatsSink/TopKSink reduce a scored stream without holding it."""
    ens = _rand_ensemble(n_outputs=2)
    plan = _plan(ens)
    x = _rand_x(ens, 80)
    res = BulkScorer(plan, ScoreConfig(chunk_rows=32, output="raw")) \
        .score(ArraySource(x), StatsSink())
    want = np.asarray(plan.raw(jnp.asarray(x)))
    assert res.output["count"] == 80
    np.testing.assert_allclose(res.output["mean"], want.mean(0),
                               rtol=1e-4, atol=1e-5)
    top = BulkScorer(plan, ScoreConfig(chunk_rows=32, output="raw")) \
        .score(ArraySource(x), TopKSink(4, column=0))
    np.testing.assert_array_equal(top.output["indices"],
                                  np.argsort(-want[:, 0])[:4])
