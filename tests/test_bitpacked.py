"""Bitpacked layout: the tail-block bug class.

32 docs share one uint32 lane word, so the two classic failure modes
are (a) batches whose tail block is partially real (n % 32 != 0) and
(b) padded lane bits leaking into a group's 2^d leaf table at its top
index.  Plus the binary-split u1 pool planes (8x shrink) and the
routing that makes `best_layout` pick the layout at all.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import layout as layout_mod
from repro.core.layout import pack_pool_u1, unpack_pool_u1
from repro.core.predictor import PredictConfig, Predictor
from repro.core.trees import ObliviousEnsemble, truncate_tree_depths
from repro.kernels import ops, ref, tuning


def _ensemble(seed=7, n_trees=12, depth=4, n_features=11, n_borders=9,
              n_outputs=2, mixed=True):
    rng = np.random.default_rng(seed)
    borders = jnp.asarray(
        np.sort(rng.normal(size=(n_borders, n_features)), 0)
        .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, n_features,
                                  (n_trees, depth)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, n_borders + 1,
                                  (n_trees, depth)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(n_trees, 2 ** depth, n_outputs))
                     .astype(np.float32))
    ens = ObliviousEnsemble(sf, sb, lv, borders,
                            jnp.full((n_features,), n_borders, jnp.int32))
    if mixed:
        ens = truncate_tree_depths(
            ens, [(1, 2, 3, 4)[t % 4] for t in range(n_trees)])
    return ens


def _want(ens, x):
    return np.asarray(ens.base_score)[None, :] + np.asarray(
        ref.fused_predict(x, ens.borders, ens.split_features,
                          ens.split_bins, ens.leaf_values))


# --------------------------------------------------------------------------
# (a) ragged tail blocks: n % 32 != 0
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 31, 33, 63, 97])
@pytest.mark.parametrize("strategy", ["staged", "fused"])
def test_tail_block_exact(n, strategy):
    """Bitpacked pallas scoring is exact for every ragged batch size —
    the padded docs of the last lane word must not leak."""
    ens = _ensemble()
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n, ens.n_features))
                    .astype(np.float32))
    plan = Predictor.build(ens, PredictConfig(
        strategy=strategy, backend="pallas", layout="bitpacked"),
        expected_batch=n)
    np.testing.assert_allclose(np.asarray(plan.raw(x)), _want(ens, x),
                               rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------------
# (b) padded lane bits vs the 2^d leaf table's top index
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_full_lane_against_top_leaf(backend):
    """Every real doc routes to leaf 2^d - 1 (all comparison bits set)
    while the lane word's padded docs must stay at leaf 0: the padded
    half of the word and the top of the leaf table meet in one uint32."""
    ens = _ensemble(mixed=False)           # one group, depth 4
    d = ens.depth
    rng = np.random.default_rng(0)
    n = 33                                 # 2 lane words, 31 padded docs
    # x above every border -> bins == n_borders >= every split_bin
    x = jnp.asarray(np.abs(rng.normal(size=(n, ens.n_features)))
                    .astype(np.float32) + 100.0)
    plan = Predictor.build(ens, PredictConfig(
        strategy="staged", backend=backend, layout="bitpacked"),
        expected_batch=n)
    top = np.asarray(ens.base_score)[None, :] + np.asarray(
        ens.leaf_values[:, 2 ** d - 1, :]).sum(0)[None, :]
    np.testing.assert_allclose(np.asarray(plan.raw(x)),
                               np.broadcast_to(top, (n, ens.n_outputs)),
                               rtol=1e-5, atol=1e-4)
    # and the indexes themselves are bit-exact at the table's top slot
    bins = ref.binarize(x, ens.borders)
    idx = ops.leaf_index_bp_prepadded(
        bins, jnp.transpose(ens.split_features),
        jnp.transpose(ens.split_bins), backend=backend, block_t=1)
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.full((n, ens.n_trees), 2 ** d - 1))


def test_bitpacked_ref_leaf_indexes_bit_exact_vs_soa():
    """Acceptance pin: bitpacked leaf indexes == soa leaf indexes,
    exactly, on the ref backend (integers, no tolerance)."""
    ens = _ensemble()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(37, ens.n_features))
                    .astype(np.float32))
    bins = ref.binarize(x, ens.borders)
    want = ref.leaf_index(bins, ens.split_features, ens.split_bins)
    got = ref.leaf_index_bitpacked(bins,
                                   jnp.transpose(ens.split_features),
                                   jnp.transpose(ens.split_bins))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# binary-split schemas: u1 pool planes, the 8x pool-memory shrink
# --------------------------------------------------------------------------
def test_u1_pool_planes_roundtrip_and_score():
    ens = _ensemble(n_features=64, n_borders=1)    # binary splits
    lowered = layout_mod.lower(ens, "bitpacked", backend="ref")
    desc = lowered.describe()
    assert desc["binary_split"]
    assert desc["pool_row_bytes_u8"] == 64
    assert desc["pool_row_bytes_u1"] == 8          # 2 uint32 words
    assert desc["pool_shrink_x"] == 8.0
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(45, 64)).astype(np.float32))
    plan = Predictor.build(ens, PredictConfig(
        strategy="staged", backend="ref", layout="bitpacked"))
    pool = plan.quantize(x)
    planes = pack_pool_u1(pool.bins)
    assert planes.shape == (45, 2) and planes.dtype == jnp.uint32
    back = unpack_pool_u1(planes, 64)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.asarray(pool.bins))
    # a pool rebuilt from the u1 planes scores identically to the
    # float batch — the 8x shrink is lossless for binary splits
    rebuilt = dataclasses.replace(pool, bins=back.astype(jnp.uint8))
    np.testing.assert_array_equal(np.asarray(plan.raw(rebuilt)),
                                  np.asarray(plan.raw(x)))


def test_non_binary_schema_reports_no_shrink():
    lowered = layout_mod.lower(_ensemble(), "bitpacked", backend="ref")
    desc = lowered.describe()
    assert not desc["binary_split"]
    assert desc["pool_shrink_x"] == 1.0


def test_best_layout_routes_huge_mixed_to_bitpacked():
    depths = np.tile([4, 6, 8, 10], 50_000)
    assert tuning.best_layout(depths, 1, 512) == "bitpacked"
    # while modest mixed-depth models keep the grouped f32 layout
    assert tuning.best_layout(np.tile([2, 3, 4, 6], 25), 1,
                              54) == "depth_grouped"
