"""GBDT core: training learns, prediction strategies agree, persistence."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import boosting, losses, predict, quantize
from repro.core.boosting import BoostingParams
from repro.core.trees import ObliviousEnsemble
from repro.data import synthetic


def _fit(ds, n_trees=40, depth=None):
    loss = losses.make_loss(ds.loss, n_classes=max(ds.n_classes, 2),
                            group_index=ds.group_index_train)
    p = BoostingParams(n_trees=n_trees, depth=depth or ds.params.depth,
                       learning_rate=max(ds.params.learning_rate, 0.1))
    return boosting.fit(ds.x_train, ds.y_train, loss=loss, params=p) \
        + (loss,)


def test_multiclass_learns():
    ds = synthetic.load("covertype", scale=0.005)
    ens, hist, loss = _fit(ds, n_trees=60, depth=6)
    pred = predict.predict_class(ens, jnp.asarray(ds.x_test))
    acc = float((np.asarray(pred) == ds.y_test).mean())
    assert acc > 0.8, acc


def test_binary_learns():
    ds = synthetic.load("santander", scale=0.005)
    ens, hist, loss = _fit(ds, n_trees=80, depth=3)
    pred = predict.predict_class(ens, jnp.asarray(ds.x_test))
    acc = float((np.asarray(pred) == ds.y_test).mean())
    assert acc > 0.75, acc


def test_regression_learns():
    ds = synthetic.load("year_prediction_msd", scale=0.005)
    base_mae = np.abs(ds.y_test - np.median(ds.y_train)).mean()
    ens, hist, loss = _fit(ds, n_trees=80)
    raw = predict.raw_predict(ens, jnp.asarray(ds.x_test))
    # MAE fits around the initial raw 0 -> add train median offset trees do
    mae = np.abs(np.asarray(raw[:, 0]) - ds.y_test).mean()
    assert mae < base_mae * 1.05   # must at least approach the median
    assert hist["train_loss"][-1] < hist["train_loss"][0]


def test_ranking_learns():
    ds = synthetic.load("mq2008", scale=0.5)
    ens, hist, loss = _fit(ds, n_trees=60)
    # pairwise accuracy on train should beat random 0.5
    raw = predict.raw_predict(ens, jnp.asarray(ds.x_train))
    loss_te = losses.make_loss("yetirank",
                               group_index=ds.group_index_train)
    pacc = float(loss_te.metric(raw, jnp.asarray(ds.y_train)))
    assert pacc > 0.65, pacc


def test_strategies_agree():
    ds = synthetic.load("covertype", scale=0.003)
    ens, _, _ = _fit(ds, n_trees=25, depth=5)
    x = jnp.asarray(ds.x_test[:200])
    staged = predict.raw_predict(ens, x, strategy="staged", backend="ref")
    fused = predict.raw_predict(ens, x, strategy="fused", backend="ref")
    blocked = predict.raw_predict(ens, x, strategy="staged", backend="ref",
                                  tree_block=8)
    np.testing.assert_allclose(np.asarray(staged), np.asarray(fused),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(staged), np.asarray(blocked),
                               rtol=1e-5, atol=1e-5)


def test_ensemble_roundtrip(tmp_path):
    ds = synthetic.load("santander", scale=0.002)
    ens, _, _ = _fit(ds, n_trees=10, depth=2)
    path = tmp_path / "model.npz"
    ens.save(path)
    ens2 = ObliviousEnsemble.load(path)
    x = jnp.asarray(ds.x_test[:50])
    np.testing.assert_array_equal(
        np.asarray(predict.raw_predict(ens, x)),
        np.asarray(predict.raw_predict(ens2, x)))
    assert ens2.describe() == ens.describe()


def test_ensemble_pytree_roundtrip():
    """Regression: unflattening must bypass the base_score default.

    The old registration re-ran __post_init__ on every tree_unflatten,
    so any structural map whose leaves were not arrays (tree_map to
    None, tree_transpose) crashed on `leaf_values.shape`.
    """
    import jax
    ens = ObliviousEnsemble(
        jnp.zeros((2, 3), jnp.int32), jnp.ones((2, 3), jnp.int32),
        jnp.zeros((2, 8, 1)), jnp.zeros((4, 5)), jnp.zeros((5,), jnp.int32))
    # defaulted base_score is materialized at construction
    assert ens.base_score.shape == (1,)
    leaves, td = jax.tree_util.tree_flatten(ens)
    assert len(leaves) == 6               # base_score is a real leaf
    back = jax.tree_util.tree_unflatten(td, leaves)
    np.testing.assert_array_equal(np.asarray(back.base_score),
                                  np.asarray(ens.base_score))
    # structural maps with non-array leaves must not crash
    nones = jax.tree_util.tree_map(lambda _: None, ens,
                                   is_leaf=lambda v: v is None)
    assert nones.base_score is None and nones.leaf_values is None
    # and identity maps round-trip values exactly
    mapped = jax.tree_util.tree_map(lambda a: a + 0, ens)
    np.testing.assert_array_equal(np.asarray(mapped.split_bins),
                                  np.asarray(ens.split_bins))
    # jit treats the ensemble as a transparent pytree
    total = jax.jit(lambda e: e.leaf_values.sum() + e.base_score.sum())(ens)
    assert float(total) == 0.0


def test_borders_monotone_and_binarize_range():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 7)).astype(np.float32)
    borders, n_borders = quantize.compute_borders(x, max_bins=32)
    b = np.asarray(borders)
    for j in range(7):
        col = b[:int(n_borders[j]), j]
        assert np.all(np.diff(col) >= 0)
    bins = np.asarray(quantize.binarize_matrix(jnp.asarray(x), borders))
    assert bins.min() >= 0
    assert np.all(bins.max(0) <= np.asarray(n_borders))


def test_constant_feature_never_split():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(400, 4)).astype(np.float32)
    x[:, 2] = 7.0                      # constant junk feature
    y = (x[:, 0] > 0).astype(np.int32)
    loss = losses.make_loss("logloss")
    ens, _ = boosting.fit(x, y.astype(np.float32), loss=loss,
                          params=BoostingParams(n_trees=20, depth=3,
                                                learning_rate=0.3))
    assert not np.any(np.asarray(ens.split_features) == 2)


def test_ordered_boosting_runs_and_reduces_leakage():
    """Ordered boosting: finite, loss decreases, and on a noisy dataset
    the train metric is LESS overfit than plain boosting (prefix
    estimates cannot memorize their own target)."""
    rng = np.random.default_rng(7)
    n = 600
    x = rng.normal(size=(n, 6)).astype(np.float32)
    y = (0.4 * x[:, 0] + rng.normal(size=n)).astype(np.float32)  # noisy
    loss = losses.make_loss("rmse")
    plain, h_plain = boosting.fit(
        x, y, loss=loss, params=BoostingParams(n_trees=60, depth=4,
                                               learning_rate=0.3))
    ordered, h_ord = boosting.fit(
        x, y, loss=loss, params=BoostingParams(n_trees=60, depth=4,
                                               learning_rate=0.3,
                                               ordered=True))
    assert np.isfinite(h_ord["train_loss"]).all()
    assert h_ord["train_loss"][-1] < h_ord["train_loss"][0]
    # plain memorizes noise faster -> lower (over-fit) train loss
    assert h_plain["train_loss"][-1] < h_ord["train_loss"][-1]
    # both produce usable ensembles
    for ens in (plain, ordered):
        raw = predict.raw_predict(ens, jnp.asarray(x))
        assert np.isfinite(np.asarray(raw)).all()
