"""Lowered ensemble layouts (`core.layout`): lowering correctness and
parity across soa / depth_major / depth_grouped, registry layout
routing, tuning-based layout selection, plan integration (config
resolution, stats, serving metrics), and the lowered-pytree round
trips."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import layout as layout_mod
from repro.core.layout import (BitpackedLayout, DepthGroupedLayout,
                               DepthMajorLayout, SoaLayout, lower)
from repro.core.predictor import PredictConfig, Predictor
from repro.core.trees import (ObliviousEnsemble, PAD_SPLIT_BIN,
                              truncate_tree_depths)
from repro.kernels import ops, ref, registry, tuning


def _rand_ensemble(seed=3, n_trees=13, depth=4, n_features=11,
                   n_borders=9, n_outputs=2):
    rng = np.random.default_rng(seed)
    borders = jnp.asarray(
        np.sort(rng.normal(size=(n_borders, n_features)), 0)
        .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, n_features,
                                  (n_trees, depth)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, n_borders,
                                  (n_trees, depth)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(n_trees, 2 ** depth, n_outputs))
                     .astype(np.float32))
    return ObliviousEnsemble(sf, sb, lv, borders,
                             jnp.full((n_features,), n_borders, jnp.int32))


def _mixed_depth(ens, cycle=(1, 2, 3, None)):
    """Truncate tree t to depth cycle[t % len] through the canonical
    `trees.truncate_tree_depths` (trailing always-left pads)."""
    depths = [ens.depth if cycle[t % len(cycle)] is None
              else min(cycle[t % len(cycle)], ens.depth)
              for t in range(ens.n_trees)]
    return truncate_tree_depths(ens, depths)


def _rand_x(ens, n=37, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, ens.n_features))
                       .astype(np.float32))


def _want(ens, x):
    return np.asarray(ens.base_score)[None, :] + np.asarray(
        ref.fused_predict(x, ens.borders, ens.split_features,
                          ens.split_bins, ens.leaf_values))


# --------------------------------------------------------------------------
# true_depths metadata
# --------------------------------------------------------------------------
def test_true_depths():
    ens = _mixed_depth(_rand_ensemble(n_trees=8, depth=4))
    np.testing.assert_array_equal(ens.true_depths,
                                  [1, 2, 3, 4, 1, 2, 3, 4])
    # uniform ensembles report the shared depth everywhere
    uni = _rand_ensemble(n_trees=5)
    np.testing.assert_array_equal(uni.true_depths, [4] * 5)
    # a PAD level BETWEEN real levels is not depth padding
    sb = np.asarray(uni.split_bins).copy()
    sb[0, 1] = PAD_SPLIT_BIN           # mid-level pad: still depth 4
    sb[1, 1:] = PAD_SPLIT_BIN          # trailing run: depth 1
    mixed = dataclasses.replace(uni, split_bins=jnp.asarray(sb))
    np.testing.assert_array_equal(mixed.true_depths, [4, 1, 4, 4, 4])


def test_true_depths_all_padded_tree():
    ens = _rand_ensemble(n_trees=3)
    sb = np.asarray(ens.split_bins).copy()
    sb[1, :] = PAD_SPLIT_BIN           # depth-0 (constant) tree
    ens = dataclasses.replace(ens, split_bins=jnp.asarray(sb))
    np.testing.assert_array_equal(ens.true_depths, [4, 0, 4])
    # lowering clamps the group to depth 1 and stays correct
    x = _rand_x(ens, 9)
    plan = Predictor.build(ens, PredictConfig(
        strategy="staged", backend="ref", layout="depth_grouped"))
    np.testing.assert_allclose(np.asarray(plan.raw(x)), _want(ens, x),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Lowering parity: every layout == the logical model, on unpadded,
# depth-padded, and mixed-depth ensembles, both kernel families,
# both strategies
# --------------------------------------------------------------------------
@pytest.mark.parametrize("layout", layout_mod.LAYOUT_NAMES)
@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("strategy", ["staged", "fused"])
def test_layout_parity_mixed_depth(layout, backend, strategy):
    ens = _mixed_depth(_rand_ensemble())
    x = _rand_x(ens, 37)
    plan = Predictor.build(ens, PredictConfig(
        strategy=strategy, backend=backend, layout=layout),
        expected_batch=37)
    np.testing.assert_allclose(np.asarray(plan.raw(x)), _want(ens, x),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("layout", layout_mod.LAYOUT_NAMES)
def test_layout_parity_unpadded_uniform(layout):
    ens = _rand_ensemble()                 # no padded levels at all
    x = _rand_x(ens, 21)
    plan = Predictor.build(ens, PredictConfig(
        strategy="staged", backend="ref", layout=layout))
    np.testing.assert_allclose(np.asarray(plan.raw(x)), _want(ens, x),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("layout", layout_mod.LAYOUT_NAMES)
def test_layout_parity_quantized_pool(layout):
    # the pool path starts at leaf_sum: every layout must score a
    # pre-quantized uint8 pool identically to its float path
    ens = _mixed_depth(_rand_ensemble())
    x = _rand_x(ens, 19)
    plan = Predictor.build(ens, PredictConfig(
        strategy="staged", backend="ref", layout=layout))
    pool = plan.quantize(x)
    np.testing.assert_array_equal(np.asarray(plan.raw(pool)),
                                  np.asarray(plan.raw(x)))


def test_depth_major_ref_is_bit_exact():
    # the one-hot matmul touches only f32-exact integers: depth_major
    # on the jnp reference must be BIT-identical to soa, not just close
    ens = _mixed_depth(_rand_ensemble())
    x = _rand_x(ens, 33)
    soa = Predictor.build(ens, PredictConfig(
        strategy="staged", backend="ref", layout="soa"))
    dm = Predictor.build(ens, PredictConfig(
        strategy="staged", backend="ref", layout="depth_major"))
    np.testing.assert_array_equal(np.asarray(soa.raw(x)),
                                  np.asarray(dm.raw(x)))


def test_depth_grouped_shrinks_leaf_tables():
    ens = _mixed_depth(_rand_ensemble(n_trees=16, depth=5))
    soa = lower(ens, "soa")
    grouped = lower(ens, "depth_grouped")
    assert grouped.leaf_table_bytes() < soa.leaf_table_bytes() / 2
    # group structure: one group per distinct clamped depth, all trees
    assert [g.depth for g in grouped.groups] == [1, 2, 3, 5]
    assert sum(g.n_trees for g in grouped.groups) == ens.n_trees


def test_lower_pallas_pads_model_once():
    ens = _rand_ensemble()
    ops.reset_pad_stats()
    low = lower(ens, "depth_major", backend="pallas", t_align=16)
    assert ops.pad_stats()["model"] == low.n_model_pads > 0
    assert low.borders.shape[1] % ops.FEATURE_ALIGN == 0
    assert low.onehot.shape[0] % 16 == 0
    assert low.split_bins_dm.shape == (ens.depth, low.onehot.shape[0])
    # ref lowering keeps exact shapes (padding would be wasted math)
    assert lower(ens, "depth_major").onehot.shape[0] == ens.n_trees


def test_lower_rejects_unknown_layout_and_tracers():
    ens = _rand_ensemble()
    with pytest.raises(ValueError, match="unknown layout"):
        lower(ens, "warp")
    # depth_grouped must refuse tracer ensembles (shard-local plans)
    def build_traced(sb):
        traced = dataclasses.replace(ens, split_bins=sb)
        return lower(traced, "depth_grouped")
    with pytest.raises(Exception):
        jax.eval_shape(build_traced, ens.split_bins)


# --------------------------------------------------------------------------
# Registry layout routing + capability metadata
# --------------------------------------------------------------------------
def test_registry_layout_resolution():
    assert registry.resolve("leaf_index", "ref",
                            layout="depth_major") == "ref_dm"
    assert registry.resolve("leaf_index", "pallas",
                            layout="depth_major") == "pallas_dm"
    # soa kernels serve depth_grouped directly (per-group evaluation)
    assert registry.resolve("leaf_index", "ref",
                            layout="depth_grouped") == "ref"
    # binarize is layout-independent
    assert registry.resolve("binarize", "ref",
                            layout="depth_major") == "ref"
    # uint8 pools route to the shared dm impl (it takes both dtypes)
    assert registry.resolve("leaf_index", "ref", dtype="uint8",
                            layout="depth_major") == "ref_dm"
    # bitpacked routes via the _bp suffix exactly like _dm
    assert registry.resolve("leaf_index", "ref",
                            layout="bitpacked") == "ref_bp"
    assert registry.resolve("leaf_index", "pallas", dtype="uint8",
                            layout="bitpacked") == "pallas_bp"
    assert registry.resolve("fused_predict", "pallas",
                            layout="bitpacked") == "pallas_bp"
    assert registry.resolve("binarize", "ref", layout="bitpacked") == "ref"
    with pytest.raises(ValueError, match="does not consume"):
        registry.resolve("leaf_gather", "ref", layout="nope")


def test_every_layout_claims_only_covered_ops():
    # the CI capability smoke, as a test: every op a layout claims has
    # at least one registered implementation consuming that layout
    for name, spec in layout_mod.LAYOUTS.items():
        for op in spec.claimed_ops:
            impls = registry.impls_for_layout(op, name)
            assert impls, f"layout {name} claims {op} with no impl"
    rows = registry.table()
    assert all("layouts" in r for r in rows)
    assert "layouts" in registry.format_table().splitlines()[0]


# --------------------------------------------------------------------------
# Tuning: layout selection from ensemble shape
# --------------------------------------------------------------------------
def test_best_layout_heuristics():
    mixed = np.tile([2, 3, 4, 6], 25)
    uniform = np.full(100, 6)
    assert tuning.best_layout(mixed, 1, 54) == "depth_grouped"
    assert tuning.best_layout(mixed, 1, 54,
                              backend="pallas") == "depth_grouped"
    # uniform depths: the hoisted one-hot pays off only for the pallas
    # kernel family; the jnp reference gathers cheaper than it matmuls
    assert tuning.best_layout(uniform, 1, 54) == "soa"
    assert tuning.best_layout(uniform, 1, 54,
                              backend="pallas") == "depth_major"
    # a one-hot matrix over budget falls back to soa
    assert tuning.best_layout(np.full(200_000, 8), 1, 512,
                              backend="pallas") == "soa"
    assert tuning.best_layout(np.asarray([], np.int64), 1, 54) == "soa"
    # mixed depths whose f32 one-hot working set blows the VMEM budget
    # route to the integer bitpacked pipeline (any backend)
    huge_mixed = np.tile([4, 6, 8, 10], 50_000)
    assert tuning.best_layout(huge_mixed, 1, 512) == "bitpacked"
    assert tuning.best_layout(huge_mixed, 1, 512,
                              backend="pallas") == "bitpacked"
    costs = tuning.layout_costs(mixed, 1, 54)
    assert costs["depth_grouped_leaf_bytes"] < costs["soa_leaf_bytes"]
    assert costs["bitpacked_leaf_bytes"] == costs["depth_grouped_leaf_bytes"]
    assert 0 < costs["bitpacked_plane_bytes"] < costs["soa_leaf_bytes"]


# --------------------------------------------------------------------------
# Plan integration
# --------------------------------------------------------------------------
def test_config_layout_validation_and_resolution():
    with pytest.raises(ValueError, match="layout"):
        PredictConfig(layout="columnar")
    with pytest.raises(ValueError, match="soa-layout"):
        PredictConfig(tree_block=4, layout="depth_grouped")
    ens = _mixed_depth(_rand_ensemble())
    r = PredictConfig().resolve(ens)
    assert r.layout == "depth_grouped"      # mixed depths -> grouped
    assert r.is_resolved
    # tree blocking pins auto to soa (blocked loop is an soa feature)
    rb = PredictConfig(tree_block=4).resolve(ens)
    assert rb.layout == "soa"
    # uniform-depth ref plans stay on the compatibility default
    assert PredictConfig().resolve(_rand_ensemble()).layout == "soa"
    assert not PredictConfig(layout="depth_major").is_resolved


def test_plan_stats_expose_layout_and_lowering():
    ens = _mixed_depth(_rand_ensemble())
    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"))
    s = plan.stats
    assert s["layout"] == "depth_grouped"
    assert s["lower_time_s"] >= 0.0
    assert plan.describe()["layout"] == "depth_grouped"
    assert plan.describe()["lowered"]["layout"] == "depth_grouped"
    assert "depth_grouped" in repr(plan)
    # deferred prepare (mesh-style plans): lowering lands on first call
    lazy = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"),
                           prepare=False)
    assert lazy.stats["lower_time_s"] == 0.0
    lazy.raw(_rand_x(ens, 5))
    assert lazy._lowered is not None


def test_server_metrics_report_layout():
    from repro.serving.engine import GBDTServer
    ens = _mixed_depth(_rand_ensemble(n_outputs=1))
    server = GBDTServer(ens, config=PredictConfig(strategy="staged",
                                                  backend="ref"),
                        max_batch=16)
    try:
        snap = server.metrics.snapshot()
        assert snap["layout"] == "depth_grouped"
    finally:
        server.close()


# --------------------------------------------------------------------------
# Lowered layouts are well-behaved pytrees
# --------------------------------------------------------------------------
@pytest.mark.parametrize("layout", layout_mod.LAYOUT_NAMES)
def test_lowered_pytree_roundtrip(layout):
    ens = _mixed_depth(_rand_ensemble())
    low = lower(ens, layout)
    leaves, td = jax.tree_util.tree_flatten(low)
    back = jax.tree_util.tree_unflatten(td, leaves)
    assert type(back) is type(low)
    x = _rand_x(ens, 7)
    bins = ref.binarize(x, ens.borders)
    np.testing.assert_array_equal(
        np.asarray(low.leaf_sum(bins, backend="ref", block_t=16)),
        np.asarray(back.leaf_sum(bins, backend="ref", block_t=16)))
    # structural maps must not re-run lowering logic
    nones = jax.tree_util.tree_map(lambda _: None, low,
                                   is_leaf=lambda v: v is None)
    assert isinstance(nones, (SoaLayout, DepthMajorLayout,
                              DepthGroupedLayout, BitpackedLayout))
