"""Perf-regression gate: classification of gated metrics, the
tolerance-band compare, and positive/negative controls against the
committed results/perf baselines (no bench runs — the gate's compare
path is a pure function of scenario dicts)."""
import copy
import json

import pytest

from repro.launch.perf_gate import (BASELINE_DIR, BENCHES, classify,
                                    compare, load_dir, main)


def _baselines():
    base = load_dir(BASELINE_DIR)
    assert base, "committed results/perf baselines missing"
    prefixes = tuple(p for _, ps in BENCHES.values() for p in ps)
    return {k: v for k, v in base.items() if k.startswith(prefixes)}


def test_classify_families():
    assert classify("speedup_vs_naive") == "ratio"
    assert classify("parity_ratio_vs_jitted_legacy") == "ratio"
    assert classify("max_abs_err") == "err"
    assert classify("exact") == "flag"
    assert classify("splits_equal_vs_seed") == "flag"
    assert classify("compiles") == "zero"
    assert classify("binarize_calls") == "zero"
    # absolute wall/throughput numbers are deliberately not gated
    assert classify("us_per_call") is None
    assert classify("rows_per_s") is None
    assert classify("wall_s") is None


def test_baselines_pass_against_themselves():
    base = _baselines()
    rows = compare(base, base)
    assert rows, "no gated metrics found in committed baselines"
    assert all(r["status"] == "ok" for r in rows)
    # the committed trajectory actually exercises every gate family
    kinds = {r["kind"] for r in rows}
    assert {"ratio", "err", "flag", "zero"} <= kinds


def test_positive_control_injected_regressions_fail():
    base = _baselines()
    fresh = copy.deepcopy(base)
    # collapse-class slowdown, parity rot, flag degradation, and a
    # broken zero-dispatch contract — one per gate family
    fresh["scoring-bench__bulk-prequant"]["speedup_vs_naive"] = 0.9
    fresh["layout-sweep__bitpacked"]["max_abs_err"] = 0.5
    fresh["training-bench__pool"]["splits_equal_vs_seed"] = False
    fresh["mesh-bench__k4"]["binarize_calls"] = 3
    rows = compare(base, fresh)
    bad = {(r["scenario"], r["metric"]) for r in rows
           if r["status"] == "REGRESSION"}
    assert bad == {
        ("scoring-bench__bulk-prequant", "speedup_vs_naive"),
        ("layout-sweep__bitpacked", "max_abs_err"),
        ("training-bench__pool", "splits_equal_vs_seed"),
        ("mesh-bench__k4", "binarize_calls"),
    }


def test_tolerance_band_boundaries():
    base = {"s": {"speedup_vs_x": 2.0}}
    at_floor = compare(base, {"s": {"speedup_vs_x": 2.0 * 0.4}})
    assert all(r["status"] == "ok" for r in at_floor)
    below = compare(base, {"s": {"speedup_vs_x": 2.0 * 0.39}})
    assert any(r["status"] == "REGRESSION" for r in below)
    # a tighter band flags what the default tolerates
    tight = compare(base, {"s": {"speedup_vs_x": 1.7}}, ratio_tol=0.1)
    assert any(r["status"] == "REGRESSION" for r in tight)


def test_missing_fresh_scenario_is_skipped_not_failed():
    base = {"mesh-bench__k8": {"speedup_vs_k1": 1.6}}
    rows = compare(base, {})
    assert [r["status"] for r in rows] == ["skipped"]


def test_missing_metric_in_fresh_is_schema_break():
    base = {"s": {"speedup_vs_x": 2.0, "exact": True}}
    rows = compare(base, {"s": {"exact": True}})
    bad = [r for r in rows if r["status"] == "REGRESSION"]
    assert len(bad) == 1 and bad[0]["metric"] == "speedup_vs_x"
    assert "missing" in bad[0]["detail"]


def test_err_metric_floor_allows_noise_on_zero_baselines():
    # baseline max_abs_err == 0.0 must not reject fresh fp rounding
    base = {"s": {"max_abs_err": 0.0}}
    ok = compare(base, {"s": {"max_abs_err": 5e-6}})
    assert all(r["status"] == "ok" for r in ok)
    bad = compare(base, {"s": {"max_abs_err": 1e-3}})
    assert any(r["status"] == "REGRESSION" for r in bad)


def test_zero_gate_only_binds_zero_baselines():
    base = {"s": {"compiles": 2}}
    rows = compare(base, {"s": {"compiles": 5}})
    assert all(r["status"] == "ok" for r in rows)   # nonzero base: free


def test_main_fresh_dir_end_to_end(tmp_path):
    # negative control through the CLI: baselines vs a copy pass...
    fresh = tmp_path / "fresh"
    fresh.mkdir()
    for name, snap in _baselines().items():
        (fresh / f"{name}.json").write_text(json.dumps(snap))
    assert main(["--check", "--fresh-dir", str(fresh)]) == 0
    # ...and an injected collapse fails with a non-zero exit
    hot = json.loads(
        (fresh / "scoring-bench__bulk-prequant.json").read_text())
    hot["speedup_vs_naive"] = 0.5
    (fresh / "scoring-bench__bulk-prequant.json").write_text(
        json.dumps(hot))
    report = tmp_path / "report.json"
    assert main(["--check", "--fresh-dir", str(fresh),
                 "--json-out", str(report)]) == 1
    rows = json.loads(report.read_text())
    assert any(r["status"] == "REGRESSION" for r in rows)
    # without --check the regression is reported but the exit is 0
    assert main(["--fresh-dir", str(fresh)]) == 0


def test_unknown_bench_selection_errors():
    with pytest.raises(SystemExit):
        main(["--benches", "nope", "--fresh-dir", "/tmp"])
