"""Compiled-plan Predictor API: config validation + one-time resolution,
plan-cache bounds (recompiles per batch shape), prepare-once model
padding, parity with the legacy kwarg path, CatBoost JSON ingestion,
and ensemble concat/slice validation."""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import predict
from repro.core.predictor import (PredictConfig, Predictor,
                                  load_catboost_json)
from repro.core.trees import (ObliviousEnsemble, PAD_SPLIT_BIN,
                              concat_ensembles)
from repro.kernels import ops, ref


def _rand_ensemble(seed=3, n_trees=13, depth=4, n_features=11,
                   n_borders=9, n_outputs=2):
    rng = np.random.default_rng(seed)
    borders = jnp.asarray(
        np.sort(rng.normal(size=(n_borders, n_features)), 0)
        .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, n_features,
                                  (n_trees, depth)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, n_borders,
                                  (n_trees, depth)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(n_trees, 2 ** depth, n_outputs))
                     .astype(np.float32))
    return ObliviousEnsemble(sf, sb, lv, borders,
                             jnp.full((n_features,), n_borders, jnp.int32))


def _rand_x(ens, n=37, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, ens.n_features))
                       .astype(np.float32))


# --------------------------------------------------------------------------
# PredictConfig
# --------------------------------------------------------------------------
def test_config_validation():
    with pytest.raises(ValueError):
        PredictConfig(strategy="warp")
    with pytest.raises(ValueError):
        PredictConfig(backend="cuda")
    with pytest.raises(ValueError):
        PredictConfig(tree_block=-1)
    with pytest.raises(ValueError):
        PredictConfig(block_n=0)
    with pytest.raises(ValueError):
        PredictConfig(block_t=-8)


def test_config_resolves_auto_once():
    ens = _rand_ensemble()
    cfg = PredictConfig()          # everything auto
    assert not cfg.is_resolved
    r = cfg.resolve(ens, n_rows=64)
    assert r.is_resolved
    assert r.strategy in ("staged", "fused")
    assert r.backend in ("pallas", "ref")
    # fused plans always carry concrete block shapes
    rf = PredictConfig(strategy="fused").resolve(ens, n_rows=64)
    assert rf.block_n is not None and rf.block_t is not None
    # resolving a resolved config is a no-op
    assert r.resolve(ens) == r


def test_build_rejects_config_and_kwargs():
    ens = _rand_ensemble()
    with pytest.raises(TypeError):
        Predictor.build(ens, PredictConfig(), strategy="staged")
    # kwargs-only convenience form works
    plan = Predictor.build(ens, strategy="staged", backend="ref")
    assert plan.config.strategy == "staged"


# --------------------------------------------------------------------------
# Plan cache + prepare-once padding (the acceptance criteria)
# --------------------------------------------------------------------------
def test_plan_cache_bounded_by_batch_shapes():
    ens = _rand_ensemble()
    plan = Predictor.build(ens, strategy="staged", backend="ref")
    x = _rand_x(ens, 64)
    for n in (16, 16, 16, 32, 16, 32):
        plan.raw(x[:n])
    s = plan.stats
    # recompiles are bounded by distinct batch shapes, not call count
    assert s["traces"]["raw"] == 2, s
    assert s["cache_entries"] == 2
    plan.proba(x[:16])             # separate entry point, own cache
    assert plan.stats["traces"]["proba"] == 1
    assert plan.stats["total_traces"] == 3


def test_model_padded_once_then_zero_model_pads():
    # The core acceptance check: after build, repeated fixed-batch
    # predicts trigger zero model-side jnp.pad ops and <= 1 XLA trace.
    ens = _rand_ensemble()
    x = _rand_x(ens, 16)
    ops.reset_pad_stats()
    plan = Predictor.build(ens, PredictConfig(strategy="fused",
                                              backend="pallas"),
                           expected_batch=16)
    build_pads = ops.pad_stats()["model"]
    assert build_pads > 0                       # unpadded model: F, T pads
    assert plan.stats["build_model_pads"] == build_pads
    ops.reset_pad_stats()
    outs = [plan.raw(x) for _ in range(3)]
    assert ops.pad_stats()["model"] == 0        # zero model-side pads
    assert plan.stats["traces"]["raw"] == 1     # one compile at fixed batch
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[-1]))


def test_deferred_prepare_pads_on_first_predict():
    # prepare=False (mesh servers): no model prep at build, one-time
    # prep on first local predict, same results.
    ens = _rand_ensemble()
    x = _rand_x(ens, 16)
    plan = Predictor.build(ens, PredictConfig(strategy="fused",
                                              backend="pallas"),
                           expected_batch=16, prepare=False)
    assert plan.stats["build_model_pads"] == 0
    got = np.asarray(plan.raw(x))
    assert plan.stats["build_model_pads"] > 0
    want = np.asarray(ref.fused_predict(x, ens.borders, ens.split_features,
                                        ens.split_bins, ens.leaf_values))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_staged_prepadded_pallas_zero_model_pads():
    ens = _rand_ensemble()
    x = _rand_x(ens, 8)
    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="pallas"))
    ops.reset_pad_stats()
    plan.raw(x)
    plan.raw(x)
    assert ops.pad_stats()["model"] == 0
    assert plan.stats["traces"]["raw"] == 1


# --------------------------------------------------------------------------
# Parity with the legacy kwarg path
# --------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    PredictConfig(strategy="staged", backend="ref"),
    PredictConfig(strategy="fused", backend="ref"),
    PredictConfig(strategy="staged", backend="pallas"),
    PredictConfig(strategy="fused", backend="pallas"),
    PredictConfig(strategy="staged", backend="ref", tree_block=4),
    PredictConfig(strategy="staged", backend="pallas", tree_block=4),
])
def test_plan_matches_kwarg_path_on_unpadded_ensemble(cfg):
    # 13 trees / depth 4 / 11 features: nothing divides the kernel block
    # multiples, so the prepadded plan must reproduce the per-call
    # padding exactly.
    ens = _rand_ensemble()
    x = _rand_x(ens, 37)
    want = np.asarray(ref.fused_predict(x, ens.borders, ens.split_features,
                                        ens.split_bins, ens.leaf_values))
    plan = Predictor.build(ens, cfg, expected_batch=37)
    got = np.asarray(plan.raw(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    legacy = np.asarray(predict.raw_predict(
        ens, x, strategy=cfg.strategy, backend=cfg.backend,
        tree_block=cfg.tree_block))
    np.testing.assert_allclose(got, legacy, rtol=1e-5, atol=1e-4)


def test_proba_and_classify_match_legacy():
    ens = _rand_ensemble(n_outputs=3)
    x = _rand_x(ens, 20)
    plan = Predictor.build(ens, strategy="staged", backend="ref")
    np.testing.assert_allclose(
        np.asarray(plan.proba(x)),
        np.asarray(predict.predict_proba(ens, x, strategy="staged",
                                         backend="ref")),
        rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(plan.classify(x)),
        np.asarray(predict.predict_class(ens, x, strategy="staged",
                                         backend="ref")))
    # binary model probas are two-column sigmoid
    bin_ens = _rand_ensemble(seed=5, n_outputs=1)
    bplan = Predictor.build(bin_ens, strategy="staged", backend="ref")
    proba = np.asarray(bplan.proba(_rand_x(bin_ens, 9)))
    assert proba.shape == (9, 2)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, rtol=1e-6)


# --------------------------------------------------------------------------
# CatBoost JSON ingestion
# --------------------------------------------------------------------------
def _catboost_json(tmp_path):
    model = {
        "features_info": {"float_features": [
            {"flat_feature_index": 0, "borders": [0.0, 1.0]},
            {"flat_feature_index": 1, "borders": [0.5]},
        ]},
        "oblivious_trees": [
            {"splits": [
                {"split_type": "FloatFeature", "float_feature_index": 0,
                 "border": 1.0},
                {"split_type": "FloatFeature", "float_feature_index": 1,
                 "border": 0.5},
            ], "leaf_values": [1.0, 2.0, 3.0, 4.0]},
            # shallower tree: importer pads it to the ensemble depth
            {"splits": [
                {"split_type": "FloatFeature", "float_feature_index": 0,
                 "border": 0.0},
            ], "leaf_values": [10.0, 20.0]},
        ],
        "scale_and_bias": [2.0, [0.25]],
    }
    path = tmp_path / "model.json"
    path.write_text(json.dumps(model))
    return path


def _hand_built_equivalent():
    """The same model assembled directly — the round-trip oracle."""
    borders = jnp.asarray(np.array([[0.0, 0.5], [1.0, np.inf]], np.float32))
    sf = jnp.asarray(np.array([[0, 1], [0, 0]], np.int32))
    sb = jnp.asarray(np.array([[2, 1], [1, PAD_SPLIT_BIN]], np.int32))
    lv = jnp.asarray(2.0 * np.array(
        [[[1.0], [2.0], [3.0], [4.0]],
         [[10.0], [20.0], [0.0], [0.0]]], np.float32))
    return ObliviousEnsemble(sf, sb, lv, borders,
                             jnp.asarray(np.array([2, 1], np.int32)),
                             base_score=jnp.asarray([0.25], jnp.float32))


def test_catboost_json_roundtrip_matches_hand_built(tmp_path):
    ens = load_catboost_json(_catboost_json(tmp_path))
    want = _hand_built_equivalent()
    assert ens.describe() == want.describe()
    np.testing.assert_array_equal(np.asarray(ens.split_features),
                                  np.asarray(want.split_features))
    np.testing.assert_array_equal(np.asarray(ens.split_bins),
                                  np.asarray(want.split_bins))
    np.testing.assert_allclose(np.asarray(ens.leaf_values),
                               np.asarray(want.leaf_values))
    np.testing.assert_allclose(np.asarray(ens.base_score), [0.25])

    x = jnp.asarray(np.array([[-1.0, 0.0], [0.5, 0.9], [2.0, 0.9],
                              [2.0, 0.0]], np.float32))
    plan = Predictor.from_catboost_json(_catboost_json(tmp_path),
                                        PredictConfig(strategy="fused",
                                                      backend="ref"))
    got = np.asarray(plan.raw(x))[:, 0]
    # hand computation: raw = 2*(tree0_leaf + tree1_leaf) + 0.25 where
    # tree0 leaf bit0 = x0 > 1.0, bit1 = x1 > 0.5; tree1 bit0 = x0 > 0.0
    expect = np.array([2 * (1 + 10), 2 * (3 + 20), 2 * (4 + 20),
                       2 * (2 + 20)], np.float32) + 0.25
    np.testing.assert_allclose(got, expect, rtol=1e-6)
    np.testing.assert_allclose(
        got, np.asarray(predict.raw_predict(
            ens, x, strategy="staged", backend="ref"))[:, 0], rtol=1e-6)


def test_catboost_json_multiclass_scale_and_bias(tmp_path):
    """Multiclass export edge: per-class bias vector + scale applied to
    every leaf value; 3-class leaf tables are leaf-major."""
    model = {
        "features_info": {"float_features": [
            {"flat_feature_index": 0, "borders": [0.0]},
        ]},
        "oblivious_trees": [
            {"splits": [
                {"split_type": "FloatFeature", "float_feature_index": 0,
                 "border": 0.0},
            ],
             # leaf-major: leaf 0 -> classes (1, 2, 3), leaf 1 -> (4, 5, 6)
             "leaf_values": [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]},
        ],
        "scale_and_bias": [0.5, [0.1, 0.2, 0.3]],
    }
    path = tmp_path / "mc.json"
    path.write_text(json.dumps(model))
    ens = load_catboost_json(path)
    assert ens.n_outputs == 3
    np.testing.assert_allclose(np.asarray(ens.base_score), [0.1, 0.2, 0.3])
    np.testing.assert_allclose(np.asarray(ens.leaf_values[0, 0]),
                               [0.5, 1.0, 1.5])
    plan = Predictor.build(ens, strategy="staged", backend="ref")
    x = jnp.asarray([[-1.0], [1.0]], jnp.float32)
    np.testing.assert_allclose(
        np.asarray(plan.raw(x)),
        np.asarray([[0.6, 1.2, 1.8], [2.1, 2.7, 3.3]]), rtol=1e-6)
    # mismatched bias width is a hard error
    model["scale_and_bias"] = [1.0, [0.1, 0.2]]
    path.write_text(json.dumps(model))
    with pytest.raises(ValueError, match="scale_and_bias"):
        load_catboost_json(path)


def _nonuniform_json(tmp_path):
    """Depths 3 / 1 / 2: exercises true_depths + depth_grouped."""
    b = {"split_type": "FloatFeature", "float_feature_index": 0}
    model = {
        "features_info": {"float_features": [
            {"flat_feature_index": 0, "borders": [0.0, 1.0, 2.0]},
        ]},
        "oblivious_trees": [
            {"splits": [dict(b, border=0.0), dict(b, border=1.0),
                        dict(b, border=2.0)],
             "leaf_values": [float(v) for v in range(8)]},
            {"splits": [dict(b, border=1.0)],
             "leaf_values": [10.0, 20.0]},
            {"splits": [dict(b, border=0.0), dict(b, border=2.0)],
             "leaf_values": [1.0, 2.0, 3.0, 4.0]},
        ],
    }
    path = tmp_path / "mixed.json"
    path.write_text(json.dumps(model))
    return path


def test_catboost_json_nonuniform_depths_lower_grouped(tmp_path):
    ens = load_catboost_json(_nonuniform_json(tmp_path))
    assert ens.depth == 3
    np.testing.assert_array_equal(ens.true_depths, [3, 1, 2])
    # shallow trees use the PAD_SPLIT_BIN always-left convention: the
    # padded levels can never fire, so their leaf-index bits stay 0
    sb = np.asarray(ens.split_bins)
    assert sb[1, 1] == PAD_SPLIT_BIN and sb[1, 2] == PAD_SPLIT_BIN
    assert sb[2, 2] == PAD_SPLIT_BIN
    np.testing.assert_array_equal(np.asarray(ens.leaf_values)[1, 2:, 0], 0.0)
    # an auto plan on this model picks depth_grouped and matches ref
    from repro.kernels import ref
    plan = Predictor.build(ens, PredictConfig(strategy="staged",
                                              backend="ref"))
    assert plan.config.layout == "depth_grouped"
    lowered = plan.describe()["lowered"]
    assert lowered["groups"] == {1: 1, 2: 1, 3: 1}
    x = jnp.asarray([[-0.5], [0.5], [1.5], [2.5]], jnp.float32)
    want = np.asarray(ref.fused_predict(
        x, ens.borders, ens.split_features, ens.split_bins,
        ens.leaf_values))
    np.testing.assert_allclose(np.asarray(plan.raw(x)), want,
                               rtol=1e-6, atol=1e-6)
    # hand check: x=2.5 crosses every border -> tree0 leaf 7,
    # tree1 leaf 1, tree2 leaf 3
    np.testing.assert_allclose(np.asarray(plan.raw(x))[3, 0],
                               7.0 + 20.0 + 4.0, rtol=1e-6)


def test_catboost_json_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"oblivious_trees": []}))
    with pytest.raises(ValueError):
        load_catboost_json(bad)
    # border value that is not among the feature's borders
    model = json.loads(_catboost_json(tmp_path).read_text())
    model["oblivious_trees"][0]["splits"][0]["border"] = 0.33
    bad.write_text(json.dumps(model))
    with pytest.raises(ValueError, match="border"):
        load_catboost_json(bad)


# --------------------------------------------------------------------------
# Ensemble concat/slice validation
# --------------------------------------------------------------------------
def test_concat_validates_compatibility():
    a = _rand_ensemble(seed=1)
    ok = concat_ensembles(a, a)           # same borders: fine
    assert ok.n_trees == 2 * a.n_trees
    with pytest.raises(ValueError, match="depth"):
        concat_ensembles(a, _rand_ensemble(seed=1, depth=3))
    with pytest.raises(ValueError, match="n_outputs"):
        concat_ensembles(a, _rand_ensemble(seed=1, n_outputs=5))
    with pytest.raises(ValueError, match="border"):
        concat_ensembles(a, _rand_ensemble(seed=2, n_borders=7))
    with pytest.raises(ValueError, match="border"):
        concat_ensembles(a, _rand_ensemble(seed=99))  # same shape, new vals


def test_slice_trees_validates_range():
    a = _rand_ensemble()
    assert a.slice_trees(0, 5).n_trees == 5
    for start, stop in ((-1, 4), (4, 2), (0, a.n_trees + 1)):
        with pytest.raises(ValueError):
            a.slice_trees(start, stop)
