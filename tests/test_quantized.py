"""Quantized-first evaluation API + kernel registry: pool-vs-float
parity across backends, uint8 bin-range edges, schema-fingerprint
safety, zero-binarize accounting, border-computation edge cases, and
registry introspection."""
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import predict, quantize
from repro.core.predictor import PredictConfig, Predictor
from repro.core.quantize import QuantizedPool, quantize_pool
from repro.core.trees import ObliviousEnsemble
from repro.kernels import ops, ref, registry


def _rand_ensemble(seed=3, n_trees=13, depth=4, n_features=11,
                   n_borders=9, n_outputs=2, borders=None):
    rng = np.random.default_rng(seed)
    if borders is None:
        borders = jnp.asarray(
            np.sort(rng.normal(size=(n_borders, n_features)), 0)
            .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, n_features,
                                  (n_trees, depth)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, n_borders,
                                  (n_trees, depth)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(n_trees, 2 ** depth, n_outputs))
                     .astype(np.float32))
    return ObliviousEnsemble(sf, sb, lv, borders,
                             jnp.full((n_features,), n_borders, jnp.int32))


def _rand_x(ens, n=37, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, ens.n_features))
                       .astype(np.float32))


# --------------------------------------------------------------------------
# compute_borders edge cases (satellite)
# --------------------------------------------------------------------------
def test_compute_borders_validates_max_bins():
    x = np.random.default_rng(0).normal(size=(20, 3)).astype(np.float32)
    with pytest.raises(ValueError, match="max_bins"):
        quantize.compute_borders(x, max_bins=257)
    with pytest.raises(ValueError, match="max_bins"):
        quantize.compute_borders(x, max_bins=1)
    borders, counts = quantize.compute_borders(x, max_bins=256)
    assert borders.shape == (255, 3)
    assert int(counts.max()) <= 255


def test_compute_borders_constant_and_nan_columns():
    rng = np.random.default_rng(1)
    x = np.stack([
        rng.normal(size=64),                  # normal column
        np.full(64, 2.5),                     # constant
        np.full(64, np.nan),                  # all-NaN
        np.full(64, np.inf),                  # all-inf (non-finite)
    ], axis=1).astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # the old path warned here
        borders, counts = quantize.compute_borders(x, max_bins=16)
    counts = np.asarray(counts)
    assert counts[0] > 0
    # constant / all-NaN / all-inf columns: no border separates anything
    assert counts[1] == counts[2] == counts[3] == 0
    b = np.asarray(borders)
    assert np.all(np.isinf(b[:, 1:]))
    assert borders.dtype == jnp.float32
    # borders never sit at the column max (x > border must be non-trivial)
    assert np.all(b[:counts[0], 0] < x[:, 0].max())


def test_binarize_matrix_shim_matches_registry_path():
    ens = _rand_ensemble()
    x = _rand_x(ens, 10)
    got = quantize.binarize_matrix(x, ens.borders)
    want = ops.binarize(x, ens.borders, backend="ref")
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# Quantized-vs-float parity across registry backends (satellite)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    PredictConfig(strategy="staged", backend="ref"),
    PredictConfig(strategy="staged", backend="pallas"),
    PredictConfig(strategy="fused", backend="ref"),
    PredictConfig(strategy="fused", backend="pallas"),
    PredictConfig(strategy="staged", backend="ref", tree_block=4),
    PredictConfig(strategy="staged", backend="pallas", tree_block=4),
])
def test_pool_matches_float_path(cfg):
    ens = _rand_ensemble()
    x = _rand_x(ens, 37)
    plan = Predictor.build(ens, cfg, expected_batch=37)
    pool = plan.quantize(x)
    assert pool.bins.dtype == jnp.uint8
    assert pool.bins.shape == (37, ens.n_features)
    np.testing.assert_allclose(np.asarray(plan.raw(x)),
                               np.asarray(plan.raw(pool)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(plan.proba(x)),
                               np.asarray(plan.proba(pool)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(plan.classify(x)),
                                  np.asarray(plan.classify(pool)))


def test_pool_parity_across_backends():
    # One pool, every backend/strategy: all must agree with the oracle.
    ens = _rand_ensemble(seed=11)
    x = _rand_x(ens, 20)
    want = np.asarray(ref.fused_predict(x, ens.borders, ens.split_features,
                                        ens.split_bins, ens.leaf_values))
    pool = quantize_pool(x, ens.borders, backend="ref")
    for cfg in (PredictConfig(strategy="staged", backend="ref"),
                PredictConfig(strategy="staged", backend="pallas"),
                PredictConfig(strategy="fused", backend="pallas")):
        plan = Predictor.build(ens, cfg, expected_batch=20)
        got = np.asarray(plan.raw(pool))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_legacy_kwarg_path_accepts_pool():
    ens = _rand_ensemble()
    x = _rand_x(ens, 16)
    plan = Predictor.build(ens, strategy="staged", backend="ref")
    pool = plan.quantize(x)
    np.testing.assert_allclose(
        np.asarray(predict.raw_predict(ens, pool, strategy="staged",
                                       backend="ref")),
        np.asarray(plan.raw(x)), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# uint8 bin-range edge: 255 borders, last bin id 255 (satellite)
# --------------------------------------------------------------------------
def test_bin_id_255_last_border_edge():
    rng = np.random.default_rng(5)
    F = 3
    borders = jnp.asarray(np.sort(rng.normal(size=(255, F)), 0)
                          .astype(np.float32))
    # rows below the lowest border, between, and above the highest
    x = jnp.asarray(np.stack([
        np.asarray(borders[0]) - 1.0,
        np.asarray(borders[127]),
        np.asarray(borders[254]) + 1.0,
    ]).astype(np.float32))
    b_i32 = np.asarray(ops.binarize(x, borders, backend="ref"))
    for backend in ("ref", "pallas"):
        b_u8 = np.asarray(ops.binarize_u8(x, borders, backend=backend))
        assert b_u8.dtype == np.uint8
        np.testing.assert_array_equal(b_u8.astype(np.int32), b_i32)
    assert b_i32.max() == 255          # the uint8 ceiling, exactly
    assert b_i32.min() == 0
    # leaf_index over u8 bins must agree with the int32 stream even when
    # split_bins reference the last border (id 255)
    sf = jnp.asarray(np.array([[0, 1], [2, 2]], np.int32))
    sb = jnp.asarray(np.array([[255, 128], [1, 255]], np.int32))
    want = np.asarray(ref.leaf_index(jnp.asarray(b_i32), sf, sb))
    for backend in ("ref", "pallas"):
        got = np.asarray(ops.leaf_index(jnp.asarray(b_u8), sf, sb,
                                        backend=backend))
        np.testing.assert_array_equal(got, want)


def test_u8_binarize_rejects_too_many_borders():
    rng = np.random.default_rng(6)
    borders = jnp.asarray(np.sort(rng.normal(size=(256, 2)), 0)
                          .astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
    for backend in ("ref", "pallas"):
        with pytest.raises(ValueError, match="255"):
            ops.binarize_u8(x, borders, backend=backend)
    with pytest.raises(ValueError, match="255"):
        quantize_pool(x, borders)


# --------------------------------------------------------------------------
# Schema fingerprint (satellite)
# --------------------------------------------------------------------------
def test_pool_fingerprint_mismatch_raises():
    ens_a = _rand_ensemble(seed=1)
    ens_b = _rand_ensemble(seed=1,
                           borders=ens_a.borders + np.float32(0.25))
    plan_a = Predictor.build(ens_a, strategy="staged", backend="ref")
    plan_b = Predictor.build(ens_b, strategy="staged", backend="ref")
    pool = plan_a.quantize(_rand_x(ens_a, 8))
    with pytest.raises(ValueError, match="schema mismatch"):
        plan_b.raw(pool)
    with pytest.raises(ValueError, match="schema mismatch"):
        plan_b.raw_uncached(pool)
    # same borders -> shareable pool, regardless of plan config
    plan_a2 = Predictor.build(ens_a, strategy="fused", backend="pallas",
                              expected_batch=8)
    np.testing.assert_allclose(np.asarray(plan_a2.raw(pool)),
                               np.asarray(plan_a.raw(pool)),
                               rtol=1e-5, atol=1e-5)


def test_pool_validates_shape_and_dtype():
    with pytest.raises(ValueError, match="uint8"):
        QuantizedPool(jnp.zeros((4, 3), jnp.int32), "abc")
    with pytest.raises(ValueError, match="N, F"):
        QuantizedPool(jnp.zeros((4,), jnp.uint8), "abc")


def test_pool_slice_and_pad_rows():
    ens = _rand_ensemble()
    plan = Predictor.build(ens, strategy="staged", backend="ref")
    pool = plan.quantize(_rand_x(ens, 10))
    part = pool.slice_rows(2, 7)
    assert len(part) == 5 and part.fingerprint == pool.fingerprint
    padded = part.pad_rows(8)
    assert len(padded) == 8
    assert np.all(np.asarray(padded.bins)[5:] == 0)     # bin-0 pad rows
    with pytest.raises(ValueError, match="pad"):
        padded.pad_rows(4)
    np.testing.assert_allclose(
        np.asarray(plan.raw(padded))[:5],
        np.asarray(plan.raw(pool))[2:7], rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# Zero-binarize accounting on the pool path (acceptance criterion)
# --------------------------------------------------------------------------
def test_zero_binarize_dispatches_when_scoring_pool():
    ens = _rand_ensemble()
    x = _rand_x(ens, 16)
    plan = Predictor.build(ens, strategy="staged", backend="ref")
    pool = plan.quantize(x)
    registry.reset_call_stats()
    for _ in range(3):
        plan.raw(pool)
    stats = registry.call_stats()
    assert stats.get("binarize", 0) == 0, stats
    assert stats.get("leaf_index", 0) >= 1       # the pool path did run
    # the float path, by contrast, dispatches binarize
    plan.raw(x)
    assert registry.call_stats().get("binarize", 0) >= 1


# --------------------------------------------------------------------------
# Registry introspection + legacy shim (satellite / acceptance)
# --------------------------------------------------------------------------
def test_registry_lists_every_op_with_ref_and_pallas():
    rows = registry.table()
    by_op = {}
    for r in rows:
        by_op.setdefault(r["op"], set()).add(r["impl"])
    for op in registry.CORE_OPS:
        assert op in by_op, f"op {op} missing from registry"
        assert len(by_op[op]) >= 2, f"op {op} has < 2 implementations"
        assert {"ref", "pallas"} <= by_op[op]
    # u8 bin-stream variants exist where the dtype matters
    assert "pallas_u8" in by_op["leaf_index"]
    assert {"ref_u8", "pallas_u8"} <= by_op["binarize"]
    assert registry.known_backends() == ("pallas", "ref")
    # the rendered table carries one line per row plus a two-line header
    assert len(registry.format_table().splitlines()) == len(rows) + 2


def test_registry_resolve_and_errors():
    assert registry.resolve("binarize", "ref") == "ref"
    assert registry.resolve("binarize", "ref", dtype="uint8") == "ref_u8"
    assert registry.resolve("binarize", "auto") in ("ref", "pallas")
    assert registry.resolve("leaf_index", "ref", dtype="uint8") == "ref"
    with pytest.raises(KeyError, match="no implementation"):
        registry.resolve("binarize", "cuda")
    # histogram became a registered op (the training side); a truly
    # unknown op still raises
    assert registry.resolve("histogram", "ref") == "ref"
    with pytest.raises(KeyError, match="unknown kernel op"):
        registry.resolve("treeshap", "ref")
    with pytest.raises(ValueError, match="does not handle"):
        registry.resolve("leaf_gather", "pallas", dtype="uint8")
    with pytest.raises(ValueError):
        PredictConfig(backend="cuda")


def test_legacy_backend_kwarg_is_registry_shim():
    ens = _rand_ensemble()
    x = _rand_x(ens, 9)
    via_kwarg = ops.binarize(x, ens.borders, backend="pallas")
    via_registry = registry.dispatch("binarize", "pallas", x, ens.borders,
                                     block_n=256, block_f=128)
    np.testing.assert_array_equal(np.asarray(via_kwarg),
                                  np.asarray(via_registry))
    # exact impl names are valid backend values on the op shims
    u8 = ops.binarize(x, ens.borders, backend="ref_u8")
    assert u8.dtype == jnp.uint8


# --------------------------------------------------------------------------
# Serving: shared-quantizer path (tentpole integration)
# --------------------------------------------------------------------------
def test_registry_predict_multi_shares_quantizer():
    from repro.serving.engine import ModelRegistry
    ens_a = _rand_ensemble(seed=21, n_trees=8)
    ens_b = _rand_ensemble(seed=22, n_trees=6, borders=ens_a.borders)
    ens_c = _rand_ensemble(seed=23, n_trees=7)     # different schema
    reg = ModelRegistry(max_batch=32,
                        config=PredictConfig(strategy="staged",
                                             backend="ref"))
    try:
        reg.register("a", ens_a)
        reg.register("b", ens_b)
        reg.register("c", ens_c)
        assert reg.get("a").schema_fingerprint == \
            reg.get("b").schema_fingerprint
        assert reg.get("a").schema_fingerprint != \
            reg.get("c").schema_fingerprint
        xs = np.asarray(_rand_x(ens_a, 50))
        registry.reset_call_stats()
        multi = reg.predict_multi(xs)
        # 2 schemas -> exactly 2 binarize dispatches for 3 models
        assert registry.call_stats().get("binarize", 0) == 2
        for name in ("a", "b", "c"):
            np.testing.assert_allclose(multi[name],
                                       reg.predict_batch(name, xs),
                                       rtol=1e-5, atol=1e-6)
    finally:
        reg.close()


def test_server_predict_pool_bucketed():
    from repro.serving.engine import GBDTServer
    ens = _rand_ensemble(seed=31)
    server = GBDTServer(ens, config=PredictConfig(strategy="staged",
                                                  backend="ref"),
                        max_batch=16, min_bucket=4)
    try:
        xs = np.asarray(_rand_x(ens, 41))          # forces chunking
        pool = server.quantize(xs)
        np.testing.assert_allclose(server.predict_pool(pool),
                                   server.predict_batch(xs),
                                   rtol=1e-5, atol=1e-6)
        # retraces stay bounded: every chunk was padded to a bucket
        shapes = {s for s in server.predictor.stats["entry_shapes"]
                  if s[0] == "proba_pool"}
        assert all(s[1] in server.buckets for s in shapes), shapes
    finally:
        server.close()
