"""Block-shape sweeps: the Pallas kernels must be exact under every
candidate BlockSpec tiling (the LMUL-analog tuning knob), and the VMEM
footprint model must keep every candidate under budget."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tuning


@pytest.mark.parametrize("block_n,block_f", [(8, 128), (128, 128),
                                             (512, 256)])
def test_binarize_blocks(block_n, block_f):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(300, 60)).astype(np.float32))
    borders = jnp.asarray(np.sort(rng.normal(size=(31, 60)), 0)
                          .astype(np.float32))
    got = ops.binarize(x, borders, backend="pallas", block_n=block_n,
                       block_f=block_f)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.binarize(x, borders)))


@pytest.mark.parametrize("block_n,block_t", [(8, 8), (128, 32), (256, 64)])
def test_leaf_index_blocks(block_n, block_t):
    rng = np.random.default_rng(1)
    bins = jnp.asarray(rng.integers(0, 32, (200, 40)).astype(np.int32))
    sf = jnp.asarray(rng.integers(0, 40, (70, 6)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, 32, (70, 6)).astype(np.int32))
    got = ops.leaf_index(bins, sf, sb, backend="pallas", block_n=block_n,
                         block_t=block_t)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.leaf_index(bins, sf, sb)))


@pytest.mark.parametrize("block_n,block_t", [(64, 8), (128, 16), (256, 32)])
def test_fused_blocks(block_n, block_t):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(150, 30)).astype(np.float32))
    borders = jnp.asarray(np.sort(rng.normal(size=(15, 30)), 0)
                          .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, 30, (50, 5)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, 15, (50, 5)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(50, 32, 3)).astype(np.float32))
    got = ops.fused_predict(x, borders, sf, sb, lv, backend="pallas",
                            block_n=block_n, block_t=block_t)
    want = ref.fused_predict(x, borders, sf, sb, lv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_footprints_under_budget():
    cands = tuning.candidates_fused(F=200, D=8, L=256, C=7, n_borders=255)
    assert cands, "no candidate fits VMEM"
    for c in cands:
        assert c.footprint <= tuning.VMEM_BUDGET
    bn, bt = tuning.best_fused_blocks(200, 8, 256, 7, 255)
    assert bn >= 64 and bt >= 8


def test_footprint_model_counts_all_tiles():
    # covertype-scale: 54 features, depth 8 -> fused tile must include the
    # (bn, bt*L) one-hot; verify the model scales as expected
    small = tuning.fused_footprint(128, 8, 54, 8, 256, 7, 255)
    big = tuning.fused_footprint(128, 64, 54, 8, 256, 7, 255)
    assert big > small * 4      # one-hot term dominates, linear in bt