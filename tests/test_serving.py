"""Serving path: bucket selection, bounded recompiles, fused parity on
unpadded ensembles, GBDTServer end-to-end, model registry."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import boosting, losses, predict
from repro.core.boosting import BoostingParams
from repro.core.predictor import PredictConfig
from repro.data import synthetic
from repro.kernels import ops, ref, tuning
from repro.serving import batching
from repro.serving.engine import GBDTServer, ModelRegistry


# --------------------------------------------------------------------------
# Bucket utilities
# --------------------------------------------------------------------------
def test_pow2_buckets_cover_max_batch():
    assert batching.pow2_buckets(256) == (16, 32, 64, 128, 256)
    assert batching.pow2_buckets(100) == (16, 32, 64, 128)
    assert batching.pow2_buckets(1, min_bucket=4) == (4,)
    assert batching.pow2_buckets(5, min_bucket=1) == (1, 2, 4, 8)


def test_bucket_for_picks_smallest_fit():
    buckets = (16, 64, 256)
    assert batching.bucket_for(1, buckets) == 16
    assert batching.bucket_for(16, buckets) == 16
    assert batching.bucket_for(17, buckets) == 64
    assert batching.bucket_for(256, buckets) == 256
    with pytest.raises(ValueError):
        batching.bucket_for(257, buckets)
    with pytest.raises(ValueError):
        batching.bucket_for(0, buckets)


def test_pad_rows():
    xs = np.arange(6, dtype=np.float32).reshape(3, 2)
    padded = batching.pad_rows(xs, 8)
    assert padded.shape == (8, 2)
    np.testing.assert_array_equal(padded[:3], xs)
    np.testing.assert_array_equal(padded[3:], 0.0)
    assert batching.pad_rows(xs, 3) is xs
    with pytest.raises(ValueError):
        batching.pad_rows(xs, 2)


def test_bucketed_batcher_pads_and_unpads():
    seen_shapes = []

    def serve(xs):
        seen_shapes.append(xs.shape[0])
        return xs.sum(axis=1)

    b = batching.BucketedBatcher(serve, max_batch=32, buckets=(8, 32))
    try:
        xs = np.ones((5, 3), np.float32)
        ys = b._run_batch(xs)
        assert ys.shape == (5,)                 # padding sliced off
        assert seen_shapes == [8]               # serve saw the bucket size
        assert b.bucket_counts[8] == 1
        b._run_batch(np.ones((9, 3), np.float32))
        assert seen_shapes == [8, 32]
    finally:
        b.close()


def test_bucketed_batcher_rejects_undersized_buckets():
    with pytest.raises(ValueError):
        batching.BucketedBatcher(lambda x: x, max_batch=64, buckets=(8, 16))


# --------------------------------------------------------------------------
# Workload-aware block tuning
# --------------------------------------------------------------------------
def test_tuner_penalizes_padding_waste():
    # A 100-row workload must not be handed a 1024-row block.
    bn, bt = tuning.best_fused_blocks(54, 6, 64, 7, 255, n_rows=100,
                                      n_trees=40)
    assert bn <= 128
    assert bt <= 64
    # Without workload shape the original (unpenalized) choice stands.
    cands = tuning.candidates_fused(200, 8, 256, 7, 255)
    assert cands[0].score >= cands[-1].score


def test_ops_fused_autotunes_blocks():
    # No explicit blocks: ops picks them from the tuner; result must match
    # the reference on an oddly-shaped (unpadded) problem.
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(37, 11)).astype(np.float32))
    borders = jnp.asarray(np.sort(rng.normal(size=(9, 11)), 0)
                          .astype(np.float32))
    sf = jnp.asarray(rng.integers(0, 11, (13, 4)).astype(np.int32))
    sb = jnp.asarray(rng.integers(1, 9, (13, 4)).astype(np.int32))
    lv = jnp.asarray(rng.normal(size=(13, 16, 2)).astype(np.float32))
    got = ops.fused_predict(x, borders, sf, sb, lv, backend="pallas")
    want = ref.fused_predict(x, borders, sf, sb, lv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------------
# Server end-to-end
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cov_model():
    ds = synthetic.load("covertype", scale=0.003)
    loss = losses.make_loss("multiclass", n_classes=7)
    ens, _ = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                          params=BoostingParams(n_trees=25, depth=5,
                                                learning_rate=0.3))
    return ens, ds


def test_server_recompiles_bounded_by_buckets(cov_model):
    ens, ds = cov_model
    server = GBDTServer(ens, strategy="fused", backend="ref",
                        max_batch=64, buckets=(16, 64))
    try:
        for n in (3, 5, 9, 16, 17, 33, 50, 64, 2, 40):
            out = server.predict_batch(ds.x_test[:n])
            assert out.shape == (n, 7)
        snap = server.metrics.snapshot()
        assert snap["recompiles"] <= len(server.buckets), snap
        assert snap["batches"] == 10
        assert snap["requests"] == 3 + 5 + 9 + 16 + 17 + 33 + 50 + 64 + 2 + 40
    finally:
        server.close()


def test_server_fused_matches_staged_on_unpadded_ensemble(cov_model):
    # 25 trees of depth 5 / 54 features: nothing divides the kernel's
    # block multiples — the padding layer must make fused == staged.
    ens, ds = cov_model
    fused = GBDTServer(ens, strategy="fused", backend="ref", max_batch=64)
    staged = GBDTServer(ens, strategy="staged", backend="ref", max_batch=64)
    try:
        xs = ds.x_test[:100]
        np.testing.assert_allclose(fused.predict_batch(xs),
                                   staged.predict_batch(xs),
                                   rtol=1e-5, atol=1e-5)
    finally:
        fused.close()
        staged.close()


def test_server_fused_interpret_end_to_end():
    # Tiny model so Pallas interpret mode stays fast: full online path
    # (batcher thread -> bucket pad -> fused Pallas kernel -> unpad).
    rng = np.random.default_rng(0)
    x = rng.normal(size=(300, 6)).astype(np.float32)
    y = (x[:, 0] + 0.5 * x[:, 1] > 0).astype(np.float32)
    loss = losses.make_loss("logloss")
    ens, _ = boosting.fit(x, y, loss=loss,
                          params=BoostingParams(n_trees=8, depth=2,
                                                learning_rate=0.3))
    server = GBDTServer(ens, strategy="fused", backend="pallas",
                        max_batch=8, buckets=(8,), max_wait_ms=5.0)
    try:
        proba = server.predict(x[0])
        assert proba.shape == (2,)
        assert np.isfinite(proba).all()
        want = np.asarray(predict.predict_proba(
            ens, jnp.asarray(x[:1]), strategy="staged", backend="ref"))[0]
        np.testing.assert_allclose(proba, want, rtol=1e-5, atol=1e-5)
    finally:
        server.close()


def test_server_online_batcher_parity(cov_model):
    ens, ds = cov_model
    server = GBDTServer(ens, strategy="staged", backend="ref",
                        max_batch=32, max_wait_ms=1.0)
    try:
        got = server.predict(ds.x_test[0])
        want = np.asarray(predict.predict_proba(
            ens, jnp.asarray(ds.x_test[:1]), strategy="staged",
            backend="ref"))[0]
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    finally:
        server.close()


def test_predict_batch_chunks_oversized_input(cov_model):
    ens, ds = cov_model
    server = GBDTServer(ens, strategy="staged", backend="ref",
                        max_batch=16, buckets=(16,))
    try:
        out = server.predict_batch(ds.x_test[:40])   # 3 chunks: 16/16/8
        assert out.shape == (40, 7)
        assert server.metrics.snapshot()["batches"] == 3
        want = np.asarray(predict.predict_proba(
            ens, jnp.asarray(ds.x_test[:40]), strategy="staged",
            backend="ref"))
        np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)
    finally:
        server.close()


def test_server_accepts_predict_config(cov_model):
    # The compiled-plan path: one PredictConfig in, a resolved plan out,
    # no kwarg threading.
    ens, ds = cov_model
    server = GBDTServer(ens, config=PredictConfig(strategy="fused",
                                                  backend="ref"),
                        max_batch=32)
    try:
        assert server.config.is_resolved
        assert server.config.strategy == "fused"
        out = server.predict_batch(ds.x_test[:20])
        want = np.asarray(predict.predict_proba(
            ens, jnp.asarray(ds.x_test[:20]), strategy="staged",
            backend="ref"))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
        # server recompile metrics are fed by the plan's trace counter
        assert server.predictor.stats["total_traces"] == \
            server.metrics.snapshot()["recompiles"]
    finally:
        server.close()


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
def test_registry_serves_multiple_models(cov_model):
    ens, ds = cov_model
    reg = ModelRegistry(backend="ref", max_batch=32)
    try:
        reg.register("staged", ens, strategy="staged")
        reg.register("fused", ens, strategy="fused")
        assert reg.names() == ["fused", "staged"]
        a = reg.predict_batch("staged", ds.x_test[:20])
        b = reg.predict_batch("fused", ds.x_test[:20])
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
        m = reg.metrics()
        assert m["staged"]["requests"] == 20
        assert m["fused"]["requests"] == 20
        with pytest.raises(KeyError):
            reg.register("fused", ens)
        with pytest.raises(KeyError):
            reg.get("nope")
        reg.unregister("staged")
        assert reg.names() == ["fused"]
    finally:
        reg.close()


def test_registry_swap_builds_fresh_plan(cov_model):
    # Predictor plans are immutable: swapping the ensemble under a name
    # must discard the old server and its plan caches wholesale.
    ens, ds = cov_model
    reg = ModelRegistry(backend="ref", max_batch=32)
    try:
        old = reg.register("m", ens)
        old_plan = old.predictor
        reg.predict_batch("m", ds.x_test[:4])
        new = reg.register("m", ens, replace=True)
        assert new is not old
        assert new.predictor is not old_plan
        assert new.predictor.stats["total_traces"] == 0
    finally:
        reg.close()
