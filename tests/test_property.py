"""Property-based tests (hypothesis) on the system's invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this image")
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels import ops, ref

COMMON = dict(deadline=None, max_examples=20,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(n=st.integers(1, 80), f=st.integers(1, 40), b=st.integers(1, 20),
       seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_binarize_counts_borders(n, f, b, seed):
    """bins == #borders strictly below the value, for any data."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    borders = np.sort(rng.normal(size=(b, f)).astype(np.float32), axis=0)
    got = np.asarray(ref.binarize(jnp.asarray(x), jnp.asarray(borders)))
    want = (x[:, None, :] > borders[None, :, :]).sum(1)
    np.testing.assert_array_equal(got, want)
    assert got.min() >= 0 and got.max() <= b


@given(n=st.integers(1, 60), f=st.integers(2, 30), t=st.integers(1, 25),
       d=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_leaf_index_in_range(n, f, t, d, seed):
    rng = np.random.default_rng(seed)
    bins = rng.integers(0, 32, (n, f)).astype(np.int32)
    sf = rng.integers(0, f, (t, d)).astype(np.int32)
    sb = rng.integers(0, 33, (t, d)).astype(np.int32)
    idx = np.asarray(ref.leaf_index(jnp.asarray(bins), jnp.asarray(sf),
                                    jnp.asarray(sb)))
    assert idx.min() >= 0 and idx.max() < 2 ** d


@given(n=st.integers(1, 40), t=st.integers(1, 20), d=st.integers(1, 6),
       c=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_predict_invariant_under_tree_permutation(n, t, d, c, seed):
    """Summing over trees is order-independent."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, 2 ** d, (n, t)).astype(np.int32)
    lv = rng.normal(size=(t, 2 ** d, c)).astype(np.float32)
    perm = rng.permutation(t)
    a = np.asarray(ref.leaf_gather(jnp.asarray(idx), jnp.asarray(lv)))
    b = np.asarray(ref.leaf_gather(jnp.asarray(idx[:, perm]),
                                   jnp.asarray(lv[perm])))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@given(m=st.integers(1, 20), n=st.integers(1, 20), k=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_l2_matrix_properties(m, n, k, seed):
    """Non-negativity, zero self-distance, symmetry."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(n, k)).astype(np.float32)
    d_ab = np.asarray(ref.l2sq_matrix(jnp.asarray(a), jnp.asarray(b)))
    d_ba = np.asarray(ref.l2sq_matrix(jnp.asarray(b), jnp.asarray(a)))
    assert d_ab.min() >= 0
    np.testing.assert_allclose(d_ab, d_ba.T, rtol=1e-4, atol=1e-4)
    d_aa = np.asarray(ref.l2sq_matrix(jnp.asarray(a), jnp.asarray(a)))
    np.testing.assert_allclose(np.diag(d_aa), 0.0, atol=1e-3)


@given(n=st.integers(2, 50), seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_monotone_feature_shifts_bins_monotonically(n, seed):
    """Raising a feature value never lowers its bin (monotonicity)."""
    rng = np.random.default_rng(seed)
    x = np.sort(rng.normal(size=(n, 1)).astype(np.float32), axis=0)
    borders = np.sort(rng.normal(size=(10, 1)).astype(np.float32), axis=0)
    bins = np.asarray(ref.binarize(jnp.asarray(x), jnp.asarray(borders)))
    assert np.all(np.diff(bins[:, 0]) >= 0)


@given(n=st.integers(1, 100), cols=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_pack_unpack_bits_identity(n, cols, seed):
    """pack_bits -> unpack_bits is the identity for any (docs, depth)
    shape, including ragged tails where docs % 32 != 0."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n, cols)).astype(bool)
    words = ref.pack_bits(jnp.asarray(bits))
    assert words.dtype == jnp.uint32
    assert words.shape == (-(-n // 32), cols)
    back = np.asarray(ref.unpack_bits(words, n))
    np.testing.assert_array_equal(back, bits.astype(np.int32))


@given(n=st.integers(1, 70), f=st.integers(2, 20), t=st.integers(1, 12),
       d=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_bitpacked_leaf_index_matches_soa(n, f, t, d, seed):
    """For any valid ensemble, the bitpacked index assembly (shift/or
    on integer registers) equals the soa oracle — both directly and
    when each depth plane round-trips through uint32 lane words."""
    rng = np.random.default_rng(seed)
    bins = jnp.asarray(rng.integers(0, 64, (n, f)).astype(np.int32))
    sf = jnp.asarray(rng.integers(0, f, (t, d)).astype(np.int32))
    sb = jnp.asarray(rng.integers(0, 65, (t, d)).astype(np.int32))
    want = np.asarray(ref.leaf_index(bins, sf, sb))
    sf_bp, sb_bp = jnp.transpose(sf), jnp.transpose(sb)
    got = np.asarray(ref.leaf_index_bitpacked(bins, sf_bp, sb_bp))
    np.testing.assert_array_equal(got, want)
    via = np.asarray(ref.leaf_index_bitpacked(bins, sf_bp, sb_bp,
                                              via_words=True))
    np.testing.assert_array_equal(via, want)


@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 6))
@settings(**COMMON)
def test_padded_trees_are_noops(seed, d):
    """ops padding contract: PAD split_bin trees contribute leaf 0 and
    zero leaf values, so padding never changes predictions."""
    rng = np.random.default_rng(seed)
    n, f, t = 30, 8, 5
    x = rng.normal(size=(n, f)).astype(np.float32)
    borders = np.sort(rng.normal(size=(16, f)).astype(np.float32), 0)
    sf = rng.integers(0, f, (t, d)).astype(np.int32)
    sb = rng.integers(1, 16, (t, d)).astype(np.int32)
    lv = rng.normal(size=(t, 2 ** d, 3)).astype(np.float32)
    base = np.asarray(ref.fused_predict(
        jnp.asarray(x), jnp.asarray(borders), jnp.asarray(sf),
        jnp.asarray(sb), jnp.asarray(lv)))
    # pad with 3 inert trees
    sf2 = np.concatenate([sf, np.zeros((3, d), np.int32)])
    sb2 = np.concatenate([sb, np.full((3, d), ops.PAD_SPLIT_BIN, np.int32)])
    lv2 = np.concatenate([lv, np.zeros((3, 2 ** d, 3), np.float32)])
    padded = np.asarray(ref.fused_predict(
        jnp.asarray(x), jnp.asarray(borders), jnp.asarray(sf2),
        jnp.asarray(sb2), jnp.asarray(lv2)))
    np.testing.assert_allclose(base, padded, rtol=1e-6, atol=1e-6)
