"""Differential kernel-testing harness.

Every (op x impl x layout x bin-dtype) cell of the kernel registry's
capability table is enumerated AT COLLECTION TIME from
`registry.table()` — not hand-listed — and asserted against the
pure-jnp ref oracle on randomized ensembles/batches.  A newly
registered implementation (or a new layout/dtype claim on an existing
one) is covered here with zero new test code.

Two scenarios fold in the classic edge cases:
  mixed   mixed true depths including a depth-0 tree, NaN features,
          batch size not divisible by the 32-doc lane width
  edge    255 borders (bin ids at the 0/255 uint8 edges), T=1,
          batch=1, feature values below every border / above every
          border / NaN
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import layout as layout_mod
from repro.core import trees
from repro.core.trees import ObliviousEnsemble
from repro.kernels import histogram as hist_k
from repro.kernels import ops, ref, registry

# One pytest param per capability-table cell.  New registrations expand
# this list automatically at collection time.
CELLS = [
    pytest.param(row["op"], row["impl"], lay, dt,
                 id=f"{row['op']}-{row['impl']}-{lay}-{dt}")
    for row in registry.table()
    for lay in row["layouts"].split("/")
    for dt in row["dtypes"].split("/")
]

INT_DTYPES = ("int32", "uint8")


def _scenario(name):
    """Build (ensemble, x) for one named scenario."""
    if name == "mixed":
        rng = np.random.default_rng(11)
        n, f, b, t, d, c = 21, 7, 9, 6, 4, 2
        x = rng.normal(size=(n, f)).astype(np.float32)
        x[rng.random((n, f)) < 0.08] = np.nan
        borders = np.sort(rng.normal(size=(b, f)), 0).astype(np.float32)
        sf = rng.integers(0, f, (t, d)).astype(np.int32)
        sb = rng.integers(1, b + 1, (t, d)).astype(np.int32)
        lv = rng.normal(size=(t, 1 << d, c)).astype(np.float32)
        ens = ObliviousEnsemble(jnp.asarray(sf), jnp.asarray(sb),
                                jnp.asarray(lv), jnp.asarray(borders),
                                jnp.full((f,), b, jnp.int32))
        ens = trees.truncate_tree_depths(ens,
                                         np.array([0, 1, 2, 4, 3, 4]))
    else:  # "edge"
        rng = np.random.default_rng(23)
        f, b, t, d, c = 3, 255, 1, 2, 1
        borders = np.sort(rng.normal(size=(b, f)), 0).astype(np.float32)
        # one row: below every border (bin 0), above every border
        # (bin 255 — the uint8 ceiling), NaN (bin 0 by contract)
        x = np.array([[borders[0, 0] - 1.0, borders[-1, 1] + 1.0,
                       np.nan]], np.float32)
        sf = np.array([[1, 0]], np.int32)
        sb = np.array([[255, 1]], np.int32)
        lv = rng.normal(size=(t, 1 << d, c)).astype(np.float32)
        ens = ObliviousEnsemble(jnp.asarray(sf), jnp.asarray(sb),
                                jnp.asarray(lv), jnp.asarray(borders),
                                jnp.full((f,), b, jnp.int32))
    return ens, jnp.asarray(x)


def _bins(x, borders, dtype):
    if dtype == "uint8":
        return ref.binarize_u8(x, borders)
    return ref.binarize(x, borders)


def _family(impl):
    return "pallas" if impl.startswith("pallas") else "ref"


def _assert_int_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64),
                                  np.asarray(want).astype(np.int64))


def _assert_close(got, want):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("scenario", ["mixed", "edge"])
@pytest.mark.parametrize("op,impl,lay,dtype", CELLS)
def test_cell_matches_ref_oracle(op, impl, lay, dtype, scenario):
    ens, x = _scenario(scenario)
    sf, sb, lv = ens.split_features, ens.split_bins, ens.leaf_values
    borders = ens.borders
    fn = registry.get(op, impl).fn

    if op == "binarize":
        got = fn(x, borders)
        _assert_int_equal(got, _bins(x, borders, dtype))
        return

    if op == "l2sq":
        a = jnp.nan_to_num(x)
        _assert_close(fn(a, borders), ref.l2sq_matrix(a, borders))
        _assert_close(fn(a[0], borders), ref.l2sq_rowwise(a[0], borders))
        return

    bins = _bins(x, borders, dtype if dtype in INT_DTYPES else "int32")
    want_idx = ref.leaf_index(bins, sf, sb)

    if op == "leaf_index":
        if lay in ("soa", "depth_grouped"):
            _assert_int_equal(fn(bins, sf, sb), want_idx)
        elif lay == "depth_major":
            low = layout_mod.lower(ens, "depth_major",
                                   backend=_family(impl))
            binsp = ops.pad_features(bins, low.onehot.shape[2])
            got = fn(binsp, low.onehot, low.split_bins_dm, low.pow2)
            _assert_int_equal(got[:, :ens.n_trees], want_idx)
            # padded trees must land in leaf 0
            _assert_int_equal(got[:, ens.n_trees:],
                              np.zeros_like(got[:, ens.n_trees:]))
        else:  # bitpacked: bit-exact vs the soa oracle, by contract
            got = fn(bins, jnp.transpose(sf), jnp.transpose(sb))
            _assert_int_equal(got, want_idx)
        return

    if op == "leaf_gather":
        _assert_close(fn(want_idx, lv), ref.leaf_gather(want_idx, lv))
        return

    if op == "histogram":
        # feature-major bins (the training stream), random leaf ids and
        # g/h stats vs the segment-sum oracle.  The mixed scenario's
        # NaN features land in bin 0 by contract; the edge scenario
        # covers bin ids at the 0/255 uint8 edges and batch=1.
        # n_leaves=1 is the single-leaf (depth-0 level) case.
        rng = np.random.default_rng(31)
        n = int(bins.shape[0])
        n_bins = int(borders.shape[0]) + 1
        bins_t = jnp.transpose(bins)
        for n_leaves in (1, 4):
            leaf = jnp.asarray(rng.integers(0, n_leaves, n)
                               .astype(np.int32))
            g = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
            got = fn(bins_t, leaf, g, n_bins=n_bins, n_leaves=n_leaves)
            want = hist_k.histogram_ref(bins_t, leaf, g, n_bins=n_bins,
                                        n_leaves=n_leaves)
            _assert_close(got, want)
        return

    assert op == "fused_predict", f"harness does not cover op {op!r}"
    want = ref.fused_predict(x, borders, sf, sb, lv)
    if lay in ("soa", "depth_grouped"):
        got = fn(x, borders, sf, sb, lv)
    elif lay == "depth_major":
        low = layout_mod.lower(ens, "depth_major", backend=_family(impl))
        got = fn(x, low.borders, low.onehot, low.split_bins_dm, low.pow2,
                 low.leaf_values)
    else:  # bitpacked
        got = fn(x, borders, jnp.transpose(sf), jnp.transpose(sb), lv)
    _assert_close(got, want)


def test_table_covers_every_core_op():
    """The harness is only exhaustive if the table is: every core op
    must contribute at least one cell, and the bitpacked layout must
    appear for both structure-consuming ops."""
    ops_seen = {c.values[0] for c in CELLS}
    assert set(registry.CORE_OPS) <= ops_seen
    bp = {(c.values[0]) for c in CELLS if c.values[2] == "bitpacked"}
    assert {"leaf_index", "fused_predict"} <= bp


def test_train_on_pool_matches_train_on_float():
    """The quantized-first trainer (uint8 pool, registered histogram
    kernels) must reproduce the seed float-path scan to the leaf-value
    level: identical split structure, identical leaf values, identical
    loss trajectory — and perform zero binarize dispatches while
    boosting."""
    from repro.core import boosting
    from repro.core.losses import make_loss

    rng = np.random.default_rng(5)
    x = rng.normal(size=(400, 6)).astype(np.float32)
    y = (x[:, 0] - 2.0 * x[:, 2] + 0.3 * rng.normal(size=400)
         ).astype(np.float32)
    loss = make_loss("rmse")
    params = boosting.BoostingParams(n_trees=8, depth=3, max_bins=16,
                                     seed=3)
    ens_f, hist_f = boosting.fit_scan(x, y, loss=loss, params=params)
    ens_p, hist_p = boosting.fit(x, y, loss=loss, params=params)

    _assert_int_equal(ens_p.split_features, ens_f.split_features)
    _assert_int_equal(ens_p.split_bins, ens_f.split_bins)
    np.testing.assert_allclose(np.asarray(ens_p.leaf_values),
                               np.asarray(ens_f.leaf_values),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(hist_p["train_loss"], hist_f["train_loss"],
                               rtol=0, atol=1e-6)
    assert hist_p["dispatch_delta"].get("binarize", 0) == 0
    assert hist_p["dispatch_delta"].get("histogram", 0) > 0


def test_histogram_additive_across_row_chunks():
    """Property: histograms are additive over row chunks — summing the
    per-chunk histograms equals the full-batch histogram (the invariant
    chunked/streamed training relies on)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64),
           f=st.integers(1, 5), n_bins=st.integers(1, 9),
           n_leaves=st.integers(1, 4), frac=st.floats(0.0, 1.0))
    def prop(seed, n, f, n_bins, n_leaves, frac):
        rng = np.random.default_rng(seed)
        bins_t = jnp.asarray(rng.integers(0, n_bins, (f, n))
                             .astype(np.int32))
        leaf = jnp.asarray(rng.integers(0, n_leaves, n).astype(np.int32))
        g = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        full = hist_k.histogram_ref(bins_t, leaf, g, n_bins=n_bins,
                                    n_leaves=n_leaves)
        k = int(round(frac * n))
        parts = sum(
            hist_k.histogram_ref(bins_t[:, lo:hi], leaf[lo:hi], g[lo:hi],
                                 n_bins=n_bins, n_leaves=n_leaves)
            for lo, hi in ((0, k), (k, n)) if hi > lo)
        _assert_close(parts, full)

    prop()
