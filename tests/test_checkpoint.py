"""Checkpoint/restart + fault-tolerance tests."""
import os
import pathlib
import shutil

import jax
import numpy as np
import pytest

from repro import configs
from repro.compat import make_mesh
from repro.data.pipeline import TokenSource
from repro.training.checkpoint import CheckpointManager
from repro.training.trainer import Trainer, TrainerConfig


def _tree():
    return {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray(3), "d": np.ones((4,), np.int32)}}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=False)
    cm.save(3, _tree())
    out = cm.restore()
    np.testing.assert_array_equal(out["a"], _tree()["a"])
    np.testing.assert_array_equal(out["b"]["d"], _tree()["b"]["d"])
    assert cm.latest() == 3


def test_atomicity_no_partial_checkpoint(tmp_path):
    """A leftover .tmp dir is never picked up as a checkpoint."""
    cm = CheckpointManager(tmp_path, async_save=False)
    cm.save(1, _tree())
    # simulate a crash mid-save of step 2
    tmp = pathlib.Path(tmp_path) / "step_000000002.tmp"
    tmp.mkdir()
    (tmp / "leaves.npz").write_bytes(b"garbage")
    assert cm.latest() == 1
    out = cm.restore()
    assert out["b"]["c"][()] == 3


def test_retention_prunes(tmp_path):
    cm = CheckpointManager(tmp_path, keep_last=2, async_save=False)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree())
    assert cm.all_steps() == [3, 4]


def test_async_save_waits(tmp_path):
    cm = CheckpointManager(tmp_path, async_save=True)
    cm.save(7, _tree())
    cm.wait()
    assert cm.latest() == 7


def test_trainer_crash_resume_end_to_end(tmp_path):
    cfg = configs.get("internvl2-1b", smoke=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    ts = TokenSource(cfg.vocab_size, 16, 2)

    def batches():
        s = 0
        while True:
            b = ts.next_batch(s)
            b["frontend_embeds"] = np.zeros(
                (2, cfg.frontend_seq, cfg.d_model), np.float32)
            yield b
            s += 1

    tcfg = TrainerConfig(total_steps=8, ckpt_every=3, peak_lr=1e-3)
    tr = Trainer(cfg, mesh, tmp_path, tcfg)
    tr.init_or_restore()
    with pytest.raises(RuntimeError, match="injected"):
        tr.train(batches(), fail_at=5)
    # restart from scratch objects — must resume from step 3's checkpoint
    tr2 = Trainer(cfg, mesh, tmp_path, tcfg)
    tr2.init_or_restore()
    assert tr2.step == 3
    hist = tr2.train(batches())
    assert tr2.step == 8
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_straggler_detection(tmp_path):
    """Artificially slow step is recorded as a straggler."""
    import time
    cfg = configs.get("glm4-9b", smoke=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    ts = TokenSource(cfg.vocab_size, 16, 2)
    tr = Trainer(cfg, mesh, tmp_path,
                 TrainerConfig(total_steps=6, ckpt_every=100,
                               straggler_factor=2.0))
    tr.init_or_restore()

    real_step = tr._jit_step
    calls = {"n": 0}

    def slow_step(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 6:            # injected straggler on step 6
            time.sleep(max(2.5 * 2.0 * (sum(tr.step_times) /
                                        max(len(tr.step_times), 1)), 0.2))
        return real_step(*a, **kw)

    tr._jit_step = slow_step

    def batches():
        s = 0
        while True:
            yield ts.next_batch(s)
            s += 1

    tr.train(batches())
    assert len(tr.step_times) == 6
    assert 5 in tr.straggler_steps, (tr.straggler_steps, tr.step_times)
