"""Quantized-first training subsystem (repro.training.gbdt).

Covers the contracts the trainer ships: checkpoint/resume finishing
bit-identically, zero binarize dispatches while boosting on a pool,
the <= depth histogram compiled-shape contract, the exact train->serve
round trip through Predictor/GBDTServer, streamed-source ingest
matching in-core pool training, and the TrainingMetrics snapshot.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import boosting, quantize
from repro.core.losses import make_loss
from repro.core.predictor import Predictor, proba_from_raw
from repro.kernels import registry
from repro.serving.engine import GBDTServer
from repro.training.checkpoint import CheckpointManager
from repro.training.gbdt import GBDTTrainer, TrainingMetrics, TrainState


def _data(n=300, f=6, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = (x[:, 0] - 2.0 * x[:, 1] + 0.2 * rng.normal(size=n)
         ).astype(np.float32)
    return x, y


def _pool_setup(x, max_bins=16):
    borders, n_borders = quantize.compute_borders(x, max_bins)
    pool = quantize.quantize_pool(jnp.asarray(x), borders)
    return pool, borders, n_borders


PARAMS = boosting.BoostingParams(n_trees=6, depth=3, max_bins=16, seed=1)


def test_resume_is_bit_identical(tmp_path):
    """Kill after k trees, resume from the checkpoint, finish with a
    bit-identical ensemble and loss trajectory (the PR-5 chunk-index
    resume contract, lifted to boosting iterations)."""
    x, y = _data()
    pool, borders, n_borders = _pool_setup(x)
    loss = make_loss("rmse")

    full_tr = GBDTTrainer(loss, PARAMS)
    ens_full, hist_full = full_tr.fit_pool(pool, y, borders=borders,
                                           n_borders=n_borders)

    # "killed" run: checkpoint every 2 trees, stop at 4 by training a
    # 4-tree variant (same seed => identical prefix)
    ck = CheckpointManager(tmp_path / "ck", async_save=False)
    killed = GBDTTrainer(
        loss, boosting.BoostingParams(n_trees=4, depth=3, max_bins=16,
                                      seed=1))
    killed.fit_pool(pool, y, borders=borders, n_borders=n_borders,
                    checkpoint=ck, checkpoint_every=2)
    assert ck.latest() == 4

    resumed_tr = GBDTTrainer(loss, PARAMS)
    ens_res, hist_res = resumed_tr.fit_pool(
        pool, y, borders=borders, n_borders=n_borders, checkpoint=ck,
        resume_from=-1)

    np.testing.assert_array_equal(np.asarray(ens_res.split_features),
                                  np.asarray(ens_full.split_features))
    np.testing.assert_array_equal(np.asarray(ens_res.split_bins),
                                  np.asarray(ens_full.split_bins))
    np.testing.assert_array_equal(np.asarray(ens_res.leaf_values),
                                  np.asarray(ens_full.leaf_values))
    np.testing.assert_array_equal(hist_res["train_loss"],
                                  hist_full["train_loss"])
    np.testing.assert_array_equal(hist_res["final_raw"],
                                  hist_full["final_raw"])


def test_resume_rejects_wrong_shape(tmp_path):
    x, y = _data()
    pool, borders, n_borders = _pool_setup(x)
    loss = make_loss("rmse")
    ck = CheckpointManager(tmp_path / "ck", async_save=False)
    GBDTTrainer(loss, PARAMS).fit_pool(pool, y, borders=borders,
                                       n_borders=n_borders,
                                       checkpoint=ck, checkpoint_every=6)
    x2, y2 = _data(n=120)
    pool2, borders2, n_borders2 = _pool_setup(x2)
    with pytest.raises(ValueError, match="does not match"):
        GBDTTrainer(loss, PARAMS).fit_pool(
            pool2, y2, borders=borders2, n_borders=n_borders2,
            checkpoint=ck, resume_from=-1)


def test_train_state_roundtrip():
    st = TrainState(iteration=3, key=np.array([1, 2], np.uint32),
                    split_features=np.zeros((3, 2), np.int32),
                    split_bins=np.ones((3, 2), np.int32),
                    leaf_values=np.zeros((3, 4, 1), np.float32),
                    raw=np.zeros((10, 1), np.float32),
                    train_loss=np.zeros((3,), np.float32))
    back = TrainState.from_tree(st.tree())
    assert back.iteration == 3
    np.testing.assert_array_equal(back.key, st.key)
    np.testing.assert_array_equal(back.leaf_values, st.leaf_values)


def test_pool_boosting_zero_binarize_dispatch():
    """The acceptance invariant: boosting on a QuantizedPool performs
    zero binarize dispatches, and histogram dispatches == depth (one
    trace per level) on a cold fit, 0 on a warmed refit."""
    x, y = _data(seed=3)
    pool, borders, n_borders = _pool_setup(x)
    loss = make_loss("rmse")

    _, hist = GBDTTrainer(loss, PARAMS).fit_pool(
        pool, y, borders=borders, n_borders=n_borders)
    delta = hist["dispatch_delta"]
    assert delta.get("binarize", 0) == 0
    assert delta.get("histogram", 0) <= PARAMS.depth
    # warmed refit: identical shapes => no new histogram traces
    _, hist2 = GBDTTrainer(loss, PARAMS).fit_pool(
        pool, y, borders=borders, n_borders=n_borders)
    assert hist2["dispatch_delta"].get("histogram", 0) == 0
    assert hist2["dispatch_delta"].get("binarize", 0) == 0


def test_pool_fingerprint_guard():
    x, y = _data()
    pool, borders, _ = _pool_setup(x)
    other_borders, _ = quantize.compute_borders(x, 8)
    with pytest.raises(ValueError, match="different schema"):
        GBDTTrainer(make_loss("rmse"), PARAMS).fit_pool(
            pool, y, borders=other_borders)


def test_serve_handoff_exact():
    """The fitted ensemble round-trips through Predictor.build to EXACT
    parity with the trainer's reported training-time predictions, and
    GBDTServer serves it directly."""
    x, y = _data(seed=5)
    pool, borders, n_borders = _pool_setup(x)
    loss = make_loss("rmse")
    ens, hist = GBDTTrainer(loss, PARAMS).fit_pool(
        pool, y, borders=borders, n_borders=n_borders)

    plan = Predictor.build(ens, strategy="staged", layout="soa")
    served = np.asarray(plan.raw(pool))
    np.testing.assert_array_equal(served, hist["final_raw"])

    server = GBDTServer(ens, strategy="staged", backend="ref",
                        max_batch=64)
    try:
        assert server.schema_fingerprint == pool.fingerprint
        proba = server.predict_pool(pool)
        want = np.asarray(proba_from_raw(jnp.asarray(hist["final_raw"]),
                                         ens.n_outputs))
        np.testing.assert_allclose(proba, want, rtol=1e-5, atol=1e-5)
    finally:
        server.close()


def test_fit_source_matches_fit_pool():
    """Out-of-core streamed ingest (multi-chunk) trains the same model
    as in-core pool training: the reservoir border pass is exact when
    the source fits the sample budget, and chunked binarize is
    row-wise deterministic."""
    from repro.scoring import sources as sources_lib

    source = sources_lib.SyntheticSource("covertype", scale=0.003,
                                         split="train", repeat=2)
    ds = source.dataset
    y = np.tile(np.asarray(ds.y_train), 2)[:source.n_rows]
    loss = make_loss(ds.loss, n_classes=ds.n_classes)
    params = boosting.BoostingParams(n_trees=4, depth=3, max_bins=16,
                                     seed=0)

    chunk = 256
    assert source.n_rows > chunk       # genuinely multi-chunk
    tr_s = GBDTTrainer(loss, params)
    ens_s, hist_s = tr_s.fit_source(source, y, chunk_rows=chunk)
    assert hist_s["n_chunks"] > 1
    assert tr_s.metrics.snapshot()["n_chunks"] > 1

    # in-core reference: same rows materialized at once
    x_full = np.tile(np.asarray(ds.x_train, np.float32),
                     (2, 1))[:source.n_rows]
    borders, n_borders = quantize.compute_borders(x_full,
                                                  params.max_bins)
    pool = quantize.quantize_pool(jnp.asarray(x_full), borders)
    ens_p, _ = GBDTTrainer(loss, params).fit_pool(
        pool, y, borders=borders, n_borders=n_borders)

    np.testing.assert_array_equal(np.asarray(ens_s.split_features),
                                  np.asarray(ens_p.split_features))
    np.testing.assert_array_equal(np.asarray(ens_s.split_bins),
                                  np.asarray(ens_p.split_bins))
    np.testing.assert_array_equal(np.asarray(ens_s.leaf_values),
                                  np.asarray(ens_p.leaf_values))


def test_metrics_snapshot():
    """TrainingMetrics reports the shared serving vocabulary: pinned
    key set, rows_per_s in trained sample-rows, stage fractions that
    partition busy time."""
    x, y = _data()
    pool, borders, n_borders = _pool_setup(x)
    tr = GBDTTrainer(make_loss("rmse"), PARAMS, name="snap-test")
    tr.fit_pool(pool, y, borders=borders, n_borders=n_borders)
    snap = tr.metrics.snapshot()

    assert set(snap) == {
        "model", "iterations", "rows_trained", "rows_per_s",
        "iter_p50_ms", "iter_p99_ms", "hist_p50_ms", "split_p50_ms",
        "leaf_p50_ms", "hist_frac", "split_frac", "leaf_frac",
        "first_train_loss", "final_train_loss", "quantize_s",
        "n_chunks", "chunk_rows", "hist_dispatches",
    }
    assert snap["model"] == "snap-test"
    assert snap["iterations"] == PARAMS.n_trees
    assert snap["rows_trained"] == PARAMS.n_trees * len(x)
    assert snap["rows_per_s"] > 0
    for frac in ("hist_frac", "split_frac", "leaf_frac"):
        assert 0.0 <= snap[frac] <= 1.0
    assert (snap["hist_frac"] + snap["split_frac"] + snap["leaf_frac"]
            <= 1.0 + 1e-6)
    assert snap["final_train_loss"] < snap["first_train_loss"]
    assert snap["hist_dispatches"] <= PARAMS.depth


def test_empty_metrics_snapshot():
    snap = TrainingMetrics("idle").snapshot()
    assert snap["iterations"] == 0
    assert snap["rows_per_s"] == 0
    assert np.isnan(snap["final_train_loss"])
