"""The paper's image-embeddings workload: backbone embeddings -> KNN
features (L2SqrDistance hotspot) -> GBDT multiclass head.

Run:  PYTHONPATH=src python examples/embeddings_knn.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import boosting, knn, losses, predict
from repro.core.boosting import BoostingParams
from repro.data import synthetic
from repro.serving.engine import EmbeddingGBDTPipeline


def main():
    ds = synthetic.load("image_embeddings")
    print(f"embeddings: train {ds.emb_train.shape} test {ds.emb_test.shape}")

    feat = knn.KNNFeaturizer(jnp.asarray(ds.emb_train),
                             jnp.asarray(ds.y_train),
                             n_classes=ds.n_classes, k=16)
    x_train = knn.augment_with_knn(ds.x_train, ds.emb_train, feat)
    print(f"augmented features: {x_train.shape} "
          f"(+{feat.n_features} KNN features)")

    loss = losses.make_loss("multiclass", n_classes=ds.n_classes)
    ens, hist = boosting.fit(
        x_train, ds.y_train, loss=loss,
        params=BoostingParams(n_trees=120, depth=4, learning_rate=0.1))

    pipeline = EmbeddingGBDTPipeline(feat, ens)
    pred = pipeline.predict(ds.emb_test)
    acc = float((pred == ds.y_test).mean())
    print(f"test accuracy: {acc:.4f} (paper reports 0.802 on real VOC)")

    # baseline without KNN features, for the ablation
    ens0, _ = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                           params=BoostingParams(n_trees=120, depth=4,
                                                 learning_rate=0.1))
    pred0 = predict.predict_class(ens0, jnp.asarray(ds.x_test))
    print(f"without KNN features: {float((np.asarray(pred0) == ds.y_test).mean()):.4f}")


if __name__ == "__main__":
    main()
