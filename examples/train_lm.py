"""Train a reduced LM backbone (any of the 10 assigned archs) for a few
hundred steps on CPU with the fault-tolerant trainer.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch glm4-9b]
      [--steps 200]
"""
import argparse

import numpy as np

from repro import configs
from repro.data.pipeline import Prefetcher, TokenSource
from repro.launch.mesh import make_local_mesh
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=True)
    mesh = make_local_mesh()
    ts = TokenSource(cfg.vocab_size, seq_len=64, batch_size=8)

    def stream():
        step = 0
        while True:
            b = ts.next_batch(step)
            if cfg.frontend:
                b["frontend_embeds"] = np.zeros(
                    (8, cfg.frontend_seq, cfg.d_model), np.float32)
            yield b
            step += 1

    tr = Trainer(cfg, mesh, args.ckpt_dir,
                 TrainerConfig(total_steps=args.steps, ckpt_every=50,
                               peak_lr=3e-3))
    tr.init_or_restore()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M(smoke) "
          f"start step={tr.step}")
    hist = tr.train(Prefetcher(stream(), depth=2))
    first, last = hist[0], hist[-1]
    print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{len(hist)} steps; stragglers={len(tr.straggler_steps)}")


if __name__ == "__main__":
    main()
