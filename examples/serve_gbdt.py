"""End-to-end serving driver: a GBDT model served with batched requests
(the paper's speedup exists only for batched prediction — this is the
production shape of that finding).

Concurrent clients hit the deadline batcher; flushed batches are padded
to power-of-two buckets so the compiled plan traces at most once per
bucket (see docs/serving.md).  The server builds one `Predictor` from a
`PredictConfig` at construction: --strategy fused runs the single-pass
Pallas kernel path.

Run:  PYTHONPATH=src python examples/serve_gbdt.py [--strategy fused]
"""
import argparse
import json
import threading
import time

import numpy as np

from repro.core import boosting, losses
from repro.core.boosting import BoostingParams
from repro.core.predictor import PredictConfig
from repro.data import synthetic
from repro.serving.engine import GBDTServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", choices=["auto", "staged", "fused"],
                    default="auto")
    ap.add_argument("--backend", choices=["auto", "pallas", "ref"],
                    default="auto")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--per-client", type=int, default=25)
    args = ap.parse_args()

    ds = synthetic.load("santander", scale=0.004)
    loss = losses.make_loss("logloss")
    ens, _ = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                          params=BoostingParams(n_trees=100, depth=2,
                                                learning_rate=0.1))
    config = PredictConfig(strategy=args.strategy, backend=args.backend)
    server = GBDTServer(ens, config=config, max_batch=128,
                        max_wait_ms=3.0, name="santander")
    print(f"plan: {server.config} buckets={server.buckets}")

    n_clients, per_client = args.clients, args.per_client
    lat: list[float] = []
    lock = threading.Lock()

    def client(cid):
        rng = np.random.default_rng(cid)
        for i in range(per_client):
            x = ds.x_test[rng.integers(0, len(ds.x_test))]
            t0 = time.perf_counter()
            proba = server.batcher.submit(cid, x).get(timeout=30)
            with lock:
                lat.append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    n = n_clients * per_client

    lat_ms = np.asarray(lat) * 1e3
    sizes = server.batcher.batch_sizes
    snap = server.metrics.snapshot()
    print(f"served {n} requests in {wall:.2f}s "
          f"({n / wall:.0f} req/s)")
    print(f"latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms")
    print(f"batches formed: {len(sizes)}, mean size "
          f"{np.mean(sizes):.1f} (batching amortizes the vector width)")
    print(f"bucket usage: {server.batcher.bucket_counts}; "
          f"recompiles={snap['recompiles']} "
          f"(bounded by {len(server.buckets)} buckets)")
    print(f"server metrics: {json.dumps(snap, default=float)}")
    server.close()


if __name__ == "__main__":
    main()
