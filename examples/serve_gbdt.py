"""End-to-end serving driver: a GBDT model served with batched requests
(the paper's speedup exists only for batched prediction — this is the
production shape of that finding).

Run:  PYTHONPATH=src python examples/serve_gbdt.py
"""
import threading
import time

import numpy as np

from repro.core import boosting, losses
from repro.core.boosting import BoostingParams
from repro.data import synthetic
from repro.serving.engine import GBDTServer


def main():
    ds = synthetic.load("santander", scale=0.004)
    loss = losses.make_loss("logloss")
    ens, _ = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                          params=BoostingParams(n_trees=100, depth=2,
                                                learning_rate=0.1))
    server = GBDTServer(ens, max_batch=128, max_wait_ms=3.0)

    n_clients, per_client = 8, 25
    lat: list[float] = []
    lock = threading.Lock()

    def client(cid):
        rng = np.random.default_rng(cid)
        for i in range(per_client):
            x = ds.x_test[rng.integers(0, len(ds.x_test))]
            t0 = time.perf_counter()
            proba = server.batcher.submit(cid, x).get(timeout=30)
            with lock:
                lat.append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    n = n_clients * per_client

    lat_ms = np.asarray(lat) * 1e3
    sizes = server.batcher.batch_sizes
    print(f"served {n} requests in {wall:.2f}s "
          f"({n / wall:.0f} req/s)")
    print(f"latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms")
    print(f"batches formed: {len(sizes)}, mean size "
          f"{np.mean(sizes):.1f} (batching amortizes the vector width)")
    server.close()


if __name__ == "__main__":
    main()
