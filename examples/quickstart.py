"""Quickstart: train a CatBoost-style GBDT in JAX, build a compiled
prediction plan, verify the strategies against each other.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import boosting, losses
from repro.core.boosting import BoostingParams
from repro.core.predictor import PredictConfig, Predictor
from repro.data import synthetic


def main():
    # Covertype-shaped synthetic data (54 features, 7 classes)
    ds = synthetic.load("covertype", scale=0.01)
    loss = losses.make_loss("multiclass", n_classes=7)
    params = BoostingParams(n_trees=80, depth=6, learning_rate=0.4)

    print(f"training on {ds.x_train.shape} ...")
    ens, hist = boosting.fit(ds.x_train, ds.y_train, loss=loss,
                             params=params)
    print(f"ensemble: {ens.describe()}")
    print(f"final train loss {hist['train_loss'][-1]:.4f} "
          f"metric {hist['final_metric']:.4f}")

    # Build the plan once (auto resolved to a concrete strategy/backend,
    # model arrays padded once); every predict reuses it.
    plan = Predictor.build(ens)
    print(f"plan: {plan.config}")

    x_test = jnp.asarray(ds.x_test)
    pred = plan.classify(x_test)
    acc = float((np.asarray(pred) == ds.y_test).mean())
    print(f"test accuracy: {acc:.4f}")

    # Quantize once, score many: binarize the batch a single time into
    # a uint8 pool; every subsequent predict skips binarization (the
    # paper's evaluators only ever see the quantized representation).
    pool = plan.quantize(x_test)
    pool_pred = plan.classify(pool)
    same = bool(np.array_equal(np.asarray(pred), np.asarray(pool_pred)))
    print(f"quantized pool: bins {pool.bins.shape} {pool.bins.dtype}, "
          f"schema {pool.fingerprint}, float==pool predictions: {same}")

    # strategies must agree (paper's x86-vs-RISC-V parity check analog)
    staged = Predictor.build(ens, PredictConfig(strategy="staged",
                                                backend="ref"))
    fused = Predictor.build(ens, PredictConfig(strategy="fused",
                                               backend="ref"))
    err = float(jnp.max(jnp.abs(staged.raw(x_test[:64])
                                - fused.raw(x_test[:64]))))
    print(f"staged vs fused max deviation: {err:.2e}  "
          f"({'OK' if err < 1e-4 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
